"""End-to-end driver: train a ~100M-parameter LM with Sparse-on-Dense
weights, checkpointing and fault tolerance.

Default (``--smoke``) runs a reduced model for 120 steps in ~2 min on CPU
and prints the loss curve; ``--full`` trains the real ~130M config (sized
for accelerators — expect minutes/step on CPU).

Run:  PYTHONPATH=src python examples/train_sparse_lm.py --smoke
"""
import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--density", type=float, default=0.4)
    args = ap.parse_args()

    cli = ["--arch", "xlstm-125m",          # the ~100M-class assigned arch
           "--steps", str(args.steps),
           "--sod", "tiled_csc", "--density", str(args.density),
           "--lr", "3e-3", "--ckpt-every", "40",
           "--ckpt-dir", "/tmp/sod_100m_ckpt", "--log-every", "10"]
    if args.smoke:
        cli += ["--reduced", "--batch", "8", "--seq", "128"]
    else:
        cli += ["--batch", "8", "--seq", "512"]
    summary = train.main(cli)
    drop = summary["first_loss"] - summary["last_loss"]
    print(f"\nloss {summary['first_loss']:.3f} → {summary['last_loss']:.3f} "
          f"(-{drop:.3f}) over {summary['steps']} steps "
          f"[sparse weights, fixed mask, density {args.density}]")


if __name__ == "__main__":
    main()
