"""Quickstart: Sparse-on-Dense in five minutes (CPU).

1. prune a weight matrix (unstructured magnitude, the paper's setting),
2. pack it into TiledCSC (16-bit values + 8-bit in-tile row indices),
3. run the fused decompress+matmul Pallas kernel and check it against the
   dense result,
4. compare memory footprints (the paper's energy argument),
5. drop packed weights into a real model and run a forward pass.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import formats, pruning
from repro.core.sod import SoDConfig, sodify_params, tree_weight_bytes
from repro.data.pipeline import SyntheticLMData
from repro.kernels import ops
from repro.models.model import build_model


def main():
    key = jax.random.PRNGKey(0)

    # -- 1/2: prune + pack ----------------------------------------------------
    w = jax.random.normal(key, (1024, 1024))
    w_sparse = pruning.magnitude_prune(w, density=0.3)
    packed = formats.pack_tiled_csc(w_sparse, tile=(128, 128))
    print(f"density        : {formats.density(w_sparse):.3f}")
    print(f"dense bytes    : {packed.nbytes_dense():,}")
    print(f"compressed     : {packed.nbytes_compressed():,} "
          f"({packed.compression_ratio():.2f}x, paper: 1.5·density)")

    # -- 3: fused kernel vs dense ----------------------------------------------
    x = jax.random.normal(jax.random.fold_in(key, 1), (256, 1024))
    y_kernel = ops.sod_matmul(x, packed, impl="pallas")   # interpret on CPU
    y_dense = x @ w_sparse
    err = float(jnp.abs(y_kernel - y_dense).max())
    print(f"kernel max|err|: {err:.2e}  (vs dense matmul)")
    assert err < 1e-3

    # -- 4/5: a whole model in SoD mode ----------------------------------------
    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(
        sod=SoDConfig(mode="tiled_csc", density=0.3, min_dim=64))
    model = build_model(cfg)
    params = sodify_params(model.init(key), cfg.sod)
    stats = tree_weight_bytes(params)
    print(f"model weights  : {stats['dense']:,} B dense → "
          f"{stats['compressed']:,} B packed ({stats['ratio']:.2f}x)")
    print("  (toy 128-dim matrices pay tile-padding + max-column-cap "
          "overhead; production dims amortize it — see EXPERIMENTS.md)")
    batch = SyntheticLMData(cfg, 2, 64, seed=0).batch(0)
    loss, _ = model.loss(params, batch)
    print(f"packed-model loss: {float(loss):.4f}")


if __name__ == "__main__":
    main()
