"""Batched serving example: prefill a prompt batch, decode greedily, with
Sparse-on-Dense weights (compressed storage, dense MXU compute) — then the
continuous-batching engine replaying a ragged Poisson request trace
through a paged KV cache.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch import serve


def main():
    print("== dense weights ==")
    serve.main(["--arch", "llama3.2-1b", "--reduced", "--batch", "4",
                "--prompt-len", "32", "--gen", "16"])
    print("== Sparse-on-Dense (density 0.3, compressed storage) ==")
    serve.main(["--arch", "llama3.2-1b", "--reduced", "--batch", "4",
                "--prompt-len", "32", "--gen", "16",
                "--sod", "tiled_csc", "--density", "0.3"])
    print("== hybrid (zamba2: O(1) mamba state + shared-attn KV) ==")
    serve.main(["--arch", "zamba2-2.7b", "--reduced", "--batch", "2",
                "--prompt-len", "16", "--gen", "8"])
    demo_engine()


def demo_engine():
    """Continuous batching: staggered arrivals, mixed lengths, paged KV."""
    print("== engine: Poisson trace, SoD weights, paged KV cache ==")
    serve.main(["--arch", "llama3.2-1b", "--reduced", "--engine",
                "--requests", "8", "--arrival-rate", "0.5",
                "--prompt-len", "16", "--gen", "8", "--max-slots", "4",
                "--page-size", "8",
                "--sod", "tiled_csc", "--density", "0.3", "--plan", "auto"])


if __name__ == "__main__":
    main()
