"""Sparse-on-Dense at the interconnect: compressed weight all-gather and
top-k gradient all-reduce on a (forced) 8-device mesh.

This is the paper's compressed-memory-boundary trade applied to collectives
(DESIGN.md §2): FSDP-sharded weights cross the wire at ≈1.5·density of their
dense bytes and are re-densified locally before the dense matmul.

Run:  PYTHONPATH=src python examples/sod_fsdp_demo.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax                                          # noqa: E402
import jax.numpy as jnp                             # noqa: E402
import numpy as np                                  # noqa: E402
from jax.sharding import Mesh                       # noqa: E402

from repro.core import pruning                      # noqa: E402
from repro.core.formats import pack_tiled_csc       # noqa: E402
from repro.kernels import registry                  # noqa: E402
from repro.runtime import sod_fsdp                  # noqa: E402


def main():
    mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("data", "model"))
    key = jax.random.PRNGKey(0)

    # ---- compressed weight all-gather -------------------------------------
    density = 0.25
    w = pruning.random_sparse(key, (1024, 1024), density)
    packed = pack_tiled_csc(w, tile=(128, 128))
    x = jax.random.normal(jax.random.fold_in(key, 1), (64, 1024))
    with mesh, registry.record_dispatches() as dispatch_log:
        sharded = sod_fsdp.shard_packed(packed, mesh, axis="data")
        y = sod_fsdp.sod_fsdp_matmul(x, sharded, mesh, axis="data")
    err = float(jnp.abs(y - x @ w).max())
    dense_bytes = w.size * 2
    comp_bytes = packed.nbytes_compressed()
    print(f"weight all-gather: {dense_bytes:,} B dense → {comp_bytes:,} B "
          f"compressed ({comp_bytes/dense_bytes:.2f}×), max|err|={err:.2e}")
    # which registry impl + tuned params the shard_map body dispatched —
    # a silent fallback to the XLA oracle would show up right here
    for line in registry.dispatch_summary(dispatch_log):
        print(f"  dispatched: {line}")
    print("savings model:", sod_fsdp.collective_savings(density, ratio=0.05))

    # ---- compressed gradient all-reduce with error feedback ----------------
    g = jax.random.normal(key, (8, 65536))
    with mesh:
        mean1, resid = sod_fsdp.compressed_grad_allreduce(g, mesh, ratio=0.1)
    exact = np.asarray(g).reshape(4, 2, -1).mean(0)
    rel = np.linalg.norm(np.asarray(mean1)[:2] - exact) / np.linalg.norm(exact)
    print(f"grad all-reduce @ ratio 0.1: rel err {rel:.3f} "
          f"(residual carried to next step: {float(jnp.abs(resid).sum()):.1f})")


if __name__ == "__main__":
    main()
