#!/usr/bin/env python
"""Summarize a Chrome trace-event file written by the ``--trace`` flags.

Renders two views of a trace produced by ``repro.obs.Tracer.export``:

* **self time per span** — per track, total and *self* time (duration
  minus nested children) for every span name, so "where does a step
  go?" is answerable without opening Perfetto;
* **top slowest requests** — per-request slot residency summed over
  ``cat="request"`` spans (a preempted request has several residencies).

Usage:
  python scripts/trace_report.py out.trace.json [--top 5] [--track engine]

The file parses any trace-event JSON with ``B``/``E`` pairs nesting LIFO
per ``tid``; unmatched events are skipped, not fatal.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def load_events(path: str | pathlib.Path) -> list[dict]:
    """Read a trace file and return its ``traceEvents`` list.

    Accepts both the object form (``{"traceEvents": [...]}``) and the
    bare-array form of the trace-event format.
    """
    data = json.loads(pathlib.Path(path).read_text())
    return data["traceEvents"] if isinstance(data, dict) else data


def self_times(events: list[dict]) -> dict[tuple[str, str], dict]:
    """Aggregate span durations per ``(tid, name)``.

    Returns ``{(tid, name): {"count", "total_us", "self_us"}}`` where
    ``self_us`` excludes time spent in nested child spans on the same
    track.  Events must be in timestamp order per tid (as exported).
    """
    stacks: dict[str, list[list]] = {}   # tid -> [[name, ts, child_us], ...]
    agg: dict[tuple[str, str], dict] = {}
    for ev in events:
        ph = ev.get("ph")
        tid = str(ev.get("tid"))
        if ph == "B":
            stacks.setdefault(tid, []).append([ev["name"], ev["ts"], 0.0])
        elif ph == "E":
            stack = stacks.get(tid)
            if not stack:
                continue                 # unmatched E (truncated ring)
            name, ts0, child = stack.pop()
            dur = ev["ts"] - ts0
            a = agg.setdefault((tid, name),
                               {"count": 0, "total_us": 0.0, "self_us": 0.0})
            a["count"] += 1
            a["total_us"] += dur
            a["self_us"] += dur - child
            if stack:
                stack[-1][2] += dur
    return agg


def request_totals(events: list[dict]) -> dict[str, dict]:
    """Total slot residency per request from ``cat="request"`` spans.

    Returns ``{name: {"total_us", "residencies"}}`` — a request that was
    preempted and resumed contributes one residency per slot tenure.
    """
    open_: dict[tuple[str, str], float] = {}
    totals: dict[str, dict] = {}
    for ev in events:
        if ev.get("cat") != "request":
            continue
        key = (str(ev.get("tid")), ev["name"])
        if ev.get("ph") == "B":
            open_[key] = ev["ts"]
        elif ev.get("ph") == "E":
            ts0 = open_.pop(key, None)
            if ts0 is None:
                continue
            t = totals.setdefault(ev["name"],
                                  {"total_us": 0.0, "residencies": 0})
            t["total_us"] += ev["ts"] - ts0
            t["residencies"] += 1
    return totals


def report(path: str | pathlib.Path, *, track: str | None = None,
           top: int = 5) -> dict:
    """Build the full report for a trace file as a JSON-ready dict."""
    events = load_events(path)
    spans = self_times(events)
    if track is not None:
        spans = {k: v for k, v in spans.items() if k[0] == track}
    requests = request_totals(events)
    slowest = sorted(requests.items(), key=lambda kv: -kv[1]["total_us"])
    return {
        "events": len(events),
        "spans": {f"{tid}:{name}": v for (tid, name), v in spans.items()},
        "slowest_requests": [
            {"request": name, **v} for name, v in slowest[:top]],
    }


def main(argv=None) -> int:
    """CLI entry point: print the self-time and slowest-request tables."""
    ap = argparse.ArgumentParser(
        description="summarize a repro --trace Chrome trace-event file")
    ap.add_argument("trace", help="trace JSON path (from a --trace flag)")
    ap.add_argument("--track", default=None,
                    help="restrict the span table to one track "
                         "(e.g. engine, spec, autotune)")
    ap.add_argument("--top", type=int, default=5,
                    help="slowest requests to list (default 5)")
    args = ap.parse_args(argv)
    try:
        rep = report(args.trace, track=args.track, top=args.top)
    except (OSError, ValueError, KeyError) as e:
        print(f"error: cannot parse {args.trace}: {e}", file=sys.stderr)
        return 1

    print(f"{args.trace}: {rep['events']} events")
    print("\nself time per span"
          + (f" (track={args.track})" if args.track else "") + ":")
    print(f"  {'track:span':<32} {'count':>6} {'total_ms':>10} "
          f"{'self_ms':>10}")
    rows = sorted(rep["spans"].items(), key=lambda kv: -kv[1]["self_us"])
    for name, v in rows:
        print(f"  {name:<32} {v['count']:>6} {v['total_us'] / 1e3:>10.3f} "
              f"{v['self_us'] / 1e3:>10.3f}")

    if rep["slowest_requests"]:
        print(f"\ntop {args.top} slowest requests (slot residency):")
        print(f"  {'request':<16} {'total_ms':>10} {'residencies':>12}")
        for r in rep["slowest_requests"]:
            print(f"  {r['request']:<16} {r['total_us'] / 1e3:>10.3f} "
                  f"{r['residencies']:>12}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
