#!/usr/bin/env python
"""Markdown link checker for README and docs/ — stdlib only.

Validates every ``[text](target)`` in the given markdown files (or every
``*.md`` under given directories):

* relative file links must resolve on disk (relative to the linking file);
* ``#anchor`` fragments — bare or on a relative link — must match a
  heading in the target file, using GitHub's slug rules (lowercase,
  spaces to dashes, punctuation dropped);
* external ``http(s)://`` and ``mailto:`` links are skipped (CI must not
  depend on network reachability);
* inline-code **code pointers** of the form ``path/to/file.py:Symbol``
  (the style docs/ARCHITECTURE.md uses) must point at a real file —
  resolved against the repo root, ``src/repro/``, or the doc's own
  directory — and ``Symbol`` must be defined in it (a ``def``/``class``
  or a module-level assignment).

Exit status 1 with a per-link report if anything is broken.

Usage:
  python scripts/check_links.py README.md docs
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — excluding images' leading "!" is unnecessary: image
# paths should resolve too.  Nested parens in URLs are out of scope.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
# `path/file.py:Symbol` — the code-pointer idiom in docs/ARCHITECTURE.md
CODE_PTR_RE = re.compile(r"`([\w./-]+\.py):(\w+)`")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")
EXTERNAL = ("http://", "https://", "mailto:")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markup, lowercase, drop punctuation,
    spaces to dashes."""
    text = re.sub(r"`([^`]*)`", r"\1", heading)          # inline code
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # links
    # strip * emphasis markers; literal mid-word underscores survive into
    # GitHub's anchors (e.g. `BENCH_serving.json` → bench_servingjson), so
    # only strip _ when it wraps a word as emphasis
    text = re.sub(r"\*", "", text)
    text = re.sub(r"\b_([^_]+)_\b", r"\1", text)
    text = text.strip().lower()
    text = re.sub(r"[^\w\- ]", "", text)
    return text.replace(" ", "-")


def headings_of(path: pathlib.Path) -> set[str]:
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    for line in path.read_text().splitlines():
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        slug = github_slug(m.group(1))
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def iter_links(path: pathlib.Path):
    in_fence = False
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            yield lineno, m.group(1)


def iter_code_pointers(path: pathlib.Path):
    in_fence = False
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        if CODE_FENCE_RE.match(line):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in CODE_PTR_RE.finditer(line):
            yield lineno, m.group(1), m.group(2)


def resolve_source(doc: pathlib.Path, rel: str) -> pathlib.Path | None:
    """Find the source file a pointer names: repo root, ``src/repro/``
    (the ARCHITECTURE.md convention), or next to the doc itself."""
    root = pathlib.Path(__file__).resolve().parent.parent
    for base in (root, root / "src" / "repro", doc.parent):
        cand = base / rel
        if cand.is_file():
            return cand
    return None


def defines_symbol(src: pathlib.Path, symbol: str) -> bool:
    """True when ``symbol`` is a def/class (any nesting) or a module-level
    assignment in ``src`` — a plain text scan, no import needed."""
    pat = re.compile(
        rf"^\s*(?:def|class)\s+{re.escape(symbol)}\b"
        rf"|^{re.escape(symbol)}\s*[:=]", re.MULTILINE)
    return bool(pat.search(src.read_text()))


def check_file(path: pathlib.Path) -> list[str]:
    errors = []
    for lineno, target in iter_links(path):
        if target.startswith(EXTERNAL):
            continue
        base, _, frag = target.partition("#")
        if base:
            dest = (path.parent / base).resolve()
            if not dest.exists():
                errors.append(f"{path}:{lineno}: broken link {target!r} "
                              f"({dest} does not exist)")
                continue
        else:
            dest = path
        if frag and dest.suffix == ".md":
            if frag not in headings_of(dest):
                errors.append(f"{path}:{lineno}: broken anchor "
                              f"{target!r} (no heading slugs to "
                              f"{frag!r} in {dest.name})")
    for lineno, rel, symbol in iter_code_pointers(path):
        src = resolve_source(path, rel)
        if src is None:
            errors.append(f"{path}:{lineno}: dangling code pointer "
                          f"`{rel}:{symbol}` ({rel} not found)")
        elif not defines_symbol(src, symbol):
            errors.append(f"{path}:{lineno}: stale code pointer "
                          f"`{rel}:{symbol}` (no such symbol in {src})")
    return errors


def main(argv: list[str]) -> int:
    files: list[pathlib.Path] = []
    for arg in argv or ["README.md", "docs"]:
        p = pathlib.Path(arg)
        if p.is_dir():
            files += sorted(p.rglob("*.md"))
        elif p.exists():
            files.append(p)
        else:
            print(f"check_links: no such path {arg!r}", file=sys.stderr)
            return 1
    errors: list[str] = []
    for f in files:
        errors += check_file(f)
    for e in errors:
        print(e, file=sys.stderr)
    print(f"check_links: {len(files)} files, "
          f"{len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
