"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the kernel sweeps in ``tests/test_kernels.py``
and the jnp fallback used on non-TPU backends / inside the multi-device
dry-run.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import BlockCSR, TiledCSC

__all__ = [
    "decompress_tiled_ref",
    "decompress_block_ref",
    "sod_matmul_ref",
    "block_matmul_ref",
    "dense_matmul_ref",
]


def decompress_tiled_ref(packed: TiledCSC) -> jax.Array:
    """The decompression unit, element granular (scatter-add)."""
    return packed.to_dense()


def decompress_block_ref(packed: BlockCSR) -> jax.Array:
    return packed.to_dense()


def dense_matmul_ref(x: jax.Array, w: jax.Array,
                     out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or x.dtype
    return jnp.dot(
        x, w, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def sod_matmul_ref(x: jax.Array, packed: TiledCSC, out_dtype=None) -> jax.Array:
    """x @ decompress(packed) — the Sparse-on-Dense dataflow, unfused."""
    w = packed.to_dense()
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {w.shape}")
    return dense_matmul_ref(x, w, out_dtype)


def block_matmul_ref(x: jax.Array, packed: BlockCSR, out_dtype=None) -> jax.Array:
    w = packed.to_dense()
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"inner dims mismatch: {x.shape} @ {w.shape}")
    return dense_matmul_ref(x, w, out_dtype)
