# Sparse-on-Dense kernels: the fused decompress+matmul Pallas kernel
# (sod_matmul.py), the VREG-block zero-tile-skip kernel (block_matmul.py),
# jnp oracles (ref.py), the kernel registry + autotuner (registry.py,
# autotune.py), and the public dispatch wrappers (ops.py).
