"""VREG-block Sparse-on-Dense matmul with zero-macro-tile skipping.

The TPU-native adaptation of the paper's insight (DESIGN.md §2): the natural
decompression granule on a TPU is the (8, 128) vector register, not a single
element.  Decompression of a (bk, bn) macro tile is then a short loop of
whole-register dynamic-slice copies — near line rate on the VPU — and macro
tiles whose ``tile_nnz == 0`` skip their MXU dot entirely (a *compute* win
the paper's always-dense array cannot realize; the paper's structured-sparsity
"bypass" mode, Section V-A, taken one step further).

``tile_nnz`` and ``block_ids`` ride in SMEM via scalar prefetch so they can
steer control flow before the tile data arrives.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams
from repro.core.formats import BlockCSR

__all__ = ["block_matmul_pallas"]


def _block_matmul_kernel(
    nnz_ref,     # SMEM (Kt, Nt) int32
    ids_ref,     # SMEM (Kt, Nt, bcap) int32, -1 = padding
    x_ref,       # (bm, bk)
    bvals_ref,   # (1, 1, bcap, br, bn)
    *refs,       # [scale_ref (1,1) | cb_ref (1,ncodes)], o_ref, slab_ref, acc_ref
    kt_total: int,
    bk: int,
    br: int,
    bcap: int,
    qmode: str = "none",
):
    o_ref, slab_ref, acc_ref = refs[-3:]
    q_ref = refs[0] if qmode != "none" else None
    n = pl.program_id(0)
    m = pl.program_id(1)
    k = pl.program_id(2)
    nnz = nnz_ref[k, n]

    @pl.when(jnp.logical_and(m == 0, nnz > 0))
    def _decompress():
        bn_ = bvals_ref.shape[-1]
        cb = q_ref[...] if qmode == "codebook" else None
        # Quantized blocks accumulate in f32 (codes dequantize per block;
        # the shared per-tile scale multiplies the finished tile once).
        tile_dtype = bvals_ref.dtype if qmode == "none" else jnp.float32

        def body(s, tile):
            bid = ids_ref[k, n, s]
            # Padding (bid == -1) contributes zeros added at offset 0 — a
            # no-op because real block ids are unique and values are 0
            # (codebook entry 0 is pinned to 0.0 for the same reason).
            off = jnp.maximum(bid, 0) * br
            blk = bvals_ref[0, 0, s]
            if qmode == "codebook":
                idx = blk.astype(jnp.int32)
                deq = jnp.zeros(blk.shape, jnp.float32)
                for code in range(cb.shape[-1]):
                    deq += jnp.where(idx == code, cb[0, code], 0.0)
                blk = deq
            elif qmode != "none":
                blk = blk.astype(jnp.float32)
            cur = jax.lax.dynamic_slice(tile, (off, 0), (br, tile.shape[1]))
            return jax.lax.dynamic_update_slice(tile, cur + blk, (off, 0))

        tile = jax.lax.fori_loop(
            0, bcap, body, jnp.zeros((bk, bn_), tile_dtype)
        )
        if qmode in ("int8", "fp8"):
            tile = tile * q_ref[0, 0]
        slab_ref[k] = tile.astype(slab_ref.dtype)

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(nnz > 0)
    def _dot():
        acc_ref[...] += jnp.dot(
            x_ref[...], slab_ref[k], preferred_element_type=jnp.float32
        )

    @pl.when(k == kt_total - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "interpret", "out_dtype")
)
def block_matmul_pallas(
    x: jax.Array,
    packed: BlockCSR,
    *,
    bm: int = 128,
    interpret: bool = True,
    out_dtype=None,
):
    """``x @ decompress(packed)`` with zero-macro-tile skip, 2-D ``x``."""
    out_dtype = out_dtype or x.dtype
    kt, nt = packed.grid
    bk, bn = packed.tile
    br = packed.br
    bcap = packed.bcap
    m_dim = x.shape[0]
    if x.shape[1] != kt * bk:
        raise ValueError(f"x K dim {x.shape[1]} != packed padded K {kt * bk}")
    if m_dim % bm:
        raise ValueError(f"M={m_dim} not a multiple of bm={bm}")
    mt = m_dim // bm

    # Effective FLOPs scale with the non-zero macro-tile fraction.
    nz_tiles = int(jnp.count_nonzero(packed.tile_nnz)) if not isinstance(
        packed.tile_nnz, jax.core.Tracer
    ) else kt * nt
    cost = pl.CostEstimate(
        flops=2 * m_dim * bk * bn * max(nz_tiles, 1),
        bytes_accessed=(
            x.size * x.dtype.itemsize
            + packed.block_vals.size * packed.block_vals.dtype.itemsize
            + packed.block_ids.size * 2
            + m_dim * nt * bn * jnp.dtype(out_dtype).itemsize
        ),
        transcendentals=0,
    )

    qmode = packed.qmode
    extra_in = []
    extra_specs = []
    if qmode in ("int8", "fp8"):
        extra_in.append(packed.scale)
        extra_specs.append(pl.BlockSpec((1, 1), lambda n, m, k, *_: (k, n)))
    elif qmode == "codebook":
        cb = packed.codebook.reshape(1, -1)
        extra_in.append(cb)
        extra_specs.append(pl.BlockSpec(cb.shape, lambda n, m, k, *_: (0, 0)))

    kernel = functools.partial(
        _block_matmul_kernel, kt_total=kt, bk=bk, br=br, bcap=bcap,
        qmode=qmode,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nt, mt, kt),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda n, m, k, *_: (m, k)),
            pl.BlockSpec(
                (1, 1, bcap, br, bn), lambda n, m, k, *_: (k, n, 0, 0, 0)
            ),
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda n, m, k, *_: (m, n)),
        scratch_shapes=[
            pltpu.VMEM((kt, bk, bn), x.dtype),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_dim, nt * bn), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
    )(packed.tile_nnz, packed.block_ids, x, packed.block_vals, *extra_in)
