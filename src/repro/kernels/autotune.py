"""Autotuner for the kernel registry, with a persistent tuning cache.

Tuning happens in two stages, mirroring how the paper's design-space sweeps
work (tiling/buffering sweeps in SCNN/EIE): an **analytical prior** from the
same traffic model as :mod:`repro.core.cost_model` ranks every (impl, params)
candidate for a problem, then the top few are **measured** and the winner is
persisted.  Dispatch at trace time (inside ``jit``) only ever *reads* the
cache — measurement is strictly an outside-of-trace operation driven by
:func:`tune` / :func:`warmup_params` (the launch scripts' ``--autotune``).

Cache file format (JSON, one file per machine):

.. code-block:: json

    {
      "version": 1,
      "kernel_hash": "<sha256 prefix over src/repro/kernels/*.py>",
      "entries": {
        "tiled_csc|m=128|k=512|n=512|d=0.312|f32|interpret": {
          "impl": "pallas_fused",
          "params": {"bm": 128, "slot_chunk": 8, "k_slab": 0},
          "us": 1234.5,
          "source": "measured"
        }
      }
    }

The file lives at ``~/.cache/repro/tuning_cache.json`` unless the
``REPRO_TUNING_CACHE`` environment variable points elsewhere.  Editing any
kernel source changes ``kernel_hash`` and invalidates every entry; the
backend is part of each entry key, so one cache file serves CPU and TPU runs
of the same checkout.
"""
from __future__ import annotations

import json
import os
import pathlib
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.plan import QVALUE_BITS
from repro.kernels import registry
from repro.kernels.registry import KernelImpl, ProblemKey

__all__ = [
    "TuningCache",
    "default_cache_path",
    "get_cache",
    "set_cache",
    "key_str",
    "predict_us",
    "rank_candidates",
    "tune",
    "lookup",
    "warmup_params",
]

CACHE_VERSION = 1

# crude per-backend throughput constants for the prior (the prior only needs
# to *order* candidates; measurement fixes the magnitudes)
_PEAK_FLOPS = {"cpu": 5e10, "gpu": 1e13, "tpu": 2e14, "interpret": 5e10}
_MEM_BW = {"cpu": 2e10, "gpu": 1e12, "tpu": 1.2e12, "interpret": 2e10}
# the Pallas interpreter executes the kernel body in Python per grid step —
# orders of magnitude slower than compiled jnp; the prior must know that so
# a cold cache on CPU never routes the hot path through the interpreter.
_INTERPRET_OVERHEAD_US_PER_STEP = 300.0


def default_cache_path() -> pathlib.Path:
    """Cache file location: $REPRO_TUNING_CACHE, else the user cache dir."""
    env = os.environ.get("REPRO_TUNING_CACHE")
    if env:
        return pathlib.Path(env).expanduser()
    return pathlib.Path("~/.cache/repro/tuning_cache.json").expanduser()


def key_str(key: ProblemKey) -> str:
    """Stable string form of a :class:`ProblemKey` — the cache-entry key."""
    # tile/cap are part of the key: two packs of the same logical (K, N)
    # with different tile geometry have different param spaces and winners,
    # and must not collide on one cache entry.  The mesh signature is
    # appended only when set (SPMD dispatch): shapes are then per-local-
    # shard, and a tile tuned for the (m/dp, k, n/tp) shard must not be
    # served to an unsharded run of the same global shape (or to a
    # different mesh).
    d = f"{key.density:.3f}"
    bk, bn = key.tile
    s = (f"{key.fmt}|m={key.m}|k={key.k}|n={key.n}|d={d}"
         f"|t={bk}x{bn}|cap={key.cap}|{key.dtype}|{key.backend}")
    if key.qmode != "none":
        # appended only when quantized: pre-qmode cache entries stay valid,
        # and int8 codes vs codebook indices (same int8 dtype, different
        # dequant inner loop) cannot collide on one entry
        s += f"|q={key.qmode}"
    if key.mesh:
        s += f"|mesh={key.mesh}"
    return s


class TuningCache:
    """Persistent (impl, params) winners, versioned by the kernel sources."""

    def __init__(self, path: pathlib.Path | str | None = None):
        self.path = pathlib.Path(path) if path else default_cache_path()
        self.kernel_hash = registry.kernel_hash()
        self.entries: dict[str, dict] = {}
        self._load()

    def _load(self) -> None:
        try:
            raw = json.loads(self.path.read_text())
        except (OSError, ValueError):
            return
        if (raw.get("version") != CACHE_VERSION
                or raw.get("kernel_hash") != self.kernel_hash):
            return  # stale: kernels changed since these were measured
        entries = raw.get("entries")
        if isinstance(entries, dict):
            self.entries = entries

    def save(self) -> None:
        """Atomically persist entries (tmp-file + rename), stamped with the
        kernel-source hash so stale measurements self-invalidate."""
        self.path.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "version": CACHE_VERSION,
            "kernel_hash": self.kernel_hash,
            "entries": self.entries,
        }
        tmp = self.path.with_suffix(".tmp")
        tmp.write_text(json.dumps(payload, indent=1, sort_keys=True))
        tmp.replace(self.path)

    def get(self, key: ProblemKey) -> dict | None:
        """Cached winner for the problem, or None on a cold key."""
        return self.entries.get(key_str(key))

    def put(self, key: ProblemKey, impl: str, params: dict, us: float,
            source: str = "measured") -> None:
        """Record a winner (impl + params + measured microseconds) for the
        problem; ``source`` distinguishes measured from prior-seeded."""
        self.entries[key_str(key)] = {
            "impl": impl, "params": params, "us": us, "source": source,
        }

    def __len__(self) -> int:
        return len(self.entries)


_CACHE: TuningCache | None = None
_CACHE_PINNED = False       # set_cache() pins; env changes then can't evict


def get_cache() -> TuningCache:
    """Process-wide cache singleton (lazy; honours REPRO_TUNING_CACHE).

    A cache installed with :func:`set_cache` (e.g. the launch scripts'
    ``--tuning-cache``) is pinned: it keeps serving dispatch lookups even
    though its path differs from the env default.
    """
    global _CACHE
    if _CACHE is None or (not _CACHE_PINNED
                          and _CACHE.path != default_cache_path()):
        _CACHE = TuningCache()
    return _CACHE


def set_cache(cache: TuningCache | None) -> None:
    """Install (and pin) the process-wide cache; None unpins and reverts
    to the env-default path on next :func:`get_cache`."""
    global _CACHE, _CACHE_PINNED
    _CACHE = cache
    _CACHE_PINNED = cache is not None


def install_cache(path: str | pathlib.Path | None) -> TuningCache:
    """Resolve a cache for an explicit ``--tuning-cache`` argument.

    With a path: load that cache and pin it as the process-wide cache so
    *dispatch* reads the same file the caller tunes into.  Without: the
    default singleton.  One helper so every CLI (serve/train/bench) shares
    the pinning semantics.
    """
    if path:
        cache = TuningCache(path)
        set_cache(cache)
        return cache
    return get_cache()


def lookup(key: ProblemKey) -> dict | None:
    """Trace-safe cache read used by the dispatcher."""
    return get_cache().get(key)


# ---------------------------------------------------------------------------
# analytical prior
# ---------------------------------------------------------------------------
def predict_us(key: ProblemKey, impl: KernelImpl, params: dict) -> float:
    """Cost-model-style prediction of one candidate's runtime (µs).

    Same traffic reasoning as :mod:`repro.core.cost_model`: compute term =
    dense FLOPs at peak, memory term = bytes moved at peak bandwidth, where
    packed operands move ≈1.5·density of their dense bytes (16-bit value +
    8-bit index) and a non-resident K-slab (k_slab > 0 and < Kt) pays its
    decompression once per M-block instead of once.
    """
    m, k, n = key.m, key.k, key.n
    itemsize = jnp.dtype(key.dtype).itemsize
    flops = 2.0 * m * k * n
    x_bytes = m * k * itemsize
    out_bytes = m * n * itemsize
    dense_w_bytes = k * n * itemsize

    backend = key.backend
    peak = _PEAK_FLOPS.get(backend, 5e10)
    bw = _MEM_BW.get(backend, 2e10)

    if impl.name == "jnp_oracle":
        # scatter-decompress materializes the dense matrix, then a dense dot
        w_bytes = dense_w_bytes * 2          # write dense + read it back
        decompress_flops = key.density * k * n * 4
        us = max(flops / peak, (x_bytes + w_bytes + out_bytes) / bw) * 1e6
        us += decompress_flops / peak * 1e6
        return us

    if impl.name == "dense_ref":
        return max(flops / peak,
                   (x_bytes + dense_w_bytes + out_bytes) / bw) * 1e6

    # pallas impls: compressed traffic.  Value bytes per slot follow the
    # qmode (16-bit unquantized, 8-bit int8/fp8, 4-bit codebook index) over
    # a 1-byte row index and 2-byte dense elements — 1.5·density unquantized,
    # less when the values are stored quantized.
    vbytes = QVALUE_BITS.get(key.qmode, 16) / 8.0
    w_bytes = key.density * dense_w_bytes * ((vbytes + 1.0) / 2.0)
    bm = params.get("bm", 128)
    mt = max(-(-m // max(bm, 1)), 1)
    bk, bn = key.tile
    decomp_elems = key.kt * (n / bn) * key.cap * bn   # slots touched once
    slot_chunk = max(params.get("slot_chunk", 8), 1)
    decomp_cost = decomp_elems * (1.0 + 8.0 / slot_chunk)  # loop overhead
    if key.qmode == "codebook":
        decomp_cost *= 2.0   # compare-select over the shared-value table
    k_slab = params.get("k_slab", 0)
    if 0 < k_slab < key.kt:
        decomp_cost *= mt                    # re-decompress per M-block
    us = max(flops / peak, (x_bytes + w_bytes + out_bytes) / bw) * 1e6
    us += decomp_cost / peak * 1e6
    if backend != "tpu":
        # off-TPU the pallas kernels run through the interpreter
        grid_steps = mt * key.kt * max(-(-n // bn), 1)
        us += grid_steps * _INTERPRET_OVERHEAD_US_PER_STEP
    return us


def rank_candidates(key: ProblemKey) -> list[tuple[float, KernelImpl, dict]]:
    """All capable (impl, params) candidates, cheapest-predicted first."""
    out = []
    for impl in registry.candidates(key):
        for params in impl.param_grid(key):
            out.append((predict_us(key, impl, params), impl, params))
    out.sort(key=lambda t: (t[0], -t[1].priority))
    return out


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------
def _measure(fn: Callable[[], jax.Array], iters: int = 3) -> float:
    jax.block_until_ready(fn())          # compile
    jax.block_until_ready(fn())          # warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6                    # min-of-N: robust to host noise


def tune(
    x: jax.Array,
    w,
    *,
    backend: str | None = None,
    mesh: str = "",
    cache: TuningCache | None = None,
    top_k: int = 4,
    iters: int = 3,
    measure_fn: Callable | None = None,
    force: bool = False,
    trials_out: list | None = None,
) -> dict:
    """Measure the best candidates for ``x @ w`` and persist the winner.

    ``x`` must be a concrete 2-D array (never call this inside ``jit``).
    Returns the cache entry.  A warm cache returns immediately without
    measuring unless ``force``; ``measure_fn(fn) -> us`` is injectable for
    tests; when ``trials_out`` is a list it receives every measured
    ``(impl_name, params, us)`` (the benchmark sweep reads the default
    config's time out of it — same measurement session as the winner's).

    ``mesh`` is an SPMD mesh signature (:func:`repro.runtime.spmd.mesh_key`
    + plan): ``x``/``w`` must then be the per-device *local* shard shapes —
    single-device measurement of the local problem is exactly what the
    shard_map body will execute per chip — and the entry lands under the
    mesh-qualified cache key the SPMD dispatcher reads.
    """
    cache = get_cache() if cache is None else cache
    key = registry.problem_key(w, m=x.shape[0], backend=backend, mesh=mesh)
    hit = cache.get(key)
    if hit is not None and not force:
        return hit
    measure = measure_fn or (lambda fn: _measure(fn, iters=iters))
    ranked = rank_candidates(key)
    if not ranked:
        raise ValueError(f"no kernel impl supports {key}")
    # prior top-k, plus every capable impl's default params — the status quo
    # is always measured, so a tuned choice can never lose to it silently.
    # Trials are deduplicated (and persisted) on *canonical* params: what
    # the runner will actually execute for this M (bm clamping, slot_chunk
    # sanitizing, k_slab residency), so the same effective kernel is never
    # measured twice and the cache records what really ran.
    m = x.shape[0]
    trials: list[tuple[KernelImpl, dict]] = []
    seen: set = set()
    for _, impl, params in ranked[:max(top_k, 1)]:
        canon = impl.canonical_params(key, params, m)
        sig = (impl.name, tuple(sorted(canon.items())))
        if sig not in seen:
            trials.append((impl, canon))
            seen.add(sig)
    for impl in registry.candidates(key):
        canon = impl.canonical_params(key, impl.default_params(key), m)
        sig = (impl.name, tuple(sorted(canon.items())))
        if sig not in seen:
            trials.append((impl, canon))
            seen.add(sig)
    best: tuple[float, KernelImpl, dict] | None = None
    tracer = obs.get_tracer()
    for impl, params in trials:
        with tracer.span(f"measure:{impl.name}", track="autotune",
                         key=key_str(key), params=str(params)):
            us = float(measure(
                lambda impl=impl, params=params: impl.run(
                    x, w, backend=key.backend, **params)
            ))
        if trials_out is not None:
            trials_out.append((impl.name, dict(params), us))
        if best is None or us < best[0]:
            best = (us, impl, params)
    us, impl, params = best
    cache.put(key, impl.name, params, us)
    cache.save()
    return cache.get(key)


# ---------------------------------------------------------------------------
# model-level warmup (what launch --autotune calls)
# ---------------------------------------------------------------------------
def warmup_params(
    params,
    m_values: tuple[int, ...],
    *,
    backend: str | None = None,
    cache: TuningCache | None = None,
    iters: int = 1,
    seed: int = 0,
) -> dict:
    """Tune every distinct packed-weight shape in a param pytree.

    Walks the tree, collects unique (format, K, N, cap, dtype) layouts —
    stacked layers/experts share one entry per layout — and tunes each at
    every requested M.  Returns ``{"tuned": n_measured, "cached": n_hits}``.
    """
    from repro.core.formats import BlockCSR, TiledCSC

    cache = get_cache() if cache is None else cache
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda l: isinstance(l, (TiledCSC, BlockCSR)))
    seen: dict[tuple, object] = {}
    for leaf in leaves:
        def _slice0(a, tail):
            # first per-matrix slice of a stacked side array (None stays None)
            return None if a is None else a.reshape((-1,) + a.shape[-tail:])[0]

        if isinstance(leaf, TiledCSC):
            if leaf.lead:
                # Stacked (scan/expert) layouts: the model's scan body
                # slices lead dims off before sod.apply (lax.scan slicing +
                # tree_map(t[j])), so dispatch sees the per-layer slice —
                # tune that slice and the keys line up exactly.
                leaf = TiledCSC(_slice0(leaf.vals, 4), _slice0(leaf.rows, 4),
                                leaf.shape, leaf.tile,
                                scale=_slice0(leaf.scale, 2),
                                codebook=_slice0(leaf.codebook, 1),
                                qmode=leaf.qmode)
            sig = ("tiled_csc", leaf.shape, leaf.cap, str(leaf.dtype),
                   leaf.tile, leaf.qmode)
        elif isinstance(leaf, BlockCSR):
            if leaf.lead:
                leaf = BlockCSR(_slice0(leaf.block_vals, 5),
                                _slice0(leaf.block_ids, 3),
                                _slice0(leaf.tile_nnz, 2),
                                leaf.shape, leaf.tile, leaf.br,
                                scale=_slice0(leaf.scale, 2),
                                codebook=_slice0(leaf.codebook, 1),
                                qmode=leaf.qmode)
            sig = ("block_csr", leaf.shape, leaf.bcap, str(leaf.dtype),
                   leaf.tile, leaf.br, leaf.qmode)
        else:
            continue
        seen.setdefault(sig, leaf)

    stats = {"tuned": 0, "cached": 0}
    key_rng = jax.random.PRNGKey(seed)
    for sig, leaf in seen.items():
        for m in dict.fromkeys(int(v) for v in m_values):
            pk = registry.problem_key(leaf, m=m, backend=backend)
            if cache.get(pk) is not None:
                stats["cached"] += 1
                continue
            x = jax.random.normal(
                jax.random.fold_in(key_rng, hash(sig) % (2**31) + m),
                (m, leaf.shape[0]), jnp.float32,
            ).astype(leaf.dtype if jnp.issubdtype(
                jnp.dtype(leaf.dtype), jnp.floating) else jnp.float32)
            tune(x, leaf, backend=backend, cache=cache, iters=iters)
            stats["tuned"] += 1
    return stats
