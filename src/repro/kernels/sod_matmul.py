"""Flagship Sparse-on-Dense Pallas kernel: fused decompress + dense matmul.

This is the TPU realization of the paper's datapath (Fig. 2): compressed
weights stream HBM→VMEM (the "global buffer → decompression unit" hop), a
VPU decompression loop re-densifies each (bk, bn) tile *once per K-slab
residency*, and the MXU consumes the dense tile for every M block — the
weight-stationary reuse that amortizes decompression exactly as the paper's
dataflow amortizes its decompression-unit latency.

Memory traffic for weights is ``≈ (value_bytes + index_byte) · nnz`` instead
of ``2 · K · N`` — the paper's 1.5·density ratio (16-bit value + 8-bit index).

Grid: ``(Nt, Mt, Kt)``, K innermost.
  * decompression of tile (k, n) happens only at ``m == 0``; the dense slab
    (Kt, bk, bn) persists in VMEM scratch across the whole M sweep;
  * a float32 accumulator carries partial sums across K;
  * output (m, n) is written once at ``k == Kt-1`` (consecutive revisits).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams
from repro.core.formats import TiledCSC

__all__ = ["sod_matmul_pallas"]


def _dequant_chunk(v: jax.Array, codebook: jax.Array) -> jax.Array:
    """Codebook dequant of one slot chunk: unrolled compare-select over the
    (small, static) shared-value table — same VPU idiom as the row-index
    compare-accumulate, no gather needed."""
    idx = v.astype(jnp.int32)
    out = jnp.zeros(v.shape, jnp.float32)
    for code in range(codebook.shape[-1]):
        out += jnp.where(idx == code, codebook[0, code], 0.0)
    return out


def _decompress_tile(
    vals: jax.Array,  # (cap, bn)
    rows: jax.Array,  # (cap, bn) int32, -1 = padding
    bk: int,
    slot_chunk: int,
    codebook: jax.Array | None = None,  # (1, ncodes) for qmode='codebook'
) -> jax.Array:
    """Compare-accumulate decompression of one (bk, bn) tile (VPU loop).

    Accumulates in float32 — for quantized operands ``vals`` holds the raw
    codes; codebook indices dequantize per chunk here, while int8/fp8 codes
    sum raw and the caller applies the per-tile scale once to the finished
    tile (``Σ qᵢ·s = s·Σ qᵢ``), keeping dequant off the inner loop.
    """
    cap, bn = vals.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (bk, 1, bn), 0)

    def body(c, acc):
        r = jax.lax.dynamic_slice(rows, (c * slot_chunk, 0), (slot_chunk, bn))
        v = jax.lax.dynamic_slice(vals, (c * slot_chunk, 0), (slot_chunk, bn))
        if codebook is None:
            vf = v.astype(jnp.float32)
        else:
            vf = _dequant_chunk(v, codebook)
        hit = iota == r[None, :, :]
        contrib = jnp.where(hit, vf[None, :, :], 0.0)
        return acc + jnp.sum(contrib, axis=1)

    n_chunks = cap // slot_chunk
    tile = jax.lax.fori_loop(
        0, n_chunks, body, jnp.zeros((bk, bn), jnp.float32)
    )
    return tile


def _sod_matmul_kernel(
    x_ref,      # (bm, bk)
    vals_ref,   # (1, 1, cap, bn)
    rows_ref,   # (1, 1, cap, bn)
    *refs,      # [scale_ref (1,1) | cb_ref (1,ncodes)], o_ref, slab_ref, acc_ref
    kt_total: int,
    bk: int,
    slot_chunk: int,
    slab_len: int,
    qmode: str = "none",
):
    o_ref, slab_ref, acc_ref = refs[-3:]
    q_ref = refs[0] if qmode != "none" else None
    m = pl.program_id(1)
    k = pl.program_id(2)
    resident = slab_len >= kt_total
    slot = k if resident else jax.lax.rem(k, slab_len)

    # Resident slab: decompress each (k, n) tile once, at m == 0, and reuse
    # it across the whole M sweep (the paper's weight-stationary reuse).
    # Non-resident slab (slab_len < Kt — the VMEM-constrained k_slab tuning
    # point): re-decompress on every visit, trading VPU work for VMEM.
    # Dequantization fuses here too — the scale rides the same residency,
    # so quantized operands cost zero extra HBM round trips.
    def _decompress():
        vals = vals_ref[0, 0]
        rows = rows_ref[0, 0].astype(jnp.int32)
        cb = q_ref[...] if qmode == "codebook" else None
        tile = _decompress_tile(vals, rows, bk, slot_chunk, codebook=cb)
        if qmode in ("int8", "fp8"):
            tile = tile * q_ref[0, 0]
        slab_ref[slot] = tile.astype(slab_ref.dtype)

    if resident:
        pl.when(m == 0)(_decompress)
    else:
        _decompress()

    @pl.when(k == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], slab_ref[slot], preferred_element_type=jnp.float32
    )

    @pl.when(k == kt_total - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "slot_chunk", "k_slab", "interpret", "out_dtype"),
)
def sod_matmul_pallas(
    x: jax.Array,
    packed: TiledCSC,
    *,
    bm: int = 128,
    slot_chunk: int = 8,
    k_slab: int = 0,
    interpret: bool = True,
    out_dtype=None,
):
    """``x @ decompress(packed)`` fused, for 2-D ``x`` of shape (M, Kp).

    ``x`` must already be padded to the packed operand's padded K
    (``packed.grid[0] * bk``) and to an M multiple of ``bm``; use
    :func:`repro.kernels.ops.sod_matmul` for the general wrapper.

    ``k_slab`` bounds the VMEM scratch holding the decompressed K-slab:
    0 (default) keeps all ``Kt`` tiles resident and decompresses each once;
    ``0 < k_slab < Kt`` keeps only ``k_slab`` tiles and re-decompresses per
    M-block — the autotuner's knob for weights whose full slab exceeds VMEM.
    """
    out_dtype = out_dtype or x.dtype
    kt, nt = packed.grid
    bk, bn = packed.tile
    cap = packed.cap
    slab_len = kt if k_slab <= 0 else min(k_slab, kt)
    m_dim = x.shape[0]
    if x.shape[1] != kt * bk:
        raise ValueError(f"x K dim {x.shape[1]} != packed padded K {kt * bk}")
    if m_dim % bm:
        raise ValueError(f"M={m_dim} not a multiple of bm={bm}")
    if cap % slot_chunk:
        raise ValueError(f"cap={cap} not a multiple of slot_chunk={slot_chunk}")
    mt = m_dim // bm

    # Compressed-traffic cost estimate: this is what the roofline reads —
    # quantized operands stream fewer value bytes (itemsize shrinks).
    idx_bytes = packed.rows.dtype.itemsize
    val_bytes = packed.vals.dtype.itemsize
    cost = pl.CostEstimate(
        flops=2 * m_dim * kt * bk * nt * bn,
        bytes_accessed=(
            x.size * x.dtype.itemsize
            + packed.vals.size * (val_bytes + idx_bytes)
            + m_dim * nt * bn * jnp.dtype(out_dtype).itemsize
        ),
        transcendentals=0,
    )

    # Quantized operands append one extra input: the (Kt, Nt) per-tile
    # scale (tile-indexed alongside vals) or the shared-value codebook
    # (same (1, ncodes) block at every grid step).
    qmode = packed.qmode
    extra_in = []
    extra_specs = []
    if qmode in ("int8", "fp8"):
        extra_in.append(packed.scale)
        extra_specs.append(pl.BlockSpec((1, 1), lambda n, m, k: (k, n)))
    elif qmode == "codebook":
        cb = packed.codebook.reshape(1, -1)
        extra_in.append(cb)
        extra_specs.append(
            pl.BlockSpec(cb.shape, lambda n, m, k: (0, 0)))

    kernel = functools.partial(
        _sod_matmul_kernel, kt_total=kt, bk=bk, slot_chunk=slot_chunk,
        slab_len=slab_len, qmode=qmode,
    )
    return pl.pallas_call(
        kernel,
        grid=(nt, mt, kt),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda n, m, k: (m, k)),
            pl.BlockSpec((1, 1, cap, bn), lambda n, m, k: (k, n, 0, 0)),
            pl.BlockSpec((1, 1, cap, bn), lambda n, m, k: (k, n, 0, 0)),
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda n, m, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((m_dim, nt * bn), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((slab_len, bk, bn), x.dtype),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        cost_estimate=cost,
        interpret=interpret,
    )(x, packed.vals, packed.rows, *extra_in)
