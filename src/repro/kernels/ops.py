"""Public jit'd wrappers around the Sparse-on-Dense kernels.

These handle arbitrary leading batch dims and the dense bypass (paper
Fig. 2c): a plain dense array flows straight to ``jnp.dot`` with no
decompression, exactly as dense-format data bypasses the decompression unit
in the paper.  Implementation choice and tile parameters come from the
kernel registry (:mod:`repro.kernels.registry`) consulted with the
autotuner's persisted winners (:mod:`repro.kernels.autotune`):

* ``impl="auto"``   — registry dispatch: tuned entry if the tuning cache has
  one for this (format, shape, density, backend), else the cost-model-prior
  default.  On CPU this is the differentiable jnp oracle; on TPU (or under
  ``backend="interpret"``) the fused Pallas kernel.
* ``impl="pallas"`` — force the Pallas kernel (interpret mode off-TPU).
* ``impl="jnp"``    — force the jnp scatter oracle.

Dispatch is pure Python over static shapes, so it is trace-safe; nothing is
ever measured inside ``jit`` (run :func:`repro.kernels.autotune.tune` or the
launch scripts' ``--autotune`` to populate the cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import BlockCSR, TiledCSC
from repro.kernels import registry
from repro.kernels.decompress import decompress_pallas

__all__ = ["sod_matmul", "decompress"]

_FORCED = {
    "pallas": {"tiled_csc": "pallas_fused", "block_csr": "pallas_block"},
    "jnp": {"tiled_csc": "jnp_oracle", "block_csr": "jnp_oracle"},
}


def _as_2d(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def sod_matmul(
    x: jax.Array,
    w,
    *,
    impl: str = "auto",
    bm: int | None = None,
    interpret: bool | None = None,
    out_dtype=None,
    backend: str | None = None,
    params: dict | None = None,
) -> jax.Array:
    """``x @ W`` where ``W`` is dense, :class:`TiledCSC` or :class:`BlockCSR`.

    ``x``: (..., K).  Returns (..., N) in ``out_dtype`` (default: x.dtype).
    ``params`` overrides individual tunables (e.g. ``{"bm": 64}``) on top of
    the tuned/default choice; ``backend`` overrides dispatch-backend
    detection (``cpu``/``tpu``/``interpret``).
    """
    out_dtype = out_dtype or x.dtype
    if isinstance(w, jax.Array) or not isinstance(w, (TiledCSC, BlockCSR)):
        # dense bypass
        return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(out_dtype)

    k_logical, n_logical = w.shape
    if x.shape[-1] != k_logical:
        raise ValueError(f"x inner dim {x.shape[-1]} != W K {k_logical}")

    x2, lead = _as_2d(x)
    fmt = registry.format_of(w)
    if backend is None:
        backend = registry.current_backend()
        if impl == "pallas" and backend not in ("tpu", "interpret"):
            backend = "interpret"
        if interpret:
            backend = "interpret"
    key = registry.problem_key(w, m=x2.shape[0], backend=backend)

    if impl in _FORCED:
        chosen = registry.get_impl(_FORCED[impl][fmt])
        run_params = chosen.default_params(key)
    elif impl == "auto":
        from repro.kernels import autotune  # deferred: autotune imports registry

        chosen, run_params = registry.choose(key, tuned=autotune.lookup(key))
    else:
        raise ValueError(f"unknown impl {impl!r}; want auto | jnp | pallas")
    if params:
        run_params = dict(run_params)
        run_params.update(
            (k, v) for k, v in params.items()
            if k in chosen.param_space(key)
        )
    if bm is not None and "bm" in chosen.param_space(key):
        run_params = dict(run_params, bm=bm)

    y = chosen.run(x2, w, out_dtype=out_dtype, backend=backend, **run_params)
    return y.reshape(*lead, n_logical)


def decompress(w, *, impl: str = "auto", interpret: bool = True) -> jax.Array:
    """Dense matrix from a packed operand (logical, un-padded shape)."""
    if isinstance(w, TiledCSC) and impl in ("auto", "pallas"):
        dense = decompress_pallas(w, interpret=interpret)
        return dense[: w.shape[0], : w.shape[1]]
    if hasattr(w, "to_dense"):
        return w.to_dense()
    return w
