"""Public jit'd wrappers around the Sparse-on-Dense kernels.

These handle arbitrary leading batch dims, M/K padding, implementation
dispatch (``pallas`` on TPU / interpret, ``jnp`` oracle elsewhere), and the
dense bypass (paper Fig. 2c): a plain dense array flows straight to
``jnp.dot`` with no decompression, exactly as dense-format data bypasses the
decompression unit in the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.formats import BlockCSR, TiledCSC
from repro.kernels import ref
from repro.kernels.block_matmul import block_matmul_pallas
from repro.kernels.decompress import decompress_pallas
from repro.kernels.sod_matmul import sod_matmul_pallas

__all__ = ["sod_matmul", "decompress"]


def _as_2d(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def _pick_bm(m: int, default: int = 128) -> int:
    """Largest sublane-aligned block size dividing the padded M."""
    if m >= default:
        return default
    for bm in (64, 32, 16, 8):
        if m % bm == 0 or bm <= m:
            return bm
    return 8


def sod_matmul(
    x: jax.Array,
    w,
    *,
    impl: str = "auto",
    bm: int = 128,
    interpret: bool = True,
    out_dtype=None,
) -> jax.Array:
    """``x @ W`` where ``W`` is dense, :class:`TiledCSC` or :class:`BlockCSR`.

    ``x``: (..., K).  Returns (..., N) in ``out_dtype`` (default: x.dtype).
    """
    out_dtype = out_dtype or x.dtype
    if isinstance(w, jax.Array) or not isinstance(w, (TiledCSC, BlockCSR)):
        # dense bypass
        return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(out_dtype)

    k_logical, n_logical = w.shape
    if x.shape[-1] != k_logical:
        raise ValueError(f"x inner dim {x.shape[-1]} != W K {k_logical}")
    if impl == "jnp" or (impl == "auto" and jax.default_backend() not in ("tpu",)
                         and not interpret):
        fn = ref.sod_matmul_ref if isinstance(w, TiledCSC) else ref.block_matmul_ref
        return fn(x, w, out_dtype=out_dtype)

    x2, lead = _as_2d(x)
    m = x2.shape[0]
    kt, _ = w.grid
    bk, _ = w.tile
    kp = kt * bk
    bm_eff = _pick_bm(m, bm)
    m_pad = (-m) % bm_eff
    k_pad = kp - k_logical
    if m_pad or k_pad:
        x2 = jnp.pad(x2, ((0, m_pad), (0, k_pad)))
    if isinstance(w, TiledCSC):
        y = sod_matmul_pallas(
            x2, w, bm=bm_eff, interpret=interpret, out_dtype=out_dtype
        )
    else:
        y = block_matmul_pallas(
            x2, w, bm=bm_eff, interpret=interpret, out_dtype=out_dtype
        )
    y = y[:m, :n_logical]
    return y.reshape(*lead, n_logical)


def decompress(w, *, impl: str = "auto", interpret: bool = True) -> jax.Array:
    """Dense matrix from a packed operand (logical, un-padded shape)."""
    if isinstance(w, TiledCSC) and impl in ("auto", "pallas"):
        dense = decompress_pallas(w, interpret=interpret)
        return dense[: w.shape[0], : w.shape[1]]
    if hasattr(w, "to_dense"):
        return w.to_dense()
    return w
