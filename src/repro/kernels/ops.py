"""Public jit'd wrappers around the Sparse-on-Dense kernels.

These handle arbitrary leading batch dims and the dense bypass (paper
Fig. 2c): a plain dense array flows straight to ``jnp.dot`` with no
decompression, exactly as dense-format data bypasses the decompression unit
in the paper.  Implementation choice and tile parameters come from the
kernel registry (:mod:`repro.kernels.registry`) consulted with the
autotuner's persisted winners (:mod:`repro.kernels.autotune`):

* ``impl="auto"``   — registry dispatch: tuned entry if the tuning cache has
  one for this (format, shape, density, backend, mesh), else the cost-model-
  prior default.  On CPU this is the differentiable jnp oracle; on TPU (or
  under ``backend="interpret"``) the fused Pallas kernel.
* ``impl="pallas"`` — force the Pallas kernel (interpret mode off-TPU).
* ``impl="jnp"``    — force the jnp scatter oracle.

When a jax mesh is active (``with mesh:`` around the jit'd model step) and
the operand is packed, dispatch routes through the SPMD execution layer
(:mod:`repro.runtime.spmd`): the chosen impl runs *inside* a ``shard_map``
whose per-device body is single-device code, which is what makes the Pallas
kernels legal in pjit-sharded steps (``pallas_call`` has no GSPMD
partitioning rule).  ``spmd=None`` opts a call site out (the SPMD layer's
own shard_map bodies do this); ``REPRO_SPMD=0`` disables the routing
process-wide.

Dispatch is pure Python over static shapes, so it is trace-safe; nothing is
ever measured inside ``jit`` (run :func:`repro.kernels.autotune.tune` or the
launch scripts' ``--autotune`` to populate the cache).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.formats import BlockCSR, TiledCSC
from repro.kernels import registry
from repro.kernels.decompress import decompress_pallas

__all__ = ["sod_matmul", "decompress", "resolve"]

_FORCED = {
    "pallas": {"tiled_csc": "pallas_fused", "block_csr": "pallas_block"},
    "jnp": {"tiled_csc": "jnp_oracle", "block_csr": "jnp_oracle"},
}


def _as_2d(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def resolve(key: "registry.ProblemKey", impl: str,
            params: dict | None = None,
            bm: int | None = None,
            fallback_params: dict | None = None,
            ) -> tuple["registry.KernelImpl", dict]:
    """(impl, run_params) for a problem key — the one dispatch resolver.

    Shared by the local path below and the shard_map bodies in
    :mod:`repro.runtime.spmd`, so mesh dispatch sees exactly the same
    tuned-entry/prior/forcing semantics as single-device dispatch.

    ``params`` always overrides the tuned/default choice;
    ``fallback_params`` (a pack plan's dispatch hint) only seeds dispatch
    when no measured tuning-cache entry was found — a hint recorded at one
    M must never override a winner tuned at another.  Under forced impls
    the cache is never consulted, so the hint applies over the forced
    impl's defaults (``sod.apply`` only passes both together when the
    forcing came from the same plan entry as the hint; a caller-forced
    ``impl=`` suppresses the hint there).
    """
    fmt = key.fmt
    tuned = None
    if impl in _FORCED:
        chosen = registry.get_impl(_FORCED[impl][fmt])
        run_params = chosen.default_params(key)
        registry.note_dispatch(key, chosen, run_params, "forced")
    elif impl == "auto":
        from repro.kernels import autotune  # deferred: autotune imports registry

        tuned = autotune.lookup(key)
        chosen, run_params = registry.choose(key, tuned=tuned)
    else:
        raise ValueError(f"unknown impl {impl!r}; want auto | jnp | pallas")
    amend = False
    if fallback_params and tuned is None:
        run_params = dict(run_params)
        run_params.update(
            (k, v) for k, v in fallback_params.items()
            if k in chosen.param_space(key)
        )
        amend = True
    if params:
        run_params = dict(run_params)
        run_params.update(
            (k, v) for k, v in params.items()
            if k in chosen.param_space(key)
        )
    if bm is not None and "bm" in chosen.param_space(key):
        run_params = dict(run_params, bm=bm)
    if params or bm is not None or amend:
        registry.amend_last_dispatch(key, chosen, run_params)
    return chosen, run_params


def sod_matmul(
    x: jax.Array,
    w,
    *,
    impl: str = "auto",
    bm: int | None = None,
    interpret: bool | None = None,
    out_dtype=None,
    backend: str | None = None,
    params: dict | None = None,
    fallback_params: dict | None = None,
    spmd: object = "auto",
) -> jax.Array:
    """``x @ W`` where ``W`` is dense, :class:`TiledCSC` or :class:`BlockCSR`.

    ``x``: (..., K).  Returns (..., N) in ``out_dtype`` (default: x.dtype).
    ``params`` overrides individual tunables (e.g. ``{"bm": 64}``) on top of
    the tuned/default choice; ``backend`` overrides dispatch-backend
    detection (``cpu``/``tpu``/``interpret``).

    ``spmd``: ``"auto"`` (default) wraps the kernel in the SPMD layer's
    shard_map when a mesh is active; an explicit
    :class:`repro.runtime.spmd.SpmdPlan` forces a particular partitioning;
    ``None`` disables mesh routing for this call.
    """
    out_dtype = out_dtype or x.dtype
    if isinstance(w, jax.Array) or not isinstance(w, (TiledCSC, BlockCSR)):
        # dense bypass
        return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(out_dtype)

    k_logical, n_logical = w.shape
    if x.shape[-1] != k_logical:
        raise ValueError(f"x inner dim {x.shape[-1]} != W K {k_logical}")

    if backend is None:
        backend = registry.current_backend()
        if impl == "pallas" and backend not in ("tpu", "interpret"):
            backend = "interpret"
        if interpret:
            backend = "interpret"

    if spmd is not None and os.environ.get("REPRO_SPMD", "1") != "0":
        # deferred import: runtime layers over kernels, but the SPMD entry
        # point lives with the other runtime collectives
        from repro.runtime import spmd as spmd_mod

        plan = spmd if isinstance(spmd, spmd_mod.SpmdPlan) else None
        mesh = spmd_mod.active_mesh()
        if not spmd_mod.in_spmd_body():
            if plan is None and spmd == "auto" and mesh is not None:
                plan = spmd_mod.auto_plan(mesh, w)
            if plan is not None:
                return spmd_mod.sod_matmul_spmd(
                    x, w, mesh=mesh, plan=plan, impl=impl, bm=bm,
                    out_dtype=out_dtype, backend=backend, params=params,
                    fallback_params=fallback_params)

    x2, lead = _as_2d(x)
    key = registry.problem_key(w, m=x2.shape[0], backend=backend)
    chosen, run_params = resolve(key, impl, params=params, bm=bm,
                                 fallback_params=fallback_params)
    y = chosen.run(x2, w, out_dtype=out_dtype, backend=backend, **run_params)
    return y.reshape(*lead, n_logical)


def decompress(w, *, impl: str = "auto", interpret: bool = True) -> jax.Array:
    """Dense matrix from a packed operand (logical, un-padded shape)."""
    if isinstance(w, TiledCSC) and impl in ("auto", "pallas"):
        dense = decompress_pallas(w, interpret=interpret)
        return dense[: w.shape[0], : w.shape[1]]
    if hasattr(w, "to_dense"):
        return w.to_dense()
    return w
