"""Standalone decompression-unit kernel (paper Fig. 4, Steps 1-5).

Turns a :class:`TiledCSC` operand into its dense matrix, one (bk, bn) tile
per grid step.  This is the paper's decompression unit in isolation — used by
tests, by the micro-benchmarks that measure decompression cost, and by the
SoD-FSDP path when a weight must be re-densified once per step outside a
matmul (e.g. before an einsum XLA fuses itself).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams
from repro.core.formats import TiledCSC
from repro.kernels.sod_matmul import _decompress_tile

__all__ = ["decompress_pallas"]


def _decompress_kernel(vals_ref, rows_ref, *refs, bk, slot_chunk, qmode):
    """One (bk, bn) tile per grid step; dequant fused as in the matmul."""
    o_ref = refs[-1]
    q_ref = refs[0] if qmode != "none" else None
    vals = vals_ref[0, 0]
    rows = rows_ref[0, 0].astype(jnp.int32)
    cb = q_ref[...] if qmode == "codebook" else None
    tile = _decompress_tile(vals, rows, bk, slot_chunk, codebook=cb)
    if qmode in ("int8", "fp8"):
        tile = tile * q_ref[0, 0]
    o_ref[...] = tile.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("slot_chunk", "interpret", "out_dtype"))
def decompress_pallas(
    packed: TiledCSC,
    *,
    slot_chunk: int = 8,
    interpret: bool = True,
    out_dtype=None,
):
    """Dense (Kp, Np) matrix from a TiledCSC operand (padded shape).

    Quantized operands dequantize in-kernel; their default output dtype is
    float32 (the stored value dtype is the code, not a value).
    """
    qmode = packed.qmode
    out_dtype = out_dtype or (
        jnp.float32 if qmode != "none" else packed.vals.dtype)
    kt, nt = packed.grid
    bk, bn = packed.tile
    cap = packed.cap
    if cap % slot_chunk:
        raise ValueError(f"cap={cap} not a multiple of slot_chunk={slot_chunk}")

    idx_bytes = packed.rows.dtype.itemsize
    cost = pl.CostEstimate(
        flops=0,
        bytes_accessed=(
            packed.vals.size * (packed.vals.dtype.itemsize + idx_bytes)
            + kt * bk * nt * bn * jnp.dtype(out_dtype).itemsize
        ),
        transcendentals=0,
    )
    extra_in = []
    extra_specs = []
    if qmode in ("int8", "fp8"):
        extra_in.append(packed.scale)
        extra_specs.append(pl.BlockSpec((1, 1), lambda k, n: (k, n)))
    elif qmode == "codebook":
        cb = packed.codebook.reshape(1, -1)
        extra_in.append(cb)
        extra_specs.append(pl.BlockSpec(cb.shape, lambda k, n: (0, 0)))
    kernel = functools.partial(_decompress_kernel, bk=bk,
                               slot_chunk=slot_chunk, qmode=qmode)
    out = pl.pallas_call(
        kernel,
        grid=(kt, nt),
        in_specs=[
            pl.BlockSpec((1, 1, cap, bn), lambda k, n: (k, n, 0, 0)),
            pl.BlockSpec((1, 1, cap, bn), lambda k, n: (k, n, 0, 0)),
            *extra_specs,
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda k, n: (k, n)),
        out_shape=jax.ShapeDtypeStruct((kt * bk, nt * bn), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        cost_estimate=cost,
        interpret=interpret,
    )(packed.vals, packed.rows, *extra_in)
    return out
