"""Kernel registry: every matmul implementation, with its capabilities.

The Sparse-on-Dense datapath has several realizations — the fused
decompress+matmul Pallas kernel, the VREG-block kernel with zero-macro-tile
skip, the differentiable jnp scatter oracle, the dense bypass — and which one
is fastest depends on the backend, the operand format, the problem shape and
the density.  Instead of a static if/else, each implementation registers
itself here with

  * a **capability predicate** (``supports``): which backends/formats/shapes
    it can run at all;
  * a **tunable-parameter space** (``param_space``): the (bm, slot_chunk,
    k_slab, …) grid the autotuner may sweep;
  * a **runner** that takes an un-padded 2-D ``x`` and the packed operand and
    owns its own padding/slicing.

:mod:`repro.kernels.autotune` consumes the registry to benchmark candidates
and persist the winners; :func:`repro.kernels.ops.sod_matmul` consults it at
trace time (pure Python on static shapes — never measures inside a trace).

Backends are the strings ``cpu`` / ``gpu`` / ``tpu`` / ``interpret``, where
``interpret`` means "TPU semantics emulated via the Pallas interpreter" — the
way the kernels run in CI and on developer machines without a TPU.
"""
from __future__ import annotations

import contextlib
import dataclasses
import hashlib
import os
import pathlib
from typing import Callable

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.formats import BlockCSR, TiledCSC, fp8_dtype

__all__ = [
    "KernelImpl",
    "ProblemKey",
    "register",
    "get_impl",
    "all_impls",
    "candidates",
    "choose",
    "problem_key",
    "format_of",
    "static_density",
    "current_backend",
    "set_backend_override",
    "kernel_hash",
    "record_dispatches",
    "note_dispatch",
    "dispatch_summary",
    "dispatch_counts",
]

BACKENDS = ("cpu", "gpu", "tpu", "interpret")

# VMEM budget for the resident decompressed K-slab (bytes); beyond this the
# fused kernel must fall back to per-use decompression (k_slab=1).
VMEM_SLAB_BUDGET = 12 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class ProblemKey:
    """Static description of one matmul problem — everything the dispatcher
    may depend on at trace time (shapes/dtypes are static under jit; weight
    *values* are not, so density is a pack-time proxy, see
    :func:`static_density`)."""

    fmt: str                 # tiled_csc | block_csr | dense
    m: int
    k: int
    n: int
    density: float           # static proxy (cap/bk fill ratio), NOT data nnz
    dtype: str
    backend: str

    # format-specific static layout facts the param spaces need
    tile: tuple[int, int] = (128, 128)
    cap: int = 0             # TiledCSC slot capacity / BlockCSR bcap*br
    kt: int = 1              # K-tile grid size
    # value quantization mode of the packed operand (none|int8|fp8|codebook).
    # Distinct from dtype: int8 codes and codebook indices share the int8
    # storage dtype but need different dequant work in the kernel.
    qmode: str = "none"

    # Non-empty when dispatching *inside* the SPMD execution layer
    # (repro.runtime.spmd): a signature like "data=4,model=2|dp" naming the
    # mesh shape and partition plan.  Shapes in the key are then per-local-
    # shard, so tuned tiles are per-shard winners, and choose() knows the
    # Pallas impls are mesh-legal (shard_map gives them per-device traces).
    mesh: str = ""


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One registered implementation."""

    name: str
    formats: tuple[str, ...]
    backends: tuple[str, ...]
    differentiable: bool
    # True when the impl is legal inside a pjit-sharded model step on a
    # mesh — either natively (plain jnp ops XLA/GSPMD can partition;
    # ``mesh_axes == ()``) or via the shard_map wrappers in
    # :mod:`repro.runtime.spmd` (``mesh_axes`` names the axis roles the
    # wrapper supports).  pallas_call still has no GSPMD partitioning rule,
    # so a Pallas impl traced *directly* under pjit (dispatch with an empty
    # ``ProblemKey.mesh``) remains off-limits on a cold TPU cache — see
    # choose().
    spmd_partitionable: bool
    priority: int            # tie-break when the prior can't separate
    param_space: Callable[[ProblemKey], dict[str, tuple]]
    run: Callable[..., jax.Array]   # run(x2, w, out_dtype=?, backend=?, **params)
    # maps requested params to what the runner will actually execute for a
    # concrete M (bm clamping, slot_chunk sanitizing, k_slab residency) —
    # the autotuner dedups trials on this so it never measures the same
    # effective kernel twice; None = params are already canonical
    canonicalize: Callable[[ProblemKey, dict, int], dict] | None = None
    # mesh-axis *roles* the SPMD layer may shard this impl over inside its
    # shard_map wrapper ("data" = M-sharding, "model" = N/K tensor
    # parallelism).  Empty = natively partitionable, no wrapper needed.
    mesh_axes: tuple[str, ...] = ()
    # value-quantization modes this impl can dequantize (capability
    # predicate for the qmode axis; fp8 is additionally gated on the jax
    # build actually providing an fp8 dtype — see supports()).
    qmodes: tuple[str, ...] = ("none", "int8", "fp8", "codebook")

    @property
    def requires_shard_map(self) -> bool:
        """Mesh-legal only through the repro.runtime.spmd wrapper."""
        return self.spmd_partitionable and bool(self.mesh_axes)

    def supports(self, key: ProblemKey) -> bool:
        """Whether this impl can run the problem (format, backend, and the
        operand's value-quantization mode)."""
        if key.fmt not in self.formats or key.backend not in self.backends:
            return False
        if key.qmode not in self.qmodes:
            return False
        if key.qmode == "fp8" and fp8_dtype() is None:
            return False
        return True

    def canonical_params(self, key: ProblemKey, params: dict, m: int) -> dict:
        """Params as the runner will actually execute them for concrete
        ``m`` (clamping/sanitizing via ``canonicalize`` when defined) —
        the autotuner dedups trials on this."""
        if self.canonicalize is None:
            return dict(params)
        return self.canonicalize(key, params, m)

    def default_params(self, key: ProblemKey) -> dict:
        """First element of every axis of the param space = the hard-coded
        defaults the seed shipped with (kept first on purpose, so the tuner
        always measures the status quo as one of its candidates)."""
        return {k: v[0] for k, v in self.param_space(key).items()}

    def param_grid(self, key: ProblemKey) -> list[dict]:
        """Cartesian product of the impl's param space — the autotuner's
        candidate list for this problem."""
        space = self.param_space(key)
        grid: list[dict] = [{}]
        for name, values in space.items():
            grid = [dict(g, **{name: v}) for g in grid for v in values]
        return grid


_REGISTRY: dict[str, KernelImpl] = {}
_BACKEND_OVERRIDE: str | None = None


def register(impl: KernelImpl) -> KernelImpl:
    """Add an impl to the global registry (returns it, decorator-style)."""
    _REGISTRY[impl.name] = impl
    return impl


def get_impl(name: str) -> KernelImpl:
    """Look up a registered impl by name; KeyError lists what exists."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no kernel impl {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def all_impls() -> dict[str, KernelImpl]:
    """Snapshot of the registry (name → impl)."""
    return dict(_REGISTRY)


def candidates(key: ProblemKey) -> list[KernelImpl]:
    """All implementations able to run this problem, best-priority first."""
    out = [i for i in _REGISTRY.values() if i.supports(key)]
    return sorted(out, key=lambda i: -i.priority)


def current_backend() -> str:
    """Dispatch backend: override > env REPRO_SOD_BACKEND > jax backend."""
    if _BACKEND_OVERRIDE is not None:
        return _BACKEND_OVERRIDE
    env = os.environ.get("REPRO_SOD_BACKEND")
    if env:
        return env
    return jax.default_backend()


def set_backend_override(backend: str | None) -> None:
    """Force the dispatch backend (tests / launch flags).  None resets."""
    global _BACKEND_OVERRIDE
    if backend is not None and backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; want one of {BACKENDS}")
    _BACKEND_OVERRIDE = backend


def format_of(w) -> str:
    """Operand's packed format name: tiled_csc, block_csr, or dense."""
    if isinstance(w, TiledCSC):
        return "tiled_csc"
    if isinstance(w, BlockCSR):
        return "block_csr"
    return "dense"


def static_density(w) -> float:
    """Trace-safe density proxy from the packed container's static layout.

    For TiledCSC the per-column slot capacity bounds the fill; for BlockCSR
    the block capacity does.  Dense is 1.0.  Rounded to 1/32 so nearby packs
    share a tuning-cache entry.
    """
    if isinstance(w, TiledCSC):
        d = min(w.cap / w.tile[0], 1.0)
    elif isinstance(w, BlockCSR):
        d = min(w.bcap * w.br / w.tile[0], 1.0)
    else:
        return 1.0
    return round(d * 32) / 32


def _m_bucket(m: int) -> int:
    """Bucket M to the next power of two (≥8) so decode (m≈1) and prefill
    (m≈batch·seq) tune separately but nearby batch sizes share entries."""
    b = 8
    while b < m:
        b *= 2
    return b


def problem_key(w, m: int, backend: str | None = None,
                mesh: str = "") -> ProblemKey:
    """The dispatch/tuning identity of one packed matmul: operand layout
    (format, K/N, static density, dtype) × bucketed M × backend × mesh
    signature.  Everything the cache keys on, nothing value-dependent."""
    fmt = format_of(w)
    backend = backend or current_backend()
    if fmt == "dense":
        k, n = int(w.shape[-2]), int(w.shape[-1])
        return ProblemKey(fmt, _m_bucket(m), k, n, 1.0,
                          str(jnp.result_type(w)), backend, mesh=mesh)
    k, n = w.shape
    if fmt == "tiled_csc":
        cap, kt = w.cap, w.grid[0]
    else:
        cap, kt = w.bcap * w.br, w.grid[0]
    return ProblemKey(
        fmt, _m_bucket(m), int(k), int(n), static_density(w),
        str(jnp.dtype(w.dtype)), backend,
        tile=tuple(w.tile), cap=int(cap), kt=int(kt),
        qmode=getattr(w, "qmode", "none"), mesh=mesh,
    )


def choose(key: ProblemKey, tuned: dict | None = None
           ) -> tuple[KernelImpl, dict]:
    """Resolve (impl, params) for a problem.

    ``tuned`` is an autotune cache entry ``{"impl": ..., "params": ...}``;
    when absent (cold cache inside a trace — we never measure there) the
    highest-priority capable impl runs with its defaults, which the
    cost-model prior in :mod:`autotune` later refines.
    """
    if tuned is not None:
        impl = _REGISTRY.get(tuned.get("impl", ""))
        if impl is not None and impl.supports(key):
            params = dict(impl.default_params(key))
            params.update(tuned.get("params") or {})
            note_dispatch(key, impl, params, "tuned")
            return impl, params
    # cold cache: cheapest candidate under the analytical prior (deferred
    # import — autotune imports this module at top level).  On a real TPU
    # the model step typically runs under pjit with sharded weights, and
    # pallas_call cannot be GSPMD-partitioned — so an *untuned* TPU
    # dispatch with no mesh signature (i.e. NOT inside the
    # repro.runtime.spmd shard_map wrapper, where pallas is per-device and
    # therefore legal) is restricted to natively partitionable impls (the
    # XLA scatter+dot oracle, which is what the pre-registry code always
    # ran).  Explicitly tuned entries may still promote the pallas kernels
    # (tuning runs per-host, outside pjit, so the operator opted in
    # knowingly).
    from repro.kernels import autotune

    ranked = autotune.rank_candidates(key)
    if key.backend == "tpu" and not key.mesh:
        safe = [t for t in ranked
                if t[1].spmd_partitionable and not t[1].requires_shard_map]
        ranked = safe or ranked
    if not ranked:
        raise ValueError(f"no kernel impl supports {key}")
    _, impl, params = ranked[0]
    note_dispatch(key, impl, params, "prior")
    return impl, params


# ---------------------------------------------------------------------------
# dispatch observability: what actually ran?
# ---------------------------------------------------------------------------
# Dispatch happens at trace time (pure Python), so a recording context
# wrapped around a jit/lower call captures every registry resolution the
# traced computation made — this is how the launch drivers and demos report
# which impl a mesh step really used instead of silently falling back.
_DISPATCH_LOGS: list[list] = []


@contextlib.contextmanager
def record_dispatches(log: list | None = None):
    """Collect ``{"key", "impl", "params", "source"}`` dicts for every
    dispatch resolved while the context is active (source is ``tuned`` /
    ``prior`` / ``forced``)."""
    log = [] if log is None else log
    _DISPATCH_LOGS.append(log)
    try:
        yield log
    finally:
        # identity, not equality: content-equal nested logs must not
        # remove each other
        for i, entry in enumerate(_DISPATCH_LOGS):
            if entry is log:
                del _DISPATCH_LOGS[i]
                break


def note_dispatch(key: ProblemKey, impl: KernelImpl, params: dict,
                  source: str) -> None:
    """Record one dispatch decision into every active
    :func:`log_dispatches` capture (no-op outside any) and, when tracing
    is on, emit it as an instant event on the ``kernels`` trace track so
    tuned-vs-prior dispatches are visible on the timeline."""
    for log in _DISPATCH_LOGS:
        log.append({"key": key, "impl": impl.name, "params": dict(params),
                    "source": source})
    tr = obs.get_tracer()
    if tr.enabled:
        tr.instant(f"{impl.name}[{source}]", track="kernels", cat="dispatch",
                   fmt=key.fmt, m=key.m, k=key.k, n=key.n,
                   backend=key.backend, source=source)


def amend_last_dispatch(key: ProblemKey, impl: KernelImpl,
                        params: dict) -> None:
    """Rewrite the params of the dispatch just noted — callers that apply
    overrides on top of the chosen params (ops.resolve) use this so the
    recorded entry shows what actually ran."""
    for log in _DISPATCH_LOGS:
        if log and log[-1]["key"] == key and log[-1]["impl"] == impl.name:
            log[-1]["params"] = dict(params)


def dispatch_summary(log: list) -> list[str]:
    """Human-readable one-liners, deduplicated, for a recorded log."""
    seen: dict[str, int] = {}
    lines: list[str] = []
    for rec in log:
        k = rec["key"]
        desc = (f"{rec['impl']}[{rec['source']}] "
                f"{k.fmt} m={k.m} k={k.k} n={k.n} {k.backend}"
                + (f" mesh={k.mesh}" if k.mesh else "")
                + (f" params={rec['params']}" if rec["params"] else ""))
        if desc not in seen:
            seen[desc] = len(lines)
            lines.append(desc)
    return lines


def dispatch_counts(log: list) -> dict[str, int]:
    """Dispatch totals per ``impl[source]`` for a recorded log — the
    compact tuned-cache-coverage view the serve/dryrun reports surface
    (e.g. ``{"pallas_fused[tuned]": 12, "dense[prior]": 2}``)."""
    out: dict[str, int] = {}
    for rec in log:
        k = f"{rec['impl']}[{rec['source']}]"
        out[k] = out.get(k, 0) + 1
    return out


def kernel_hash() -> str:
    """Short content hash over the kernel sources — versions the tuning
    cache: edit any kernel and every persisted measurement is invalidated."""
    h = hashlib.sha256()
    pkg = pathlib.Path(__file__).parent
    for p in sorted(pkg.glob("*.py")):
        h.update(p.name.encode())
        h.update(p.read_bytes())
    return h.hexdigest()[:16]


# ---------------------------------------------------------------------------
# built-in implementations
# ---------------------------------------------------------------------------
def _sanitize_slot_chunk(cap: int, slot_chunk: int) -> int:
    slot_chunk = max(min(slot_chunk, cap), 1)
    while cap % slot_chunk:
        slot_chunk -= 1
    return slot_chunk


def _dtype_name(out_dtype) -> str | None:
    return jnp.dtype(out_dtype).name if out_dtype is not None else None


def _run_pallas_fused(x2, w, *, out_dtype=None, backend="interpret",
                      bm=128, slot_chunk=8, k_slab=0):
    from repro.kernels import vjp

    fn = vjp.fused_matmul(
        vjp.pick_bm(x2.shape[0], bm),
        _sanitize_slot_chunk(w.cap, slot_chunk),
        k_slab,
        backend != "tpu",
        _dtype_name(out_dtype),
    )
    return fn(x2, w)


def _run_pallas_block(x2, w, *, out_dtype=None, backend="interpret", bm=128):
    from repro.kernels import vjp

    fn = vjp.block_matmul(
        vjp.pick_bm(x2.shape[0], bm), backend != "tpu", _dtype_name(out_dtype)
    )
    return fn(x2, w)


_JITTED: dict[str, Callable] = {}


def _jitted_ref(name: str) -> Callable:
    # jit once per oracle so registry-run calls (and the autotuner's
    # measurements) see compiled-dispatch cost, same as the pallas wrappers
    if not _JITTED:
        from repro.kernels import ref

        for n, fn in (("tiled", ref.sod_matmul_ref),
                      ("block", ref.block_matmul_ref),
                      ("dense", ref.dense_matmul_ref)):
            _JITTED[n] = jax.jit(fn, static_argnames=("out_dtype",))
    return _JITTED[name]


def _run_jnp_oracle(x2, w, *, out_dtype=None, backend="cpu"):
    fn = _jitted_ref("tiled" if isinstance(w, TiledCSC) else "block")
    return fn(x2, w, out_dtype=out_dtype)


def _run_dense(x2, w, *, out_dtype=None, backend="cpu"):
    return _jitted_ref("dense")(x2, w, out_dtype=out_dtype)


def _bm_axis(key: ProblemKey) -> tuple[int, ...]:
    opts = [128] + [b for b in (256, 64, 32, 16, 8) if b <= max(key.m, 8)]
    return tuple(dict.fromkeys(opts))  # keep order, drop dups


def _fused_space(key: ProblemKey) -> dict[str, tuple]:
    # k_slab: 0 = fully resident K-slab (the seed's hard-coded behaviour,
    # kept first = default); 1 = re-decompress per use (minimal VMEM).  A
    # resident slab larger than the VMEM budget is not offered at all.
    # The slab scratch is allocated in the *activation* dtype, which can be
    # wider than the packed weights — budget for f32 worst case.
    bk, bn = key.tile
    itemsize = max(jnp.dtype(key.dtype).itemsize, 4)
    slab_bytes = key.kt * bk * bn * itemsize
    k_slab = (0, 1) if slab_bytes <= VMEM_SLAB_BUDGET else (1,)
    chunks = tuple(c for c in (8, 4, 16) if c <= key.cap)
    return {
        "bm": _bm_axis(key),
        "slot_chunk": chunks or (1,),
        "k_slab": k_slab,
    }


def _block_space(key: ProblemKey) -> dict[str, tuple]:
    return {"bm": _bm_axis(key)}


def _fused_canonical(key: ProblemKey, params: dict, m: int) -> dict:
    from repro.kernels import vjp

    k_slab = params.get("k_slab", 0)
    if k_slab <= 0 or k_slab >= key.kt:
        k_slab = 0               # fully resident, however it was spelled
    return {
        "bm": vjp.pick_bm(m, params.get("bm", 128)),
        "slot_chunk": _sanitize_slot_chunk(key.cap,
                                           params.get("slot_chunk", 8)),
        "k_slab": k_slab,
    }


def _block_canonical(key: ProblemKey, params: dict, m: int) -> dict:
    from repro.kernels import vjp

    return {"bm": vjp.pick_bm(m, params.get("bm", 128))}


# The pallas impls list "cpu" too: they run there through the interpreter,
# which the autotuner's prior penalizes heavily — so a cold cache on CPU
# still dispatches to the jnp oracle, but *measurement* may promote the
# interpreted kernel where it genuinely wins (e.g. block-skip at high
# zero-tile fractions).
# mesh-legal via the repro.runtime.spmd shard_map wrappers ("data" =
# M-sharding / compressed FSDP gather, "model" = column/row tensor
# parallelism); dispatch outside the wrapper (empty key.mesh) still treats
# them as unpartitionable — see choose().
register(KernelImpl(
    name="pallas_fused",
    formats=("tiled_csc",),
    backends=("tpu", "interpret", "cpu"),
    differentiable=True,   # custom VJP in kernels/vjp.py
    spmd_partitionable=True,
    priority=30,
    param_space=_fused_space,
    run=_run_pallas_fused,
    canonicalize=_fused_canonical,
    mesh_axes=("data", "model"),
))

register(KernelImpl(
    name="pallas_block",
    formats=("block_csr",),
    backends=("tpu", "interpret", "cpu"),
    differentiable=True,   # custom VJP in kernels/vjp.py
    spmd_partitionable=True,
    priority=30,
    param_space=_block_space,
    run=_run_pallas_block,
    canonicalize=_block_canonical,
    mesh_axes=("data", "model"),
))

register(KernelImpl(
    name="jnp_oracle",
    formats=("tiled_csc", "block_csr"),
    backends=("cpu", "gpu", "tpu"),
    differentiable=True,
    spmd_partitionable=True,
    priority=20,
    param_space=lambda key: {},
    run=_run_jnp_oracle,
))

register(KernelImpl(
    name="dense_ref",
    formats=("dense",),
    backends=BACKENDS,
    differentiable=True,
    spmd_partitionable=True,
    priority=10,
    param_space=lambda key: {},
    run=_run_dense,
))
