"""Differentiable wrappers for the Pallas kernels (custom VJPs).

``pallas_call`` has no autodiff rule, so without these the registry could
never route a *training* matmul through the fused kernels — dispatch would
have to special-case "am I under grad?" (impossible to detect at trace
time).  Instead each Pallas matmul gets an analytical backward pass in plain
jnp:

* ``dL/dx = g @ W.Tᵀ``  with ``W`` re-densified once (scatter oracle);
* ``dL/dvals`` is a *gather* of the dense weight cotangent ``xᵀ @ g`` at the
  packed (row-index, column) coordinates — the exact transpose of the
  scatter-add decompression, so padding slots (``row == -1``) receive
  exactly-zero gradient and fixed-mask sparse training stays on the mask,
  same as the jnp oracle path.

Integer leaves (row indices, block ids, tile_nnz) get ``float0`` cotangents
as JAX requires.  The wrapped callables are cached per static parameter
tuple so ``jit`` retracing stays cheap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import BlockCSR, TiledCSC, _dequant_values

__all__ = ["fused_matmul", "block_matmul", "pick_bm"]


def _pull_quant(w_vals, scale, codebook, qmode, nval_dims, gdq):
    """Cotangents of (vals, scale, codebook) given the cotangent of the
    *dequantized* slot values.

    Pulls ``gdq`` back through :func:`_dequant_values` with ``jax.vjp`` so
    the quantized-weight gradient story is definitionally the same as
    differentiating the jnp oracle's ``to_dense``: int codes get ``float0``,
    fp8 codes get fp8 cotangents scaled by the tile scale, and scale /
    codebook accumulate their chain-rule sums.
    """
    _, pull = jax.vjp(
        lambda v, s, c: _dequant_values(v, s, c, qmode, nval_dims),
        w_vals, scale, codebook)
    return pull(gdq.astype(jnp.float32))


def pick_bm(m: int, requested: int) -> int:
    """Largest sublane-aligned M-block ≤ requested that fits M."""
    for bm in (requested, 128, 64, 32, 16, 8):
        if bm <= requested and bm <= max(m, 8):
            return bm
    return 8


def _pad_m_k(x2: jax.Array, bm: int, kp: int) -> jax.Array:
    m_pad = (-x2.shape[0]) % bm
    k_pad = kp - x2.shape[1]
    if m_pad or k_pad:
        x2 = jnp.pad(x2, ((0, m_pad), (0, k_pad)))
    return x2


def _grad_w_tiles(x2: jax.Array, g: jax.Array, shape, tile, grid):
    """Cotangent of the padded dense weight, tiled to (Kt, Nt, bk, bn)."""
    kt, nt = grid
    bk, bn = tile
    gw = jnp.dot(x2.T, g, preferred_element_type=jnp.float32)
    gw = jnp.pad(gw, ((0, kt * bk - shape[0]), (0, nt * bn - shape[1])))
    return gw.reshape(kt, bk, nt, bn).transpose(0, 2, 1, 3)


@functools.lru_cache(maxsize=None)
def fused_matmul(bm: int, slot_chunk: int, k_slab: int, interpret: bool,
                 out_dtype: str | None):
    """Differentiable ``(x2, packed: TiledCSC) -> y`` through the fused
    Pallas kernel, for the given static kernel parameters."""
    from repro.kernels.sod_matmul import sod_matmul_pallas

    @jax.custom_vjp
    def f(x2, w):
        kt, _ = w.grid
        bk, _ = w.tile
        m, n_logical = x2.shape[0], w.shape[1]
        xp = _pad_m_k(x2, bm, kt * bk)
        y = sod_matmul_pallas(
            xp, w, bm=bm, slot_chunk=slot_chunk, k_slab=k_slab,
            interpret=interpret,
            out_dtype=jnp.dtype(out_dtype) if out_dtype else None,
        )
        return y[:m, :n_logical]

    def fwd(x2, w):
        return f(x2, w), (x2, w)

    def bwd(res, g):
        x2, w = res
        bk = w.tile[0]
        wd = w.to_dense()
        gx = jnp.dot(g, wd.T, preferred_element_type=jnp.float32
                     ).astype(x2.dtype)
        tiles = _grad_w_tiles(x2, g, w.shape, w.tile, w.grid)
        rows = w.rows.astype(jnp.int32)
        gdq = jnp.take_along_axis(tiles, jnp.clip(rows, 0, bk - 1), axis=2)
        gdq = jnp.where(rows >= 0, gdq, 0)
        if w.qmode == "none":
            gvals = gdq.astype(w.vals.dtype)
            gscale = gcodebook = None
        else:
            gvals, gscale, gcodebook = _pull_quant(
                w.vals, w.scale, w.codebook, w.qmode, 2, gdq)
        grows = np.zeros(w.rows.shape, jax.dtypes.float0)
        return gx, TiledCSC(vals=gvals, rows=grows, shape=w.shape,
                            tile=w.tile, scale=gscale, codebook=gcodebook,
                            qmode=w.qmode)

    f.defvjp(fwd, bwd)
    return f


@functools.lru_cache(maxsize=None)
def block_matmul(bm: int, interpret: bool, out_dtype: str | None):
    """Differentiable ``(x2, packed: BlockCSR) -> y`` through the
    zero-tile-skipping Pallas kernel."""
    from repro.kernels.block_matmul import block_matmul_pallas

    @jax.custom_vjp
    def f(x2, w):
        kt, _ = w.grid
        bk, _ = w.tile
        m, n_logical = x2.shape[0], w.shape[1]
        xp = _pad_m_k(x2, bm, kt * bk)
        y = block_matmul_pallas(
            xp, w, bm=bm, interpret=interpret,
            out_dtype=jnp.dtype(out_dtype) if out_dtype else None,
        )
        return y[:m, :n_logical]

    def fwd(x2, w):
        return f(x2, w), (x2, w)

    def bwd(res, g):
        x2, w = res
        kt, nt = w.grid
        bk, bn = w.tile
        br = w.br
        nb = bk // br
        wd = w.to_dense()
        gx = jnp.dot(g, wd.T, preferred_element_type=jnp.float32
                     ).astype(x2.dtype)
        tiles = _grad_w_tiles(x2, g, w.shape, w.tile, w.grid)
        tiles5 = tiles.reshape(kt, nt, nb, br, bn)
        ids = w.block_ids
        idx = jnp.clip(ids, 0, nb - 1)[:, :, :, None, None]
        gdq = jnp.take_along_axis(
            tiles5, jnp.broadcast_to(idx, ids.shape + (br, bn)), axis=2)
        gdq = jnp.where((ids >= 0)[:, :, :, None, None], gdq, 0)
        if w.qmode == "none":
            gblocks = gdq.astype(w.block_vals.dtype)
            gscale = gcodebook = None
        else:
            gblocks, gscale, gcodebook = _pull_quant(
                w.block_vals, w.scale, w.codebook, w.qmode, 3, gdq)
        gids = np.zeros(ids.shape, jax.dtypes.float0)
        gnnz = np.zeros(w.tile_nnz.shape, jax.dtypes.float0)
        return gx, BlockCSR(block_vals=gblocks, block_ids=gids,
                            tile_nnz=gnnz, shape=w.shape, tile=w.tile,
                            br=w.br, scale=gscale, codebook=gcodebook,
                            qmode=w.qmode)

    f.defvjp(fwd, bwd)
    return f
