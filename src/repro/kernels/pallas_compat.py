"""Version shims for the Pallas TPU API surface the kernels use.

``pltpu.CompilerParams`` was named ``TPUCompilerParams`` in older JAX
releases (≤0.4.x); resolve whichever exists once so every kernel stays
importable across the versions the CI matrix and the baked container ship.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
