"""Mixture-of-Experts MLP with top-k routing, shared experts, EP sharding.

Covers the two assigned MoE archs:
  * qwen2-moe-a2.7b — 60 routed experts top-4 + shared expert (+ gate)
  * granite-moe-1b  — 32 routed experts top-8, no shared expert

Dispatch is capacity-based (scatter → batched expert einsum → combine) so the
expert dimension shards cleanly on the ``model`` axis (expert parallelism)
and HLO FLOPs reflect *active* experts, not a dense-all-experts product.
Experts are ceil-padded to the EP axis size; the router masks padding.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import sod
from repro.models import layers

Params = dict[str, Any]


def _maybe_constrain(x: jax.Array, *axes):
    """Sharding constraint when tracing under a mesh; no-op otherwise.

    GSPMD won't propagate data-sharding through the computed-index dispatch
    scatter (it conservatively all-reduces the whole capacity buffer — §Perf
    B2, refuted); the explicit constraint pins E to the model axis and the
    block dim to the data axes (B3)."""
    try:
        from jax.interpreters import pxla
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty:
            return x
        names = set(mesh.axis_names)
        fixed = []
        for a in axes:
            if a is None:
                fixed.append(None)
            elif a == "data":
                dp = tuple(n for n in ("pod", "data") if n in names)
                fixed.append(dp if len(dp) > 1 else (dp[0] if dp else None))
            else:
                fixed.append(a if a in names else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*fixed)))
    except Exception:
        return x


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int          # real experts
    n_experts_padded: int   # ceil-padded to EP axis
    top_k: int
    d_model: int
    d_ff: int               # per-expert hidden
    n_shared: int = 0       # shared experts (always-on)
    d_shared_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    act: str = "silu"
    # Rank/dispatch within this many contiguous token blocks.  When blocks
    # align with the data-parallel sharding of the token dim, the dispatch
    # scatter is shard-local — no capacity-buffer all-reduce over the data
    # axis (EXPERIMENTS.md §Perf B2).  1 = global dispatch.
    dispatch_blocks: int = 1

    def capacity(self, n_tokens: int) -> int:
        c = int(n_tokens * self.top_k / max(self.n_experts, 1)
                * self.capacity_factor)
        return max((c + 127) // 128 * 128, 128)


def pad_experts(n_experts: int, ep_axis: int = 16) -> int:
    return (n_experts + ep_axis - 1) // ep_axis * ep_axis


def init_moe(key, spec: MoESpec, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = spec.n_experts_padded, spec.d_model, spec.d_ff

    def expert_init(k, d_in, d_out):
        scale = (1.0 / d_in) ** 0.5
        return (jax.random.normal(k, (e, d_in, d_out), jnp.float32) * scale).astype(dtype)

    params: Params = {
        "router": layers.dense_init(ks[0], d, e, jnp.float32),
        "w_gate": expert_init(ks[1], d, f),
        "w_up": expert_init(ks[2], d, f),
        "w_down": expert_init(ks[3], f, d),
    }
    if spec.n_shared:
        params["shared"] = layers.init_mlp(
            ks[4], d, spec.d_shared_ff or spec.d_ff * spec.n_shared, dtype
        )
        params["shared_gate"] = layers.dense_init(
            jax.random.fold_in(ks[4], 1), d, 1, jnp.float32
        )
    return params


def moe_mlp(params: Params, x: jax.Array, spec: MoESpec):
    """x (B, S, D) → (B, S, D), plus router aux loss."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    cap = spec.capacity(t)

    logits = jnp.dot(xt, params["router"].astype(xt.dtype),
                     preferred_element_type=jnp.float32)
    if spec.n_experts_padded > spec.n_experts:
        pad_mask = jnp.arange(spec.n_experts_padded) >= spec.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, spec.top_k)  # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- capacity-based dispatch (block-local, sort-based ranking) --------
    # B1: rank assignments within their expert via a stable argsort —
    #     O(N log N), no (T·K × E) one-hot, same first-come slot semantics.
    # B2: ranking/scatter happen independently per token *block*; blocks
    #     align with the data sharding so the dispatch scatter is local.
    e = spec.n_experts_padded
    nb = spec.dispatch_blocks if t % spec.dispatch_blocks == 0 else 1
    tb = t // nb
    cap = spec.capacity(tb)
    a_blk = expert_ids.reshape(nb, tb * spec.top_k)           # (NB, A)

    def rank_block(assign):
        order = jnp.argsort(assign, stable=True)
        sorted_e = assign[order]
        hist = jnp.zeros((e,), jnp.int32).at[assign].add(1)
        starts = jnp.cumsum(hist) - hist                      # (E,) tiny
        rank = jnp.arange(assign.shape[0], dtype=jnp.int32) \
            - starts[sorted_e]
        return jnp.zeros_like(assign).at[order].set(rank)

    slot = jax.vmap(rank_block)(a_blk).reshape(t, spec.top_k)
    keep = slot < cap
    # scatter tokens into (E, NB, C, D); NB rides the token sharding
    flat_e = expert_ids.reshape(-1)
    flat_b = jnp.repeat(jnp.arange(nb, dtype=jnp.int32), tb * spec.top_k)
    flat_slot = jnp.where(keep, slot, cap).reshape(-1)        # cap = drop bin
    dispatched = jnp.zeros((e, nb, cap + 1, d), xt.dtype)
    src = jnp.repeat(xt[:, None, :], spec.top_k, axis=1).reshape(-1, d)
    dispatched = dispatched.at[flat_e, flat_b, flat_slot].add(
        src, mode="drop")
    # NOTE: forcing P('model','data',·,·) here makes GSPMD reshard the giant
    # src instead (16× more traffic — §Perf B3, refuted).  The real fix is a
    # shard_map all-to-all token exchange; left as the documented next step.
    dispatched = dispatched[:, :, :cap]                       # (E, NB, C, D)

    # ---- batched expert MLP (E shards on "model", NB on data) ------------
    h_gate = jnp.einsum("ebcd,edf->ebcf", dispatched, params["w_gate"],
                        preferred_element_type=jnp.float32).astype(xt.dtype)
    h_up = jnp.einsum("ebcd,edf->ebcf", dispatched, params["w_up"],
                      preferred_element_type=jnp.float32).astype(xt.dtype)
    h = layers.activate(h_gate, spec.act) * h_up
    out_e = jnp.einsum("ebcf,efd->ebcd", h, params["w_down"],
                       preferred_element_type=jnp.float32).astype(xt.dtype)

    # ---- combine ----------------------------------------------------------
    gathered = out_e[flat_e, flat_b, jnp.clip(flat_slot, 0, cap - 1)]
    gathered = jnp.where(keep.reshape(-1, 1), gathered, 0)
    weights = (gate_vals * keep).reshape(-1, 1).astype(xt.dtype)
    combined = jnp.sum(
        (gathered * weights).reshape(t, spec.top_k, d), axis=1
    )

    if "shared" in params:
        sg = jax.nn.sigmoid(
            jnp.dot(xt, params["shared_gate"].astype(xt.dtype))
        ).astype(xt.dtype)
        combined = combined + sg * layers.mlp(params["shared"], xt, spec.act)

    # ---- load-balance aux loss (Switch-style) ------------------------------
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids[:, 0], e), axis=0) / t
    ) * e
    frac = jnp.mean(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=(0, 1))
    aux = spec.router_aux_weight * jnp.sum(frac * me) * e

    return combined.reshape(b, s, d), aux
