"""Mixture-of-Experts MLP with top-k routing, shared experts, EP sharding.

Covers the two assigned MoE archs:
  * qwen2-moe-a2.7b — 60 routed experts top-4 + shared expert (+ gate)
  * granite-moe-1b  — 32 routed experts top-8, no shared expert

Dispatch is capacity-based (scatter → batched expert einsum → combine) so the
expert dimension shards cleanly on the ``model`` axis (expert parallelism)
and HLO FLOPs reflect *active* experts, not a dense-all-experts product.
Experts are ceil-padded to the EP axis size; the router masks padding.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # moved out of experimental in newer jax
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover - version dependent
    from jax import shard_map

from repro.core import sod
from repro.models import layers

Params = dict[str, Any]


def _maybe_constrain(x: jax.Array, *axes):
    """Sharding constraint when tracing under a mesh; no-op otherwise.

    GSPMD won't propagate data-sharding through the computed-index dispatch
    scatter (it conservatively all-reduces the whole capacity buffer — §Perf
    B2, refuted); the explicit constraint pins E to the model axis and the
    block dim to the data axes (B3)."""
    try:
        from jax.interpreters import pxla
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh.empty:
            return x
        names = set(mesh.axis_names)
        fixed = []
        for a in axes:
            if a is None:
                fixed.append(None)
            elif a == "data":
                dp = tuple(n for n in ("pod", "data") if n in names)
                fixed.append(dp if len(dp) > 1 else (dp[0] if dp else None))
            else:
                fixed.append(a if a in names else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*fixed)))
    except Exception:
        return x


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int          # real experts
    n_experts_padded: int   # ceil-padded to EP axis
    top_k: int
    d_model: int
    d_ff: int               # per-expert hidden
    n_shared: int = 0       # shared experts (always-on)
    d_shared_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    act: str = "silu"
    # Rank/dispatch within this many contiguous token blocks.  When blocks
    # align with the data-parallel sharding of the token dim, the dispatch
    # scatter is shard-local — no capacity-buffer all-reduce over the data
    # axis (EXPERIMENTS.md §Perf B2).  1 = global dispatch.
    dispatch_blocks: int = 1
    # Mesh axis for the shard_map all-to-all token exchange (the §Perf B3
    # fix): tokens shard over (data axes × this axis), each shard ranks its
    # block locally and trades per-expert capacity buffers with its EP
    # peers — only routed tokens cross the links, never the full capacity
    # buffer.  None (default) keeps the GSPMD capacity-scatter dispatch;
    # the a2a path engages when a mesh with this axis is active and shapes
    # divide, and falls back silently otherwise.
    a2a_axis: str | None = None

    def capacity(self, n_tokens: int) -> int:
        c = int(n_tokens * self.top_k / max(self.n_experts, 1)
                * self.capacity_factor)
        return max((c + 127) // 128 * 128, 128)


def pad_experts(n_experts: int, ep_axis: int = 16) -> int:
    return (n_experts + ep_axis - 1) // ep_axis * ep_axis


def init_moe(key, spec: MoESpec, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 5)
    e, d, f = spec.n_experts_padded, spec.d_model, spec.d_ff

    def expert_init(k, d_in, d_out):
        scale = (1.0 / d_in) ** 0.5
        return (jax.random.normal(k, (e, d_in, d_out), jnp.float32) * scale).astype(dtype)

    params: Params = {
        "router": layers.dense_init(ks[0], d, e, jnp.float32),
        "w_gate": expert_init(ks[1], d, f),
        "w_up": expert_init(ks[2], d, f),
        "w_down": expert_init(ks[3], f, d),
    }
    if spec.n_shared:
        params["shared"] = layers.init_mlp(
            ks[4], d, spec.d_shared_ff or spec.d_ff * spec.n_shared, dtype
        )
        params["shared_gate"] = layers.dense_init(
            jax.random.fold_in(ks[4], 1), d, 1, jnp.float32
        )
    return params


def _rank_in_expert(assign: jax.Array, e: int) -> jax.Array:
    """First-come slot rank of each assignment within its expert.

    Stable argsort — O(N log N), no (T·K × E) one-hot, same first-come slot
    semantics as a running per-expert counter (§Perf B1).
    """
    order = jnp.argsort(assign, stable=True)
    sorted_e = assign[order]
    hist = jnp.zeros((e,), jnp.int32).at[assign].add(1)
    starts = jnp.cumsum(hist) - hist                          # (E,) tiny
    rank = jnp.arange(assign.shape[0], dtype=jnp.int32) - starts[sorted_e]
    return jnp.zeros_like(assign).at[order].set(rank)


def _a2a_dispatch(params: Params, xt: jax.Array, gate_vals: jax.Array,
                  expert_ids: jax.Array, spec: MoESpec):
    """shard_map all-to-all token exchange (the §Perf B3 fix), or None.

    Tokens shard over (data axes × ``spec.a2a_axis``); each shard ranks its
    contiguous block locally (same semantics as ``dispatch_blocks`` = the
    number of token shards), scatters its tokens into a per-expert capacity
    buffer, and ``all_to_all`` over the EP axis hands every expert owner
    exactly the routed tokens — the giant (E, NB, C, D) capacity buffer is
    never materialized globally and no GSPMD resharding of ``src`` happens.
    Expert weights stay resident sharded on the EP axis; their cotangents
    psum over the data axes via the shard_map transpose.
    """
    from repro.runtime import spmd  # deferred: models layer under runtime

    mesh = spmd.active_mesh()
    ep_ax = spec.a2a_axis
    if mesh is None or ep_ax not in mesh.axis_names or spmd.in_spmd_body():
        return None
    t, d = xt.shape
    e = spec.n_experts_padded
    ep = mesh.shape[ep_ax]
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if ep_ax in dp:
        return None                      # EP axis must be distinct from dp
    n_tok_shards = ep
    for a in dp:
        n_tok_shards *= mesh.shape[a]
    if e % ep or t % n_tok_shards:
        return None                      # shapes don't divide: fall back
    t_l = t // n_tok_shards
    cap = spec.capacity(t_l)
    k = spec.top_k
    e_per = e // ep
    tok_axes = dp + (ep_ax,)

    def body(xt_l, gate_l, eid_l, wg_l, wu_l, wd_l):
        assign = eid_l.reshape(-1)                       # (A,) A = t_l·K
        slot = _rank_in_expert(assign, e)
        keep = slot < cap
        # local per-expert capacity buffer, drop bin at cap
        src = jnp.repeat(xt_l[:, None, :], k, axis=1).reshape(-1, d)
        buf = jnp.zeros((e, cap + 1, d), xt_l.dtype)
        buf = buf.at[assign, jnp.where(keep, slot, cap)].add(src,
                                                             mode="drop")
        buf = buf[:, :cap]                               # (E, C, D)
        # trade expert slices: every EP peer receives, for its e_per local
        # experts, the capacity buffers of all ep sources
        recv = jax.lax.all_to_all(buf, ep_ax, split_axis=0, concat_axis=1,
                                  tiled=True)            # (E/ep, ep·C, D)
        h_gate = jnp.einsum("ecd,edf->ecf", recv, wg_l,
                            preferred_element_type=jnp.float32
                            ).astype(xt_l.dtype)
        h_up = jnp.einsum("ecd,edf->ecf", recv, wu_l,
                          preferred_element_type=jnp.float32
                          ).astype(xt_l.dtype)
        h = layers.activate(h_gate, spec.act) * h_up
        out_e = jnp.einsum("ecf,efd->ecd", h, wd_l,
                           preferred_element_type=jnp.float32
                           ).astype(xt_l.dtype)          # (E/ep, ep·C, D)
        # route results back to their source shards
        back = jax.lax.all_to_all(out_e, ep_ax, split_axis=1, concat_axis=0,
                                  tiled=True)            # (E, C, D)
        gathered = back[assign, jnp.clip(slot, 0, cap - 1)]
        gathered = jnp.where(keep[:, None], gathered, 0)
        weights = (gate_l.reshape(-1) * keep).astype(xt_l.dtype)
        return jnp.sum((gathered * weights[:, None]).reshape(t_l, k, d),
                       axis=1)

    tok_spec = P(tok_axes, None)
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(tok_spec, tok_spec, tok_spec,
                  P(ep_ax, None, None), P(ep_ax, None, None),
                  P(ep_ax, None, None)),
        out_specs=tok_spec,
        check_rep=False)
    return fn(xt, gate_vals, expert_ids,
              params["w_gate"], params["w_up"], params["w_down"])


def moe_mlp(params: Params, x: jax.Array, spec: MoESpec, plans=None):
    """x (B, S, D) → (B, S, D), plus router aux loss.

    ``plans`` maps the *shared* expert's projection names to their
    :class:`repro.core.plan.PackPlan` (routed experts are stacked on the EP
    dim and dispatch through the batched einsum path, which plans don't
    cover).
    """
    b, s, d = x.shape
    t = b * s
    e = spec.n_experts_padded
    xt = x.reshape(t, d)

    logits = jnp.dot(xt, params["router"].astype(xt.dtype),
                     preferred_element_type=jnp.float32)
    if spec.n_experts_padded > spec.n_experts:
        pad_mask = jnp.arange(spec.n_experts_padded) >= spec.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, spec.top_k)  # (T, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    combined = None
    if spec.a2a_axis is not None:
        combined = _a2a_dispatch(params, xt, gate_vals, expert_ids, spec)

    if combined is None:
        # ---- capacity-based dispatch (block-local, sort-based ranking) ----
        # B1: rank assignments within their expert via a stable argsort.
        # B2: ranking/scatter happen independently per token *block*; blocks
        #     align with the data sharding so the dispatch scatter is local.
        nb = spec.dispatch_blocks if t % spec.dispatch_blocks == 0 else 1
        tb = t // nb
        cap = spec.capacity(tb)
        a_blk = expert_ids.reshape(nb, tb * spec.top_k)       # (NB, A)
        slot = jax.vmap(lambda a: _rank_in_expert(a, e))(a_blk) \
            .reshape(t, spec.top_k)
        keep = slot < cap
        # scatter tokens into (E, NB, C, D); NB rides the token sharding
        flat_e = expert_ids.reshape(-1)
        flat_b = jnp.repeat(jnp.arange(nb, dtype=jnp.int32), tb * spec.top_k)
        flat_slot = jnp.where(keep, slot, cap).reshape(-1)    # cap = drop bin
        dispatched = jnp.zeros((e, nb, cap + 1, d), xt.dtype)
        src = jnp.repeat(xt[:, None, :], spec.top_k, axis=1).reshape(-1, d)
        dispatched = dispatched.at[flat_e, flat_b, flat_slot].add(
            src, mode="drop")
        # NOTE: forcing P('model','data',·,·) here makes GSPMD reshard the
        # giant src instead (16× more traffic — §Perf B3, refuted).  The
        # real fix is the shard_map all-to-all exchange above
        # (spec.a2a_axis); this path remains for meshless runs and
        # non-dividing shapes.
        dispatched = dispatched[:, :, :cap]                   # (E, NB, C, D)

        # ---- batched expert MLP (E shards on "model", NB on data) --------
        h_gate = jnp.einsum("ebcd,edf->ebcf", dispatched, params["w_gate"],
                            preferred_element_type=jnp.float32
                            ).astype(xt.dtype)
        h_up = jnp.einsum("ebcd,edf->ebcf", dispatched, params["w_up"],
                          preferred_element_type=jnp.float32).astype(xt.dtype)
        h = layers.activate(h_gate, spec.act) * h_up
        out_e = jnp.einsum("ebcf,efd->ebcd", h, params["w_down"],
                           preferred_element_type=jnp.float32
                           ).astype(xt.dtype)

        # ---- combine ------------------------------------------------------
        gathered = out_e[flat_e, flat_b, jnp.clip(flat_slot, 0, cap - 1)]
        gathered = jnp.where(keep.reshape(-1, 1), gathered, 0)
        weights = (gate_vals * keep).reshape(-1, 1).astype(xt.dtype)
        combined = jnp.sum(
            (gathered * weights).reshape(t, spec.top_k, d), axis=1
        )

    if "shared" in params:
        sg = jax.nn.sigmoid(
            jnp.dot(xt, params["shared_gate"].astype(xt.dtype))
        ).astype(xt.dtype)
        combined = combined + sg * layers.mlp(params["shared"], xt, spec.act,
                                              plans=plans)

    # ---- load-balance aux loss (Switch-style) ------------------------------
    me = jnp.mean(probs, axis=0)                                   # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_ids[:, 0], e), axis=0) / t
    ) * e
    frac = jnp.mean(jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=(0, 1))
    aux = spec.router_aux_weight * jnp.sum(frac * me) * e

    return combined.reshape(b, s, d), aux
