"""Shared building blocks: norms, RoPE, embeddings, MLPs — all SoD-aware.

Every weight matmul goes through :func:`repro.core.sod.apply`, so a layer
whose parameter leaf is a packed container (TiledCSC / BlockCSR) transparently
runs the Sparse-on-Dense datapath; dense leaves bypass decompression.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import sod

Params = dict[str, Any]


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jax.Array:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dtype)


def init_rms_norm(d: int, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros((d,), dtype)   # gamma stored as (1 + g)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                              # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations / softcap
# ---------------------------------------------------------------------------
def activate(x: jax.Array, kind: str) -> jax.Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if kind == "relu":
        return jax.nn.relu(x)
    raise ValueError(f"unknown activation {kind!r}")


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# gated MLP (SwiGLU family)
# ---------------------------------------------------------------------------
def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(params: Params, x: jax.Array, act: str = "silu",
        spmd="auto", plans=None) -> jax.Array:
    """SwiGLU MLP.  ``spmd`` forwards to the packed-matmul dispatcher: under
    an active mesh the packed projections run shard_map-wrapped (an explicit
    :class:`repro.runtime.spmd.SpmdPlan` pins the partitioning; ``None``
    opts out).  ``plans`` maps projection names (``w_gate``/``w_up``/
    ``w_down``) to their :class:`repro.core.plan.PackPlan`, so each matmul
    dispatches with its layer's plan — absent entries fall back to the
    active :class:`~repro.core.plan.ModelPlan`'s layout lookup."""
    pl = (plans or {}).get
    gate = sod.apply(x, params["w_gate"], spmd=spmd, plan=pl("w_gate"))
    up = sod.apply(x, params["w_up"], spmd=spmd, plan=pl("w_up"))
    return sod.apply(activate(gate, act) * up, params["w_down"], spmd=spmd,
                     plan=pl("w_down"))


# ---------------------------------------------------------------------------
# embedding / LM head
# ---------------------------------------------------------------------------
def embed(table: jax.Array, tokens: jax.Array, scale: bool = False) -> jax.Array:
    x = jnp.take(table, tokens, axis=0)
    if scale:  # gemma-style sqrt(d) embedding scale
        x = x * jnp.asarray(x.shape[-1] ** 0.5, x.dtype)
    return x


def lm_head(x: jax.Array, table_or_w, tied: bool, cap: float | None = None,
            spmd="auto", plan=None):
    """Project to vocab logits in float32 (loss numerics).  ``plan`` is the
    head's :class:`repro.core.plan.PackPlan` (or None for active-plan /
    layout fallback)."""
    if tied:
        w = table_or_w.T if isinstance(table_or_w, jax.Array) else table_or_w
        logits = jnp.dot(x, w, preferred_element_type=jnp.float32)
    else:
        logits = sod.apply(x, table_or_w, out_dtype=jnp.float32, spmd=spmd,
                           plan=plan)
    return softcap(logits.astype(jnp.float32), cap)


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None):
    """Mean token cross-entropy; logits (..., V) f32, targets (...) int."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
