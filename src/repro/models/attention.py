"""GQA attention: chunked (flash-style) training/prefill + cached decode.

Supports the assigned-architecture feature set: grouped KV heads, local
(sliding-window) vs global layers (gemma-2 alternation), attention logit
soft-capping, RoPE, and arbitrary-position cached decoding.

The full-sequence path is chunked with an online-softmax scan over KV blocks
(O(S) memory — required for the 32k prefill cells).  Sliding-window layers
scan only the ``window//chunk + 1`` KV blocks that can intersect the window
(O(S·W) compute instead of O(S²)).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import sod
from repro.models import layers

Params = dict[str, Any]

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Shape/behaviour spec for one attention layer: head geometry, RoPE
    base, logit scaling/soft-capping, and the flash-chunk sizes."""

    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    scale: float | None = None      # default 1/sqrt(head_dim)
    softcap: float | None = None
    chunk_q: int = 512
    chunk_k: int = 512

    @property
    def q_scale(self) -> float:
        """Query scaling applied to logits (``scale`` or 1/sqrt(hd))."""
        return self.scale if self.scale is not None else self.head_dim**-0.5


def init_attention(key, d_model: int, spec: AttnSpec, dtype=jnp.bfloat16) -> Params:
    """Initialize the q/k/v/o projection weights for one attention layer."""
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": layers.dense_init(kq, d_model, spec.n_heads * spec.head_dim, dtype),
        "wk": layers.dense_init(kk, d_model, spec.n_kv_heads * spec.head_dim, dtype),
        "wv": layers.dense_init(kv, d_model, spec.n_kv_heads * spec.head_dim, dtype),
        "wo": layers.dense_init(ko, spec.n_heads * spec.head_dim, d_model, dtype),
    }


def _project_qkv(params: Params, x: jax.Array, spec: AttnSpec,
                 positions: jax.Array):
    b, s, _ = x.shape
    q = sod.apply(x, params["wq"]).reshape(b, s, spec.n_heads, spec.head_dim)
    k = sod.apply(x, params["wk"]).reshape(b, s, spec.n_kv_heads, spec.head_dim)
    v = sod.apply(x, params["wv"]).reshape(b, s, spec.n_kv_heads, spec.head_dim)
    q = layers.apply_rope(q, positions, spec.rope_theta)
    k = layers.apply_rope(k, positions, spec.rope_theta)
    return q, k, v


def _block_scores(q, k, spec: AttnSpec):
    """q (B,Cq,KV,G,hd) × k (B,Ck,KV,hd) → (B,KV,G,Cq,Ck) float32."""
    s = jnp.einsum(
        "bqkgh,bckh->bkgqc", q, k, preferred_element_type=jnp.float32
    )
    s = s * spec.q_scale
    if spec.softcap is not None:
        s = spec.softcap * jnp.tanh(s / spec.softcap)
    return s


def _online_block(carry, scores, v_blk, mask):
    """One online-softmax update.  scores (B,KV,G,Cq,Ck) f32."""
    m_prev, l_prev, acc_prev = carry
    scores = jnp.where(mask, scores, NEG_INF)
    m_blk = jnp.max(scores, axis=-1)
    m_new = jnp.maximum(m_prev, m_blk)
    # guard fully-masked rows
    safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
    p = jnp.exp(scores - safe_m[..., None])
    p = jnp.where(mask, p, 0.0)
    corr = jnp.exp(jnp.where(jnp.isfinite(m_prev), m_prev - safe_m, NEG_INF))
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum(
        "bkgqc,bckh->bkgqh", p.astype(v_blk.dtype), v_blk,
        preferred_element_type=jnp.float32,
    )
    acc_new = acc_prev * corr[..., None] + pv
    return m_new, l_new, acc_new


def chunked_attention(
    q: jax.Array,             # (B, S, H, hd)
    k: jax.Array,             # (B, S, KV, hd)
    v: jax.Array,             # (B, S, KV, hd)
    spec: AttnSpec,
    window: int | None = None,
) -> jax.Array:
    """Causal (optionally sliding-window) attention, O(S) memory."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    cq = min(spec.chunk_q, s)
    ck = min(spec.chunk_k, s)
    if s % cq or s % ck:
        raise ValueError(f"seq {s} not divisible by chunks ({cq},{ck})")
    nq, nk = s // cq, s // ck
    qc = q.reshape(b, nq, cq, kvh, g, hd)

    if window is not None:
        # only blocks intersecting [q_start - window, q_end] matter
        n_rel = (window + cq) // ck + 1
    else:
        n_rel = None

    def q_chunk_body(i):
        qi = qc[:, i]
        q_pos = i * cq + jnp.arange(cq)

        def kv_step(carry, c):
            if window is not None:
                raw = i * cq + cq - (n_rel - c) * ck
                start = jnp.clip(raw, 0, s - ck)
            else:
                raw = start = c * ck
            k_blk = jax.lax.dynamic_slice(k, (0, start, 0, 0), (b, ck, kvh, hd))
            v_blk = jax.lax.dynamic_slice(v, (0, start, 0, 0), (b, ck, kvh, hd))
            k_pos = start + jnp.arange(ck)
            mask = k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                mask &= k_pos[None, :] > q_pos[:, None] - window
                # clipping can re-slice keys a neighbouring step also covers;
                # only this step's raw range [raw, raw+ck) may contribute
                in_range = (k_pos >= raw) & (k_pos < raw + ck)
                mask &= in_range[None, :]
            mask = mask[None, None, None]  # (1,1,1,Cq,Ck)
            scores = _block_scores(qi, k_blk, spec)
            return _online_block(carry, scores, v_blk, mask), None

        init = (
            jnp.full((b, kvh, g, cq), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, cq), jnp.float32),
            jnp.zeros((b, kvh, g, cq, hd), jnp.float32),
        )
        n_steps = n_rel if window is not None else nk
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(n_steps))
        out = acc / jnp.maximum(l, 1e-30)[..., None]        # (B,KV,G,Cq,hd)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, cq, h, hd)

    out = jax.lax.map(q_chunk_body, jnp.arange(nq))
    # (nq, B, Cq, H, hd) → (B, S, H, hd)
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def full_attention(
    params: Params,
    x: jax.Array,
    spec: AttnSpec,
    positions: jax.Array,
    window: int | None = None,
) -> jax.Array:
    """Training / prefill self-attention over a full sequence."""
    q, k, v = _project_qkv(params, x, spec, positions)
    out = chunked_attention(q, k, v, spec, window=window)
    b, s = x.shape[:2]
    return sod.apply(out.reshape(b, s, -1), params["wo"])


# ---------------------------------------------------------------------------
# cached decode
# ---------------------------------------------------------------------------
def init_cache(batch: int, max_len: int, spec: AttnSpec,
               dtype=jnp.bfloat16) -> Params:
    """Allocate a zeroed dense per-slot KV cache of ``max_len`` positions."""
    shape = (batch, max_len, spec.n_kv_heads, spec.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _decode_positions(pos: jax.Array, b: int) -> jax.Array:
    """(B, 1) RoPE positions from a scalar or per-sequence ``pos``."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        return jnp.full((b, 1), pos, jnp.int32)
    return pos.reshape(b, 1)


def _attend_cached(q, k_cache, v_cache, pos, spec: AttnSpec,
                   window: int | None):
    """One-token attention over a position-ordered KV cache.

    q (B,1,H,hd); caches (B,L,KV,hd); ``pos`` scalar or (B,).  Keys at
    positions beyond each row's ``pos`` (or outside its sliding window)
    are masked per row.
    """
    b = q.shape[0]
    s_max = k_cache.shape[1]
    kvh = spec.n_kv_heads
    g = spec.n_heads // kvh
    qh = q.reshape(b, 1, kvh, g, spec.head_dim)
    scores = _block_scores(qh, k_cache, spec)   # (B,KV,G,1,Smax)
    k_pos = jnp.arange(s_max)
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (b,))
    mask = k_pos[None, :] <= pos_b[:, None]
    if window is not None:
        mask &= k_pos[None, :] > pos_b[:, None] - window
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgqc,bckh->bqkgh", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, spec.n_heads * spec.head_dim)


def decode_attention(
    params: Params,
    x: jax.Array,             # (B, 1, D)
    cache: Params,
    pos: jax.Array,           # current position: scalar, or (B,) per row
    spec: AttnSpec,
    window: int | None = None,
):
    """One decode step: update cache at ``pos``, attend to the prefix.

    ``pos`` may be a scalar (every row at the same position — the static
    serve path) or a ``(B,)`` vector (ragged continuous batching: each
    row writes its new KV at its own position and gets its own causal /
    window mask).
    """
    b = x.shape[0]
    pos = jnp.asarray(pos)
    q, k_new, v_new = _project_qkv(params, x, spec, _decode_positions(pos, b))
    if pos.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice(
            cache["k"], k_new, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            cache["v"], v_new, (0, pos, 0, 0))
    else:
        upd = jax.vmap(
            lambda c, n, p: jax.lax.dynamic_update_slice(c, n, (p, 0, 0)))
        k_cache = upd(cache["k"], k_new, pos)
        v_cache = upd(cache["v"], v_new, pos)
    out = _attend_cached(q, k_cache, v_cache, pos, spec, window)
    out = out.astype(x.dtype)
    return sod.apply(out, params["wo"]), {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# paged decode (continuous-batching engine)
# ---------------------------------------------------------------------------
def init_paged_pool(n_pages: int, page_size: int, spec: AttnSpec,
                    dtype=jnp.bfloat16) -> Params:
    """A pool of fixed-size KV pages shared by all running sequences.

    Page 0 is conventionally the trash page: inactive engine slots point
    their whole block table at it, so their (ignored) writes never touch
    a live sequence.  The allocator in :mod:`repro.serving.pool` never
    hands it out.
    """
    shape = (n_pages, page_size, spec.n_kv_heads, spec.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def paged_prefill_attention(
    params: Params,
    x: jax.Array,              # (B, C, D) — one chunk of prompt tokens
    pool: Params,              # {"k","v"}: (n_pages, page, KV, hd)
    block_tables: jax.Array,   # (B, max_pages) page ids per logical block
    start: jax.Array,          # scalar: first position in this chunk
    valid_len: jax.Array,      # scalar: prompt length (pad cutoff)
    spec: AttnSpec,
    window: int | None = None,
):
    """Chunked-prefill attention: C prompt positions against the pool.

    The chunk covers positions ``[start, start + C)``; its KV is scattered
    into the pages named by each position's block-table entry (positions
    at or beyond ``valid_len`` — final-chunk padding — are redirected to
    the trash page so they can never dirty a live page), then the whole
    table is gathered back position-ordered and each query row attends
    under its own causal / sliding-window mask.

    Numerics mirror :func:`chunked_attention` exactly for prompts the
    reference computes in a single online-softmax block (``plen <=
    attn_chunk`` — the same regime the engine's page-bucketed full prefill
    already relies on): one :func:`_online_block` update over the gathered
    keys, where positions outside a row's mask contribute exact zeros.
    Rows are position-independent, so the chunk split itself never changes
    a token.
    """
    b, c, _ = x.shape
    start = jnp.asarray(start, jnp.int32)
    valid_len = jnp.asarray(valid_len, jnp.int32)
    idx = start + jnp.arange(c, dtype=jnp.int32)            # (C,)
    positions = jnp.broadcast_to(idx, (b, c))
    q, k_new, v_new = _project_qkv(params, x, spec, positions)
    page_size = pool["k"].shape[1]
    kvh = spec.n_kv_heads
    g = spec.n_heads // kvh
    hd = spec.head_dim

    page = jnp.take_along_axis(
        block_tables, jnp.broadcast_to(idx // page_size, (b, c)), axis=1)
    page = jnp.where((idx < valid_len)[None, :], page, 0)   # pad → trash
    off = jnp.broadcast_to(idx % page_size, (b, c))
    k_pool = pool["k"].at[page.reshape(-1), off.reshape(-1)].set(
        k_new.reshape(b * c, kvh, hd))
    v_pool = pool["v"].at[page.reshape(-1), off.reshape(-1)].set(
        v_new.reshape(b * c, kvh, hd))

    k_cache = k_pool[block_tables].reshape(b, -1, kvh, hd)
    v_cache = v_pool[block_tables].reshape(b, -1, kvh, hd)
    s_max = k_cache.shape[1]

    qh = q.reshape(b, c, kvh, g, hd)
    scores = _block_scores(qh, k_cache, spec)   # (B,KV,G,C,Smax)
    k_pos = jnp.arange(s_max)
    mask = k_pos[None, :] <= idx[:, None]
    if window is not None:
        mask &= k_pos[None, :] > idx[:, None] - window
    mask = mask[None, None, None]               # (1,1,1,C,Smax)
    init = (
        jnp.full((b, kvh, g, c), NEG_INF, jnp.float32),
        jnp.zeros((b, kvh, g, c), jnp.float32),
        jnp.zeros((b, kvh, g, c, hd), jnp.float32),
    )
    _, l, acc = _online_block(init, scores, v_cache, mask)
    out = acc / jnp.maximum(l, 1e-30)[..., None]            # (B,KV,G,C,hd)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, c, spec.n_heads * hd)
    out = out.astype(x.dtype)
    return out, {"k": k_pool, "v": v_pool}


def paged_verify_attention(
    params: Params,
    x: jax.Array,              # (B, C, D) — per-row speculative window
    pool: Params,              # {"k","v"}: (n_pages, page, KV, hd)
    block_tables: jax.Array,   # (B, max_pages) page ids per logical block
    start: jax.Array,          # (B,) first window position per row
    valid_len: jax.Array,      # (B,) per-row write cutoff (seq end)
    spec: AttnSpec,
    window: int | None = None,
):
    """Speculative-decoding verification: C positions per row, decode
    numerics.

    Row ``b`` scores window positions ``[start[b], start[b] + C)`` against
    its paged cache — the scatter/gather plumbing of
    :func:`paged_prefill_attention` (positions at or beyond ``valid_len[b]``
    redirect to the trash page so an over-long window can never dirty a
    live page) combined with the attention core of :func:`_attend_cached`
    generalized to C query rows.  That core choice is the whole point: the
    decode path normalizes scores with a float32 softmax *before* the
    bf16 value einsum, while the prefill path casts unnormalized
    online-softmax probabilities — so only this shape is bitwise identical
    to running :func:`paged_decode_attention` sequentially over the same
    tokens, which is what makes accepted speculative tokens exactly the
    greedy sequence.
    """
    b, c, _ = x.shape
    start = jnp.asarray(start, jnp.int32).reshape(b)
    valid_len = jnp.asarray(valid_len, jnp.int32).reshape(b)
    idx = start[:, None] + jnp.arange(c, dtype=jnp.int32)[None, :]  # (B,C)
    q, k_new, v_new = _project_qkv(params, x, spec, idx)
    page_size = pool["k"].shape[1]
    kvh = spec.n_kv_heads
    g = spec.n_heads // kvh
    hd = spec.head_dim

    page = jnp.take_along_axis(block_tables, idx // page_size, axis=1)
    page = jnp.where(idx < valid_len[:, None], page, 0)     # overflow → trash
    off = idx % page_size
    k_pool = pool["k"].at[page.reshape(-1), off.reshape(-1)].set(
        k_new.reshape(b * c, kvh, hd))
    v_pool = pool["v"].at[page.reshape(-1), off.reshape(-1)].set(
        v_new.reshape(b * c, kvh, hd))

    k_cache = k_pool[block_tables].reshape(b, -1, kvh, hd)
    v_cache = v_pool[block_tables].reshape(b, -1, kvh, hd)
    s_max = k_cache.shape[1]

    qh = q.reshape(b, c, kvh, g, hd)
    scores = _block_scores(qh, k_cache, spec)   # (B,KV,G,C,Smax)
    k_pos = jnp.arange(s_max)
    mask = k_pos[None, None, :] <= idx[:, :, None]          # (B,C,Smax)
    if window is not None:
        mask &= k_pos[None, None, :] > idx[:, :, None] - window
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgqc,bckh->bqkgh", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    out = out.reshape(b, c, spec.n_heads * hd).astype(x.dtype)
    return sod.apply(out, params["wo"]), {"k": k_pool, "v": v_pool}


def paged_decode_attention(
    params: Params,
    x: jax.Array,              # (B, 1, D)
    pool: Params,              # {"k","v"}: (n_pages, page, KV, hd)
    block_tables: jax.Array,   # (B, max_pages) page ids per logical block
    pos: jax.Array,            # (B,) per-sequence positions
    spec: AttnSpec,
    window: int | None = None,
    valid_len: jax.Array | None = None,
):
    """One decode step against the paged KV pool.

    Row ``b``'s logical position ``p`` lives in page
    ``block_tables[b, p // page]`` at offset ``p % page``; the new token's
    KV is scattered there, then the row's pages are gathered back into
    position order and attended with the same per-row mask as the dense
    vector-``pos`` path — so paged and dense decode are exactly
    interchangeable for equal cache contents.

    ``valid_len`` (optional, (B,)) is a per-row write cutoff: rows whose
    ``pos`` is at or beyond it redirect their KV write to the trash page.
    The engine uses it to run one batched step over a mix of decoding and
    prefilling/idle slots (cutoff 0) without copying block tables on the
    host, and to keep draft steps probing past a sequence's end from
    dirtying a live page.  Reads are unaffected — the attention mask
    already scopes each row to ``<= pos``.
    """
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, spec, _decode_positions(pos, b))
    page_size = pool["k"].shape[1]
    page = jnp.take_along_axis(
        block_tables, (pos // page_size)[:, None], axis=1)[:, 0]
    if valid_len is not None:
        valid_len = jnp.asarray(valid_len, jnp.int32).reshape(b)
        page = jnp.where(pos < valid_len, page, 0)      # overflow → trash
    off = pos % page_size
    k_pool = pool["k"].at[page, off].set(k_new[:, 0])
    v_pool = pool["v"].at[page, off].set(v_new[:, 0])
    # gather: (B, max_pages, page, KV, hd) → position-ordered (B, L, KV, hd)
    k_cache = k_pool[block_tables].reshape(
        b, -1, spec.n_kv_heads, spec.head_dim)
    v_cache = v_pool[block_tables].reshape(
        b, -1, spec.n_kv_heads, spec.head_dim)
    out = _attend_cached(q, k_cache, v_cache, pos, spec, window)
    out = out.astype(x.dtype)
    return sod.apply(out, params["wo"]), {"k": k_pool, "v": v_pool}
