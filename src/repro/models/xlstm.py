"""xLSTM blocks: mLSTM (matrix memory, chunked parallel form) + sLSTM.

mLSTM is a gated linear-attention cell with matrix state C (dk × dv per
head), exponential input gate and sigmoid forget gate, stabilized in log
space.  Training/prefill uses a chunkwise form (intra-chunk masked matmul +
inter-chunk state scan — same shape of computation as mamba2's SSD, so it
shares the MXU-friendliness).  Decode is the O(1) recurrence.

sLSTM has scalar memory with head-block-diagonal recurrence; it has no
parallel form (the paper's point), so training runs a ``lax.scan`` over time.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import sod
from repro.models import layers

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class XLSTMSpec:
    d_model: int
    n_heads: int = 4
    proj_factor: float = 2.0
    conv_width: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.n_heads


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(key, spec: XLSTMSpec, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 8)
    d, di, h = spec.d_model, spec.d_inner, spec.n_heads
    return {
        "w_up": layers.dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.conv_width, di), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": layers.dense_init(ks[2], di, di, dtype),
        "wk": layers.dense_init(ks[3], di, di, dtype),
        "wv": layers.dense_init(ks[4], di, di, dtype),
        "w_if": layers.dense_init(ks[5], di, 2 * h, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]),
        "norm": layers.init_rms_norm(di),
        "w_down": layers.dense_init(ks[6], di, d, dtype),
    }


def _mlstm_gates(u, params, h):
    gf = jnp.dot(u, params["w_if"].astype(u.dtype),
                 preferred_element_type=jnp.float32) + params["b_if"]
    li = gf[..., :h]                            # log input gate (unbounded)
    lf = jax.nn.log_sigmoid(gf[..., h:])        # log forget gate ≤ 0
    return li, lf


def mlstm_chunked(q, k, v, li, lf, chunk: int, state=None):
    """Chunkwise stabilized mLSTM.

    q,k,v: (B,S,H,dk/dv); li,lf: (B,S,H).  Returns y (B,S,H,dv), final state.
    """
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    lc = min(chunk, s)
    nc = s // lc
    q = q.reshape(b, nc, lc, h, dk) * (dk**-0.5)
    k = k.reshape(b, nc, lc, h, dk)
    v = v.reshape(b, nc, lc, h, dv)
    li = li.reshape(b, nc, lc, h)
    lf = lf.reshape(b, nc, lc, h)
    f_cum = jnp.cumsum(lf, axis=2)                          # F_i within chunk

    # log-weights D_ij = F_i - F_j + li_j (j ≤ i), stabilizer M_i
    d_j = li - f_cum                                         # li_j - F_j
    m_local = jax.lax.cummax(d_j, axis=2)                    # (B,NC,L,H)

    if state is None:
        c0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = state

    def chunk_step(carry, inp):
        c_st, n_st, m_st = carry
        qc, kc, vc, lic, fc, dj, ml = inp
        # stabilizer: m_i = F_i + max(M_i, m_state)
        m_i = fc + jnp.maximum(ml, m_st[:, None, :])         # (B,L,H)
        # intra-chunk
        dmat = fc[:, :, None, :] - fc[:, None, :, :] + lic[:, None, :, :]
        ii = jnp.arange(lc)
        causal = (ii[:, None] >= ii[None, :])[None, :, :, None]
        w = jnp.where(causal, jnp.exp(dmat - m_i[:, :, None, :]), 0.0)
        qk = jnp.einsum("blhd,bmhd->blmh", qc, kc,
                        preferred_element_type=jnp.float32)
        y_num = jnp.einsum("blmh,blmh,bmhv->blhv", qk, w,
                           vc.astype(jnp.float32))
        den = jnp.einsum("blmh,blmh->blh", qk, w)
        # inter-chunk
        scale = jnp.exp(fc + m_st[:, None, :] - m_i)          # (B,L,H)
        y_num += jnp.einsum("blhd,bhdv,blh->blhv", qc.astype(jnp.float32),
                            c_st, scale)
        den += jnp.einsum("blhd,bhd,blh->blh", qc.astype(jnp.float32),
                          n_st, scale)
        y = y_num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]
        # state update to chunk end
        f_l = fc[:, -1, :]                                    # (B,H)
        mx = ml[:, -1, :]
        m_new = f_l + jnp.maximum(mx, m_st)
        w_end = jnp.exp(f_l[:, None, :] - fc + lic - m_new[:, None, :])
        c_new = c_st * jnp.exp(f_l + m_st - m_new)[:, :, None, None] + \
            jnp.einsum("blhd,blhv,blh->bhdv", kc.astype(jnp.float32),
                       vc.astype(jnp.float32), w_end)
        n_new = n_st * jnp.exp(f_l + m_st - m_new)[:, :, None] + \
            jnp.einsum("blhd,blh->bhd", kc.astype(jnp.float32), w_end)
        return (c_new, n_new, m_new), y

    xs = tuple(
        t.transpose(1, 0, 2, 3, 4) if t.ndim == 5 else t.transpose(1, 0, 2, 3)
        for t in (q, k, v, li, f_cum, d_j, m_local)
    )
    (c_f, n_f, m_f), ys = jax.lax.scan(chunk_step, (c0, n0, m0), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)
    return y, (c_f, n_f, m_f)


def mlstm_block(params: Params, x: jax.Array, spec: XLSTMSpec,
                cache: Params | None = None, decode: bool = False):
    """Full mLSTM residual block.  x (B,S,D)."""
    b, s, _ = x.shape
    h, hd = spec.n_heads, spec.head_dim
    up = sod.apply(x, params["w_up"])
    u, g = jnp.split(up, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    uc, new_conv = _conv(u, params["conv_w"], params["conv_b"], conv_state)
    q = sod.apply(uc, params["wq"]).reshape(b, s, h, hd)
    k = sod.apply(uc, params["wk"]).reshape(b, s, h, hd)
    v = sod.apply(u, params["wv"]).reshape(b, s, h, hd)
    li, lf = _mlstm_gates(uc, params, h)
    if decode:
        state = (cache["c"], cache["n"], cache["m"])
        y, (c_f, n_f, m_f) = mlstm_chunked(q, k, v, li, lf, chunk=1,
                                           state=state)
        new_cache = {"c": c_f, "n": n_f, "m": m_f, "conv": new_conv}
    else:
        y, _ = mlstm_chunked(q, k, v, li, lf, chunk=spec.chunk)
        new_cache = None
    y = y.reshape(b, s, spec.d_inner).astype(x.dtype)
    y = layers.rms_norm(y, params["norm"])
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return sod.apply(y, params["w_down"]), new_cache


def init_mlstm_cache(batch: int, spec: XLSTMSpec, dtype=jnp.bfloat16) -> Params:
    h, hd = spec.n_heads, spec.head_dim
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, spec.conv_width - 1, spec.d_inner), dtype),
    }


def mlstm_cache_axes() -> Params:
    """Axis roles of :func:`init_mlstm_cache` leaves — O(1) matrix-memory
    state, batch at axis 0, no sequence axis."""
    from repro.models.cache import CacheAxes

    ax = CacheAxes(batch=0)
    return {"c": ax, "n": ax, "m": ax, "conv": ax}


def _conv(u, w, b, state=None):
    from repro.models.ssm import _causal_conv
    return _causal_conv(u, w, b, state)


# ---------------------------------------------------------------------------
# sLSTM — recurrent scan (no parallel form exists; the paper's point)
# ---------------------------------------------------------------------------
def init_slstm(key, spec: XLSTMSpec, dtype=jnp.bfloat16) -> Params:
    ks = jax.random.split(key, 4)
    d, h = spec.d_model, spec.n_heads
    hd = d // h
    return {
        "w_gates": layers.dense_init(ks[0], d, 4 * d, jnp.float32),
        "r_gates": (jax.random.normal(ks[1], (h, hd, 4 * hd), jnp.float32)
                    * hd**-0.5),
        "b_gates": jnp.concatenate(
            [jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)), jnp.zeros((d,))]
        ),
        "norm": layers.init_rms_norm(d),
        "w_out": layers.dense_init(ks[2], d, d, dtype),
    }


def slstm_scan(params: Params, x: jax.Array, spec: XLSTMSpec,
               state=None):
    """x (B,S,D) → (B,S,D); state = (h, c, n, m) each (B,H,hd)."""
    b, s, d = x.shape
    nh = spec.n_heads
    hd = d // nh
    wx = jnp.dot(x, params["w_gates"].astype(x.dtype),
                 preferred_element_type=jnp.float32)          # (B,S,4D)
    if state is None:
        zeros = jnp.zeros((b, nh, hd), jnp.float32)
        state = (zeros, zeros, zeros + 1e-6, zeros - 1e30)

    def step(carry, wx_t):
        h_prev, c_prev, n_prev, m_prev = carry
        rec = jnp.einsum("bhd,hde->bhe", h_prev, params["r_gates"])
        # layouts: wx_t (B, 4, H, hd); rec (B, H, 4*hd) → (B, 4, H, hd)
        gates = (
            wx_t.reshape(b, 4, nh, hd)
            + rec.reshape(b, nh, 4, hd).transpose(0, 2, 1, 3)
            + params["b_gates"].reshape(4, nh, hd)[None]
        )
        z, i_raw, f_raw, o_raw = gates[:, 0], gates[:, 1], gates[:, 2], gates[:, 3]
        z = jnp.tanh(z)
        lf = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(lf + m_prev, i_raw)
        i_g = jnp.exp(i_raw - m_new)
        f_g = jnp.exp(lf + m_prev - m_new)
        c_new = f_g * c_prev + i_g * z
        n_new = f_g * n_prev + i_g
        h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
        return (h_new, c_new, n_new, m_new), h_new

    state, hs = jax.lax.scan(step, state, wx.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    y = layers.rms_norm(y, params["norm"])
    return sod.apply(y, params["w_out"]), state


def init_slstm_cache(batch: int, spec: XLSTMSpec) -> tuple:
    nh = spec.n_heads
    hd = spec.d_model // nh
    zeros = jnp.zeros((batch, nh, hd), jnp.float32)
    return (zeros, zeros, zeros + 1e-6, zeros - 1e30)


def slstm_cache_axes() -> tuple:
    """Axis roles of :func:`init_slstm_cache` leaves (h, c, n, m)."""
    from repro.models.cache import CacheAxes

    return (CacheAxes(batch=0),) * 4
