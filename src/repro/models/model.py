"""Unified model API over the three assemblies + loss functions."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import cache as cache_mod
from repro.models import layers, transformer

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class LM:
    """Functional language model: init / apply / loss / prefill / decode."""

    cfg: ModelConfig

    # -- init ----------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        if cfg.family == "hybrid":
            return transformer.init_hybrid(key, cfg)
        if cfg.family == "ssm":
            return transformer.init_xlstm_lm(key, cfg)
        return transformer.init_transformer(key, cfg)

    # -- full-sequence forward ------------------------------------------------
    def apply(self, params: Params, batch: Params, want_cache: bool = False):
        cfg = self.cfg
        if cfg.family == "hybrid":
            return transformer.hybrid_forward(params, batch, cfg, want_cache)
        if cfg.family == "ssm":
            return transformer.xlstm_forward(params, batch, cfg, want_cache)
        return transformer.transformer_forward(params, batch, cfg, want_cache)

    def loss(self, params: Params, batch: Params):
        logits, aux, _ = self.apply(params, batch)
        targets = batch["targets"]
        mask = batch.get("loss_mask")
        if self.cfg.family == "vlm":
            # logits cover [patches + text]; loss only on the text suffix
            n_patch = logits.shape[1] - targets.shape[1]
            logits = logits[:, n_patch:]
        ce = layers.cross_entropy(logits, targets, mask)
        loss = ce + aux
        return loss, {"loss": loss, "ce": ce, "aux": jnp.asarray(aux)}

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> Params:
        cfg = self.cfg
        if cfg.family == "hybrid":
            return transformer.hybrid_init_cache(cfg, batch, max_len)
        if cfg.family == "ssm":
            return transformer.xlstm_init_cache(cfg, batch, max_len)
        return transformer.transformer_init_cache(cfg, batch, max_len)

    def prefill(self, params: Params, batch: Params):
        """Returns (last-token logits, cache).  Attention families only; the
        recurrent families rebuild state by stepping (see serve driver)."""
        logits, _, cache = self.apply(params, batch, want_cache=True)
        return logits[:, -1], cache

    def decode_step(self, params: Params, cache: Params, tokens: jax.Array,
                    pos: jax.Array):
        """tokens: (B, 1) (or (B, 1, C) audio).  Returns (logits, cache).

        ``pos`` is a scalar (all rows at the same position) or a ``(B,)``
        vector (ragged batches — each row decodes at its own position)."""
        cfg = self.cfg
        if cfg.family == "hybrid":
            return transformer.hybrid_decode(params, cache, tokens, pos, cfg)
        if cfg.family == "ssm":
            return transformer.xlstm_decode(params, cache, tokens, pos, cfg)
        return transformer.transformer_decode(params, cache, tokens, pos, cfg)

    # -- cache geometry (serving engine / serve driver) ------------------------
    def cache_spec(self) -> Params:
        """Structure-matched tree of :class:`repro.models.cache.CacheAxes`
        describing every decode-cache leaf's batch/sequence axes."""
        cfg = self.cfg
        if cfg.family == "hybrid":
            return transformer.hybrid_cache_spec(cfg)
        if cfg.family == "ssm":
            return transformer.xlstm_cache_spec(cfg)
        return transformer.transformer_cache_spec(cfg)

    def grow_cache(self, cache: Params, new_len: int) -> Params:
        """Explicit cache growth: zero-pad the *sequence* axes (and only
        those) out to ``new_len``.  Replaces the serve driver's old
        shape-matching heuristic, which mis-grew any leaf whose unrelated
        dim happened to equal the prompt length."""
        return cache_mod.grow_cache(cache, self.cache_spec(), new_len)

    # -- paged decode (continuous-batching engine) -----------------------------
    def init_paged_pool(self, n_pages: int, page_size: int) -> Params:
        if self.cfg.family in ("hybrid", "ssm"):
            raise ValueError(
                f"family {self.cfg.family!r} keeps O(1) recurrent state per "
                "slot; only attention-family KV caches are paged")
        return transformer.transformer_init_paged_pool(
            self.cfg, n_pages, page_size)

    def paged_decode_step(self, params: Params, pool: Params,
                          block_tables: jax.Array, tokens: jax.Array,
                          pos: jax.Array,
                          valid_len: jax.Array | None = None):
        """Ragged decode step over the paged KV pool: tokens (B, 1), pos
        (B,), block_tables (B, max_pages).  ``valid_len`` (optional, (B,))
        is a per-row write cutoff — rows at or beyond it redirect their KV
        write to the trash page.  Returns (logits, pool)."""
        if self.cfg.family in ("hybrid", "ssm"):
            raise ValueError(
                f"family {self.cfg.family!r} has no paged decode path")
        return transformer.transformer_decode_paged(
            params, pool, block_tables, tokens, pos, self.cfg,
            valid_len=valid_len)

    def prefill_chunk(self, params: Params, pool: Params,
                      block_tables: jax.Array, tokens: jax.Array,
                      start: jax.Array, valid_len: jax.Array):
        """Chunked prefill against the paged pool: tokens (B, C) covering
        prompt positions [start, start+C), zero-padded past ``valid_len``.
        Returns (logits for all C positions, pool)."""
        if self.cfg.family in ("hybrid", "ssm"):
            raise ValueError(
                f"family {self.cfg.family!r} has no paged prefill path — "
                "recurrent prompts replay through the decode step")
        return transformer.transformer_prefill_chunk(
            params, pool, block_tables, tokens, start, valid_len, self.cfg)

    def verify_chunk(self, params: Params, pool: Params,
                     block_tables: jax.Array, tokens: jax.Array,
                     start: jax.Array, valid_len: jax.Array):
        """Speculative-window verification: tokens (B, C) covering cache
        positions [start[b], start[b]+C) per row, writes clamped at
        valid_len[b].  Returns (logits for all C positions, pool); logits
        are bitwise what C sequential paged decode steps would produce."""
        if self.cfg.family in ("hybrid", "ssm"):
            raise ValueError(
                f"family {self.cfg.family!r} has no paged verify path — "
                "speculative decoding needs the paged-KV cache")
        return transformer.transformer_verify_chunk(
            params, pool, block_tables, tokens, start, valid_len, self.cfg)

    # -- info -------------------------------------------------------------------
    def param_count(self, params: Params | None = None) -> int:
        if params is None:
            return self.cfg.param_count()
        return sum(
            int(x.size) for x in jax.tree_util.tree_leaves(params)
            if hasattr(x, "size")
        )


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)
