"""Cache geometry: explicit per-leaf axis metadata for decode caches.

Every model family's decode cache is a pytree whose leaves carry a batch
axis (one row per running sequence) and, for attention KV leaves, a
sequence axis.  The serving layers need both pieces of information:

* the serve driver grows a prefill-sized cache out to the generation
  horizon (pad the *sequence* axes, nothing else — the old shape-matching
  heuristic in ``launch/serve.py`` silently mis-grew any leaf whose
  unrelated dim happened to equal the prompt length);
* the continuous-batching engine scatters one sequence's state into a
  *slot* of the batched cache when a request is admitted (write along the
  *batch* axis).

Each family publishes a spec tree mirroring its cache structure whose
leaves are :class:`CacheAxes` (``LM.cache_spec()``); the helpers here
consume it.  ``CacheAxes`` is deliberately NOT registered as a pytree so
``jax.tree_util.tree_map`` treats it as a leaf and the spec zips against
the cache tree.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class CacheAxes:
    """Axis roles of one cache leaf: ``batch`` is the slot axis, ``seq``
    the KV sequence axis (None for O(1) recurrent state)."""

    batch: int
    seq: int | None = None

    def shifted(self, lead: int) -> "CacheAxes":
        """The same leaf stacked under ``lead`` extra leading dims
        (layer-group stacking in the assemblies)."""
        return CacheAxes(
            self.batch + lead,
            None if self.seq is None else self.seq + lead,
        )


def shift_axes(spec_tree, lead: int):
    """Shift every :class:`CacheAxes` in a spec tree by ``lead`` leading
    dims — how per-cell specs compose into stacked family specs."""
    return jax.tree_util.tree_map(lambda ax: ax.shifted(lead), spec_tree)


def grow_cache(cache, spec_tree, new_len: int):
    """Zero-pad every sequence axis out to ``new_len`` (no-op for leaves
    already at least that long, and for seq-less recurrent state)."""

    def grow(t, ax: CacheAxes):
        if ax.seq is None or t.shape[ax.seq] >= new_len:
            return t
        pad = [(0, 0)] * t.ndim
        pad[ax.seq] = (0, new_len - t.shape[ax.seq])
        return jnp.pad(t, pad)

    return jax.tree_util.tree_map(grow, cache, spec_tree)


def write_slot(cache, sub, spec_tree, slot):
    """Scatter a single-sequence cache ``sub`` (batch size 1 on every
    batch axis) into row ``slot`` of the batched ``cache``.

    ``slot`` may be a traced scalar — the engine jits this once per cache
    structure and reuses it for every admission.  A ``sub`` leaf shorter
    than the cache on its sequence axis writes a prefix; the tail keeps
    whatever the slot held, which is safe because decode masks key
    positions beyond the sequence's ``pos`` and overwrites them in order.
    """

    def write(t, s, ax: CacheAxes):
        starts = [jnp.asarray(0)] * t.ndim
        starts[ax.batch] = jnp.asarray(slot)
        return jax.lax.dynamic_update_slice(
            t, s.astype(t.dtype), tuple(starts))

    return jax.tree_util.tree_map(write, cache, sub, spec_tree)
