"""Mamba2 (SSD) block — chunked matmul formulation, MXU-friendly.

State-space recurrence per head h with scalar decay A_h:
    S_t = exp(A_h·dt_t) · S_{t-1} + dt_t · B_t ⊗ x_t         (d_state × headdim)
    y_t = C_t · S_t + D_h · x_t
Training/prefill uses the chunked SSD form: intra-chunk contributions become
a (L_c × L_c) masked matmul, inter-chunk state is carried by a short
``lax.scan`` over chunks — O(S·L_c) compute, matmul-dominated (the reason
mamba2 maps well onto the MXU).  Decode keeps the O(1) recurrent state.

All projections run through ``sod.apply`` (Sparse-on-Dense applies to the
in/out projections; the scan itself has no weight matmul — DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import sod
from repro.models import layers

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_model: int
    d_state: int = 64
    expand: int = 2
    headdim: int = 64
    conv_width: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.d_state


def init_mamba(key, spec: MambaSpec, dtype=jnp.bfloat16) -> Params:
    """Projections are kept separate (w_z/w_x/w_b/w_c/w_dt) so the inner
    dimension (heads × headdim) shards cleanly on the TP axis; B/C/dt are
    small and replicate.  Depthwise convs are per-channel, so per-part convs
    are exactly equivalent to mamba2's conv over the concatenated channels.
    """
    ks = jax.random.split(key, 8)
    di, ds, nh = spec.d_inner, spec.d_state, spec.n_heads

    def conv_init(k, c):
        return (jax.random.normal(k, (spec.conv_width, c), jnp.float32)
                * 0.1).astype(dtype)

    return {
        "w_z": layers.dense_init(ks[0], spec.d_model, di, dtype),
        "w_x": layers.dense_init(ks[1], spec.d_model, di, dtype),
        "w_b": layers.dense_init(ks[2], spec.d_model, ds, dtype),
        "w_c": layers.dense_init(ks[3], spec.d_model, ds, dtype),
        "w_dt": layers.dense_init(ks[4], spec.d_model, nh, dtype),
        "conv_x": conv_init(ks[5], di),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_b": conv_init(ks[6], ds),
        "conv_b_b": jnp.zeros((ds,), dtype),
        "conv_c": conv_init(ks[7], ds),
        "conv_c_b": jnp.zeros((ds,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(jax.random.fold_in(key, 9), (nh,),
                                       jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "norm": layers.init_rms_norm(di),
        "out_proj": layers.dense_init(ks[0], di, spec.d_model, dtype),
    }


def _project(params: Params, x: jax.Array, spec: MambaSpec,
             conv_states: Params | None):
    """Returns z, xh, b, c, dt_raw and new conv states."""
    z = sod.apply(x, params["w_z"])
    xh = sod.apply(x, params["w_x"])
    b = sod.apply(x, params["w_b"])
    c = sod.apply(x, params["w_c"])
    dt = sod.apply(x, params["w_dt"])
    st = conv_states or {}
    xh, sx = _causal_conv(xh, params["conv_x"], params["conv_x_b"],
                          st.get("x"))
    b, sb = _causal_conv(b, params["conv_b"], params["conv_b_b"], st.get("b"))
    c, sc = _causal_conv(c, params["conv_c"], params["conv_c_b"], st.get("c"))
    return z, xh, b, c, dt, {"x": sx, "b": sb, "c": sc}


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv along S.  u (B,S,C); w (W,C).  Returns y[, state]."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    else:
        pad = state
    up = jnp.concatenate([pad, u], axis=1)
    y = sum(
        up[:, i : i + u.shape[1]] * w[i][None, None, :] for i in range(width)
    )
    y = jax.nn.silu((y + b[None, None, :]).astype(jnp.float32)).astype(u.dtype)
    new_state = up[:, -(width - 1):] if width > 1 else pad
    return y, new_state


def mamba_forward(params: Params, x: jax.Array, spec: MambaSpec) -> jax.Array:
    """Full-sequence chunked SSD.  x (B, S, D) → (B, S, D)."""
    bsz, s, _ = x.shape
    lc = min(spec.chunk, s)
    if s % lc:
        raise ValueError(f"seq {s} not divisible by chunk {lc}")
    nc = s // lc
    nh, hd, ds = spec.n_heads, spec.headdim, spec.d_state

    z, xh, b, c, dt, _ = _project(params, x, spec, None)
    xh = xh.reshape(bsz, nc, lc, nh, hd)
    b = b.reshape(bsz, nc, lc, ds)
    c = c.reshape(bsz, nc, lc, ds)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + params["dt_bias"][None, None, :]
    ).reshape(bsz, nc, lc, nh)                                  # (B,NC,L,H)
    a = -jnp.exp(params["a_log"])                                # (H,)
    adt = a[None, None, None, :] * dt                            # decay logs ≤ 0
    alpha = jnp.cumsum(adt, axis=2)                              # (B,NC,L,H)

    # ---- intra-chunk: masked (L×L) matmul per head ------------------------
    cb = jnp.einsum("bnis,bnjs->bnij", c, b,
                    preferred_element_type=jnp.float32)          # (B,NC,L,L)
    decay = alpha[:, :, :, None, :] - alpha[:, :, None, :, :]    # (B,NC,L,L,H)
    ii = jnp.arange(lc)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    m = jnp.where(causal, jnp.exp(decay), 0.0) * cb[..., None]
    m = m * dt[:, :, None, :, :]                                 # × dt_j
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", m.astype(xh.dtype), xh,
                         preferred_element_type=jnp.float32)

    # ---- chunk-final states + inter-chunk scan ----------------------------
    seg = jnp.exp(alpha[:, :, -1:, :] - alpha)                   # exp(α_L - α_j)
    bx = jnp.einsum(
        "bnjs,bnjhp->bnhsp",
        b, xh * (dt * seg)[..., :, None].astype(xh.dtype),
        preferred_element_type=jnp.float32)                      # (B,NC,H,S,P)
    chunk_decay = jnp.exp(alpha[:, :, -1, :])                    # (B,NC,H)

    def chunk_step(state, inp):
        bx_c, dec_c, alpha_c, c_c = inp
        y_inter = jnp.einsum("bis,bhsp,bih->bihp", c_c, state,
                             jnp.exp(alpha_c),
                             preferred_element_type=jnp.float32)
        state = state * dec_c[:, :, None, None] + bx_c
        return state, y_inter

    state0 = jnp.zeros((bsz, nh, ds, hd), jnp.float32)
    xs = (
        bx.transpose(1, 0, 2, 3, 4),
        chunk_decay.transpose(1, 0, 2),
        alpha.transpose(1, 0, 2, 3),
        c.transpose(1, 0, 2, 3),
    )
    _, y_inter = jax.lax.scan(chunk_step, state0, xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)                   # (B,NC,L,H,P)

    y = y_intra + y_inter
    y = y + params["d_skip"][None, None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, spec.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = layers.rms_norm(y, params["norm"])
    return sod.apply(y, params["out_proj"])


# ---------------------------------------------------------------------------
# decode (O(1) state)
# ---------------------------------------------------------------------------
def init_mamba_cache(batch: int, spec: MambaSpec, dtype=jnp.bfloat16) -> Params:
    w = spec.conv_width - 1
    return {
        "ssm": jnp.zeros((batch, spec.n_heads, spec.d_state, spec.headdim),
                         jnp.float32),
        "conv": {
            "x": jnp.zeros((batch, w, spec.d_inner), dtype),
            "b": jnp.zeros((batch, w, spec.d_state), dtype),
            "c": jnp.zeros((batch, w, spec.d_state), dtype),
        },
    }


def mamba_cache_axes() -> Params:
    """Axis roles of :func:`init_mamba_cache` leaves (structure-matched
    spec tree for :mod:`repro.models.cache`).  All mamba state is O(1) in
    sequence length — batch at axis 0, no sequence axis anywhere."""
    from repro.models.cache import CacheAxes

    ax = CacheAxes(batch=0)
    return {"ssm": ax, "conv": {"x": ax, "b": ax, "c": ax}}


def mamba_decode_step(params: Params, x: jax.Array, cache: Params,
                      spec: MambaSpec):
    """x (B, 1, D) → (B, 1, D); updates ssm/conv states."""
    bsz = x.shape[0]
    nh, hd, ds = spec.n_heads, spec.headdim, spec.d_state
    z, xh, b, c, dt, conv_state = _project(params, x, spec, cache["conv"])
    xh = xh.reshape(bsz, nh, hd)
    b = b.reshape(bsz, ds)
    c = c.reshape(bsz, ds)
    dt = jax.nn.softplus(
        dt.astype(jnp.float32)[:, 0] + params["dt_bias"][None, :]
    )                                                            # (B,H)
    a = -jnp.exp(params["a_log"])
    decay = jnp.exp(a[None, :] * dt)                             # (B,H)
    update = jnp.einsum("bs,bhp,bh->bhsp", b.astype(jnp.float32),
                        xh.astype(jnp.float32), dt)
    state = cache["ssm"] * decay[:, :, None, None] + update
    y = jnp.einsum("bs,bhsp->bhp", c.astype(jnp.float32), state)
    y = y + params["d_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, 1, spec.d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = layers.rms_norm(y, params["norm"])
    return sod.apply(y, params["out_proj"]), {"ssm": state, "conv": conv_state}
