"""Model assemblies for all assigned families.

Three assemblies share one external interface (see ``model.py``):

  * ``TransformerLM`` — dense / MoE / VLM-backbone / audio-backbone decoders.
    Layers are stacked in groups of ``len(layer_pattern)`` (gemma-2's
    local/global alternation becomes a group of two) and executed under
    ``jax.lax.scan`` so HLO size is depth-independent — required to keep 80
    dry-run compiles tractable and standard production practice.
  * ``HybridLM``  — zamba2: mamba2 stacks with a *shared* attention+MLP block
    applied every ``hybrid_attn_every`` layers.
  * ``XLSTMLM``   — groups of (slstm_every-1) mLSTM blocks + 1 sLSTM block.

Every weight matmul goes through ``sod.apply`` → Sparse-on-Dense everywhere.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import plan as plan_mod
from repro.core import sod
from repro.models import attention as attn
from repro.models import cache as cache_mod
from repro.models import layers, moe, ssm, xlstm

Params = dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _scan(body, init, xs, cfg: ModelConfig):
    """lax.scan over stacked layer groups, or an unrolled python loop when
    ``cfg.scan_layers`` is False (exact cost_analysis for the dry-run)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, init, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for g in range(n):
        carry, y = body(carry, jax.tree_util.tree_map(lambda t: t[g], xs))
        ys.append(y)
    if all(y is None for y in ys):
        return carry, None
    return carry, jax.tree_util.tree_map(lambda *t: jnp.stack(t), *ys)


def attn_spec(cfg: ModelConfig) -> attn.AttnSpec:
    return attn.AttnSpec(
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta,
        scale=cfg.attn_scale,
        softcap=cfg.attn_softcap,
        chunk_q=cfg.attn_chunk,
        chunk_k=cfg.attn_chunk,
    )


def moe_spec(cfg: ModelConfig) -> moe.MoESpec:
    return moe.MoESpec(
        n_experts=cfg.n_experts,
        n_experts_padded=moe.pad_experts(cfg.n_experts, cfg.ep_axis),
        top_k=cfg.top_k,
        d_model=cfg.d_model,
        d_ff=cfg.d_ff,
        n_shared=cfg.n_shared_experts,
        d_shared_ff=cfg.d_shared_ff,
        capacity_factor=cfg.capacity_factor,
        router_aux_weight=cfg.router_aux_weight,
        act=cfg.act,
        dispatch_blocks=cfg.moe_dispatch_blocks,
        a2a_axis=cfg.moe_a2a_axis,
    )


def mamba_spec(cfg: ModelConfig) -> ssm.MambaSpec:
    return ssm.MambaSpec(
        d_model=cfg.d_model,
        d_state=cfg.ssm_state,
        expand=cfg.ssm_expand,
        headdim=cfg.ssm_headdim,
        conv_width=cfg.ssm_conv,
        chunk=cfg.ssm_chunk,
    )


def xlstm_spec(cfg: ModelConfig) -> xlstm.XLSTMSpec:
    return xlstm.XLSTMSpec(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        proj_factor=cfg.xlstm_proj_factor,
        chunk=cfg.ssm_chunk,
    )


# ---------------------------------------------------------------------------
# attention + (mlp | moe) block
# ---------------------------------------------------------------------------
def init_attn_block(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    p: Params = {
        "norm1": layers.init_rms_norm(cfg.d_model),
        "norm2": layers.init_rms_norm(cfg.d_model),
        "attn": attn.init_attention(ks[0], cfg.d_model, attn_spec(cfg), dt),
    }
    if cfg.family == "moe":
        p["moe"] = moe.init_moe(ks[1], moe_spec(cfg), dt)
    else:
        p["mlp"] = layers.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dt)
    if cfg.use_post_norms:
        p["norm1_post"] = layers.init_rms_norm(cfg.d_model)
        p["norm2_post"] = layers.init_rms_norm(cfg.d_model)
    return p


def _apply_mlp(bp: Params, h: jax.Array, cfg: ModelConfig):
    # Per-layer pack plans: the active ModelPlan's entries for this block's
    # projections (layer stacks share one path, hence one plan entry).
    if cfg.family == "moe":
        return moe.moe_mlp(bp["moe"], h, moe_spec(cfg),
                           plans=plan_mod.active_subplans("shared"))
    return layers.mlp(bp["mlp"], h, cfg.act,
                      plans=plan_mod.active_subplans("mlp")), 0.0


def attn_block_full(bp: Params, x: jax.Array, cfg: ModelConfig,
                    positions: jax.Array, window: int | None,
                    want_kv: bool):
    """Full-sequence block.  Returns (x, (k, v) | None, aux_loss)."""
    spec = attn_spec(cfg)
    h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
    q, k, v = attn._project_qkv(bp["attn"], h, spec, positions)
    s = x.shape[1]
    eff_window = None if (window is None or window >= s) else window
    ao = attn.chunked_attention(q, k, v, spec, window=eff_window)
    ao = sod.apply(ao.reshape(*x.shape[:2], -1), bp["attn"]["wo"],
                   plan=plan_mod.active_entry("attn.wo"))
    if cfg.use_post_norms:
        ao = layers.rms_norm(ao, bp["norm1_post"], cfg.norm_eps)
    x = x + ao
    h2 = layers.rms_norm(x, bp["norm2"], cfg.norm_eps)
    mo, aux = _apply_mlp(bp, h2, cfg)
    if cfg.use_post_norms:
        mo = layers.rms_norm(mo, bp["norm2_post"], cfg.norm_eps)
    x = x + mo
    return x, ((k, v) if want_kv else None), aux


def attn_block_decode(bp: Params, x: jax.Array, cache: Params,
                      pos: jax.Array, cfg: ModelConfig,
                      window: int | None,
                      block_tables: jax.Array | None = None,
                      valid_len: jax.Array | None = None):
    """One decode block.  ``cache`` is a dense per-slot KV cache, or —
    when ``block_tables`` is given — this layer's slice of the paged KV
    pool (the engine's slot→page mapping).  ``valid_len`` (paged only)
    is the optional per-row write cutoff forwarded to
    :func:`repro.models.attention.paged_decode_attention`."""
    spec = attn_spec(cfg)
    h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
    if block_tables is None:
        ao, cache = attn.decode_attention(bp["attn"], h, cache, pos, spec,
                                          window=window)
    else:
        ao, cache = attn.paged_decode_attention(
            bp["attn"], h, cache, block_tables, pos, spec, window=window,
            valid_len=valid_len)
    if cfg.use_post_norms:
        ao = layers.rms_norm(ao, bp["norm1_post"], cfg.norm_eps)
    x = x + ao
    h2 = layers.rms_norm(x, bp["norm2"], cfg.norm_eps)
    mo, _ = _apply_mlp(bp, h2, cfg)
    if cfg.use_post_norms:
        mo = layers.rms_norm(mo, bp["norm2_post"], cfg.norm_eps)
    return x + mo, cache


def attn_block_verify(bp: Params, x: jax.Array, layer_pool: Params,
                      block_tables: jax.Array, start: jax.Array,
                      valid_len: jax.Array, cfg: ModelConfig,
                      window: int | None):
    """One block over a speculative verification window.

    Mirrors :func:`attn_block_decode`'s paged branch exactly (same norm /
    residual order, ``wo`` applied inside the attention call with the same
    dispatch) with the single-token attention replaced by
    :func:`repro.models.attention.paged_verify_attention` — row ``b``
    scores C window positions starting at ``start[b]`` instead of one.
    Every other op is position-row-independent, so verify logits for a
    window position are bitwise what the sequential decode step would
    produce there.
    """
    spec = attn_spec(cfg)
    h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
    ao, layer_pool = attn.paged_verify_attention(
        bp["attn"], h, layer_pool, block_tables, start, valid_len, spec,
        window=window)
    if cfg.use_post_norms:
        ao = layers.rms_norm(ao, bp["norm1_post"], cfg.norm_eps)
    x = x + ao
    h2 = layers.rms_norm(x, bp["norm2"], cfg.norm_eps)
    mo, _ = _apply_mlp(bp, h2, cfg)
    if cfg.use_post_norms:
        mo = layers.rms_norm(mo, bp["norm2_post"], cfg.norm_eps)
    return x + mo, layer_pool


def attn_block_prefill_chunk(bp: Params, x: jax.Array, layer_pool: Params,
                             block_tables: jax.Array, start: jax.Array,
                             valid_len: jax.Array, cfg: ModelConfig,
                             window: int | None):
    """One block over a prefill chunk against the paged KV pool.

    Mirrors :func:`attn_block_full` (same ``wo`` plan entry, same norm /
    residual order) with the full-sequence attention replaced by
    :func:`repro.models.attention.paged_prefill_attention`, so a prompt
    prefilled in chunks produces the same tokens as one fused prefill.
    """
    spec = attn_spec(cfg)
    h = layers.rms_norm(x, bp["norm1"], cfg.norm_eps)
    ao, layer_pool = attn.paged_prefill_attention(
        bp["attn"], h, layer_pool, block_tables, start, valid_len, spec,
        window=window)
    ao = sod.apply(ao, bp["attn"]["wo"],
                   plan=plan_mod.active_entry("attn.wo"))
    if cfg.use_post_norms:
        ao = layers.rms_norm(ao, bp["norm1_post"], cfg.norm_eps)
    x = x + ao
    h2 = layers.rms_norm(x, bp["norm2"], cfg.norm_eps)
    mo, _ = _apply_mlp(bp, h2, cfg)
    if cfg.use_post_norms:
        mo = layers.rms_norm(mo, bp["norm2_post"], cfg.norm_eps)
    return x + mo, layer_pool


# ---------------------------------------------------------------------------
# embedding / head / frontends
# ---------------------------------------------------------------------------
def init_embed_head(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    p: Params = {"final_norm": layers.init_rms_norm(cfg.d_model)}
    v = cfg.padded_vocab
    if cfg.family == "audio":
        p["embed"] = jax.vmap(
            lambda k: layers.embed_init(k, v, cfg.d_model, dt)
        )(jax.random.split(ks[0], cfg.n_codebooks))
        p["head"] = layers.dense_init(
            ks[1], cfg.d_model, cfg.n_codebooks * v, dt)
        return p
    p["embed"] = layers.embed_init(ks[0], v, cfg.d_model, dt)
    if not cfg.tie_embeddings:
        p["head"] = layers.dense_init(ks[1], cfg.d_model, v, dt)
    if cfg.family == "vlm":
        p["patch_proj"] = layers.dense_init(
            ks[2], cfg.frontend_dim, cfg.d_model, dt)
    return p


def embed_inputs(params: Params, batch: Params, cfg: ModelConfig) -> jax.Array:
    tokens = batch["tokens"]
    if cfg.family == "audio":
        # tokens (B, S, n_codebooks): sum of per-codebook embeddings
        x = sum(
            layers.embed(params["embed"][c], tokens[..., c])
            for c in range(cfg.n_codebooks)
        )
    else:
        x = layers.embed(params["embed"], tokens, scale=cfg.embed_scale)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        prefix = sod.apply(
            batch["patch_embeds"].astype(x.dtype), params["patch_proj"])
        x = jnp.concatenate([prefix, x], axis=1)
    return x


def project_logits(params: Params, x: jax.Array, cfg: ModelConfig):
    x = layers.rms_norm(x, params["final_norm"], cfg.norm_eps)
    v = cfg.padded_vocab
    head_plan = plan_mod.active_entry("head")
    if cfg.family == "audio":
        logits = sod.apply(x, params["head"], out_dtype=jnp.float32,
                           plan=head_plan)
        logits = logits.reshape(*x.shape[:-1], cfg.n_codebooks, v)
    elif cfg.tie_embeddings:
        logits = jnp.dot(x, params["embed"].T.astype(x.dtype),
                         preferred_element_type=jnp.float32)
    else:
        logits = sod.apply(x, params["head"], out_dtype=jnp.float32,
                           plan=head_plan)
    logits = layers.softcap(logits.astype(jnp.float32), cfg.final_softcap)
    if v != cfg.vocab:   # mask padded vocabulary slots
        mask = jnp.arange(v) >= cfg.vocab
        logits = jnp.where(mask, -1e30, logits)
    return logits


# ---------------------------------------------------------------------------
# TransformerLM (dense / moe / vlm / audio)
# ---------------------------------------------------------------------------
def init_transformer(key, cfg: ModelConfig) -> Params:
    p_period = cfg.pattern_period
    n_groups = cfg.n_layers // p_period
    ks = jax.random.split(key, 2)
    keys = jax.random.split(ks[0], cfg.n_layers).reshape(
        n_groups, p_period, -1)
    blocks = jax.vmap(jax.vmap(lambda k: init_attn_block(k, cfg)))(keys)
    params = init_embed_head(ks[1], cfg)
    params["blocks"] = blocks
    return params


def transformer_forward(params: Params, batch: Params, cfg: ModelConfig,
                        want_cache: bool = False):
    x = embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    p_period = cfg.pattern_period

    def group_body(carry, gp):
        x, aux = carry
        kvs = []
        for j in range(p_period):
            bp = jax.tree_util.tree_map(lambda t: t[j], gp)
            x, kv, a = attn_block_full(
                bp, x, cfg, positions, cfg.window_for(j), want_cache)
            aux = aux + a
            if want_cache:
                kvs.append(kv)
        if want_cache:
            ys = (
                jnp.stack([kv[0] for kv in kvs]),
                jnp.stack([kv[1] for kv in kvs]),
            )
        else:
            ys = None
        return (x, aux), ys

    body = group_body
    if cfg.remat and not want_cache:
        body = jax.checkpoint(group_body, prevent_cse=False)
    (x, aux), kv_stack = _scan(body, (x, 0.0), params["blocks"], cfg)
    logits = project_logits(params, x, cfg)
    cache = None
    if want_cache:
        cache = {"k": kv_stack[0], "v": kv_stack[1]}   # (G,P,B,S,KV,hd)
    return logits, aux, cache


def transformer_decode(params: Params, cache: Params, tokens: jax.Array,
                       pos: jax.Array, cfg: ModelConfig):
    x = embed_inputs(params, {"tokens": tokens}, cfg)
    p_period = cfg.pattern_period

    def group_body(x, inp):
        gp, kc, vc = inp
        ks, vs = [], []
        for j in range(p_period):
            bp = jax.tree_util.tree_map(lambda t: t[j], gp)
            slot = {"k": kc[j], "v": vc[j]}
            x, slot = attn_block_decode(bp, x, slot, pos, cfg,
                                        cfg.window_for(j))
            ks.append(slot["k"])
            vs.append(slot["v"])
        return x, (jnp.stack(ks), jnp.stack(vs))

    x, (knew, vnew) = _scan(
        group_body, x, (params["blocks"], cache["k"], cache["v"]), cfg)
    logits = project_logits(params, x, cfg)
    return logits, {"k": knew, "v": vnew}


def transformer_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    p_period = cfg.pattern_period
    n_groups = cfg.n_layers // p_period
    dt = _dtype(cfg)
    shape = (n_groups, p_period, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def transformer_cache_spec(cfg: ModelConfig) -> Params:
    """Axis roles of :func:`transformer_init_cache` / prefill KV leaves:
    (G, P, B, S, KV, hd) — batch 2, sequence 3."""
    ax = cache_mod.CacheAxes(batch=2, seq=3)
    return {"k": ax, "v": ax}


# ---------------------------------------------------------------------------
# paged decode (continuous-batching engine)
# ---------------------------------------------------------------------------
def transformer_init_paged_pool(cfg: ModelConfig, n_pages: int,
                                page_size: int) -> Params:
    """Per-layer KV page pools, stacked (G, P, n_pages, page, KV, hd).

    Every layer indexes its own pool with the *same* block tables — a
    sequence's logical block j lives at one page id across all layers, so
    the engine keeps a single (slots, max_pages) table.
    """
    p_period = cfg.pattern_period
    n_groups = cfg.n_layers // p_period
    dt = _dtype(cfg)
    shape = (n_groups, p_period, n_pages, page_size,
             cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}


def transformer_decode_paged(params: Params, pool: Params,
                             block_tables: jax.Array, tokens: jax.Array,
                             pos: jax.Array, cfg: ModelConfig,
                             valid_len: jax.Array | None = None):
    """One ragged decode step over the paged KV pool.

    ``pos`` is a (B,) vector — one position per engine slot.  Mirrors
    :func:`transformer_decode` with each layer's dense cache slice
    replaced by its page pool + the shared block tables.  ``valid_len``
    (optional, (B,)) gates each row's KV write: rows at or beyond their
    cutoff write to the trash page, letting one batched step cover a mix
    of decoding and prefilling/idle slots.
    """
    x = embed_inputs(params, {"tokens": tokens}, cfg)
    p_period = cfg.pattern_period

    def group_body(x, inp):
        gp, kp, vp = inp
        ks, vs = [], []
        for j in range(p_period):
            bp = jax.tree_util.tree_map(lambda t: t[j], gp)
            layer_pool = {"k": kp[j], "v": vp[j]}
            x, layer_pool = attn_block_decode(
                bp, x, layer_pool, pos, cfg, cfg.window_for(j),
                block_tables=block_tables, valid_len=valid_len)
            ks.append(layer_pool["k"])
            vs.append(layer_pool["v"])
        return x, (jnp.stack(ks), jnp.stack(vs))

    x, (knew, vnew) = _scan(
        group_body, x, (params["blocks"], pool["k"], pool["v"]), cfg)
    logits = project_logits(params, x, cfg)
    return logits, {"k": knew, "v": vnew}


def transformer_verify_chunk(params: Params, pool: Params,
                             block_tables: jax.Array, tokens: jax.Array,
                             start: jax.Array, valid_len: jax.Array,
                             cfg: ModelConfig):
    """Verify a speculative k-token window for every engine slot at once.

    ``tokens`` is (B, C) — row ``b`` holds its committed last token plus
    C-1 draft proposals, covering cache positions ``[start[b],
    start[b] + C)``; writes at or beyond ``valid_len[b]`` land in the
    trash page.  Mirrors :func:`transformer_decode_paged` with each
    single-token block swapped for :func:`attn_block_verify`, so logits
    row ``(b, i)`` is bitwise the sequential decode output at position
    ``start[b] + i`` given the fed window prefix — the property the
    engine's accept rule relies on.
    """
    x = embed_inputs(params, {"tokens": tokens}, cfg)
    p_period = cfg.pattern_period

    def group_body(x, inp):
        gp, kp, vp = inp
        ks, vs = [], []
        for j in range(p_period):
            bp = jax.tree_util.tree_map(lambda t: t[j], gp)
            layer_pool = {"k": kp[j], "v": vp[j]}
            x, layer_pool = attn_block_verify(
                bp, x, layer_pool, block_tables, start, valid_len, cfg,
                cfg.window_for(j))
            ks.append(layer_pool["k"])
            vs.append(layer_pool["v"])
        return x, (jnp.stack(ks), jnp.stack(vs))

    x, (knew, vnew) = _scan(
        group_body, x, (params["blocks"], pool["k"], pool["v"]), cfg)
    logits = project_logits(params, x, cfg)
    return logits, {"k": knew, "v": vnew}


def transformer_prefill_chunk(params: Params, pool: Params,
                              block_tables: jax.Array, tokens: jax.Array,
                              start: jax.Array, valid_len: jax.Array,
                              cfg: ModelConfig):
    """Prefill one fixed-size chunk of a prompt into the paged KV pool.

    ``tokens`` is (B, C) — the engine admits one sequence at a time, B=1 —
    covering prompt positions ``[start, start + C)``; the final chunk is
    zero-padded past ``valid_len`` (pad KV goes to the trash page).
    Returns (logits for all C positions, updated pool): the engine slices
    the last real prompt position's logits out on the host to get the
    sequence's first generated token.
    """
    x = embed_inputs(params, {"tokens": tokens}, cfg)
    p_period = cfg.pattern_period

    def group_body(x, inp):
        gp, kp, vp = inp
        ks, vs = [], []
        for j in range(p_period):
            bp = jax.tree_util.tree_map(lambda t: t[j], gp)
            layer_pool = {"k": kp[j], "v": vp[j]}
            x, layer_pool = attn_block_prefill_chunk(
                bp, x, layer_pool, block_tables, start, valid_len, cfg,
                cfg.window_for(j))
            ks.append(layer_pool["k"])
            vs.append(layer_pool["v"])
        return x, (jnp.stack(ks), jnp.stack(vs))

    x, (knew, vnew) = _scan(
        group_body, x, (params["blocks"], pool["k"], pool["v"]), cfg)
    logits = project_logits(params, x, cfg)
    return logits, {"k": knew, "v": vnew}


# ---------------------------------------------------------------------------
# HybridLM (zamba2): mamba stack + shared attention block
# ---------------------------------------------------------------------------
def init_hybrid(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    period = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // period
    mspec = mamba_spec(cfg)
    keys = jax.random.split(ks[0], cfg.n_layers).reshape(n_groups, period, -1)
    mamba_blocks = jax.vmap(jax.vmap(
        lambda k: {"norm": layers.init_rms_norm(cfg.d_model),
                   "mamba": ssm.init_mamba(k, mspec, _dtype(cfg))}
    ))(keys)
    params = init_embed_head(ks[1], cfg)
    params["mamba_blocks"] = mamba_blocks
    params["shared_attn"] = init_attn_block(ks[2], cfg)
    return params


def hybrid_forward(params: Params, batch: Params, cfg: ModelConfig,
                   want_cache: bool = False):
    x = embed_inputs(params, batch, cfg)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    mspec = mamba_spec(cfg)
    period = cfg.hybrid_attn_every

    def group_body(x, gp):
        for j in range(period):
            bp = jax.tree_util.tree_map(lambda t: t[j], gp)
            h = layers.rms_norm(x, bp["norm"], cfg.norm_eps)
            x = x + ssm.mamba_forward(bp["mamba"], h, mspec)
        x, kv, _ = attn_block_full(
            params["shared_attn"], x, cfg, positions, None, want_cache)
        return x, kv

    body = group_body
    if cfg.remat and not want_cache:
        body = jax.checkpoint(group_body, prevent_cse=False)
    x, kv_stack = _scan(body, x, params["mamba_blocks"], cfg)
    logits = project_logits(params, x, cfg)
    cache = None
    if want_cache:
        # NOTE: mamba states for continuation decode are rebuilt by the serve
        # path via a short state-prefill; attention cache is exact.
        cache = {"k": kv_stack[0], "v": kv_stack[1]}
    return logits, 0.0, cache


def hybrid_decode(params: Params, cache: Params, tokens: jax.Array,
                  pos: jax.Array, cfg: ModelConfig):
    x = embed_inputs(params, {"tokens": tokens}, cfg)
    mspec = mamba_spec(cfg)
    period = cfg.hybrid_attn_every

    def group_body(x, inp):
        gp, ssm_c, conv_c, kc, vc = inp
        new_ssm, new_conv = [], []
        for j in range(period):
            bp = jax.tree_util.tree_map(lambda t: t[j], gp)
            cj = jax.tree_util.tree_map(lambda t: t[j], conv_c)
            h = layers.rms_norm(x, bp["norm"], cfg.norm_eps)
            mo, mc = ssm.mamba_decode_step(
                bp["mamba"], h, {"ssm": ssm_c[j], "conv": cj}, mspec)
            x = x + mo
            new_ssm.append(mc["ssm"])
            new_conv.append(mc["conv"])
        slot = {"k": kc, "v": vc}
        x, slot = attn_block_decode(params["shared_attn"], x, slot, pos,
                                    cfg, None)
        new_conv = jax.tree_util.tree_map(lambda *t: jnp.stack(t), *new_conv)
        return x, (jnp.stack(new_ssm), new_conv, slot["k"], slot["v"])

    x, (ssm_n, conv_n, kn, vn) = _scan(
        group_body, x,
        (params["mamba_blocks"], cache["ssm"], cache["conv"],
         cache["k"], cache["v"]), cfg)
    logits = project_logits(params, x, cfg)
    return logits, {"ssm": ssm_n, "conv": conv_n, "k": kn, "v": vn}


def hybrid_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    period = cfg.hybrid_attn_every
    n_groups = cfg.n_layers // period
    mspec = mamba_spec(cfg)
    dt = _dtype(cfg)
    mcache = ssm.init_mamba_cache(batch, mspec, dt)
    return {
        "ssm": jnp.zeros((n_groups, period) + mcache["ssm"].shape,
                         jnp.float32),
        "conv": jax.tree_util.tree_map(
            lambda t: jnp.zeros((n_groups, period) + t.shape, t.dtype),
            mcache["conv"]),
        "k": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads,
                        cfg.head_dim), dt),
        "v": jnp.zeros((n_groups, batch, max_len, cfg.n_kv_heads,
                        cfg.head_dim), dt),
    }


def hybrid_cache_spec(cfg: ModelConfig) -> Params:
    """Axis roles of :func:`hybrid_init_cache`: mamba state stacked under
    (G, P) leading dims, shared-attn KV under (G,) — and crucially the
    mamba leaves have NO sequence axis, which is exactly what the old
    shape-matching growth heuristic got wrong when an unrelated dim
    happened to equal the prompt length."""
    m_axes = cache_mod.shift_axes(ssm.mamba_cache_axes(), 2)
    kv = cache_mod.CacheAxes(batch=1, seq=2)
    return {"ssm": m_axes["ssm"], "conv": m_axes["conv"], "k": kv, "v": kv}


# ---------------------------------------------------------------------------
# XLSTMLM: (slstm_every-1) mLSTM + 1 sLSTM per group
# ---------------------------------------------------------------------------
def init_xlstm_lm(key, cfg: ModelConfig) -> Params:
    xs = xlstm_spec(cfg)
    period = cfg.slstm_every or cfg.n_layers
    n_m = period - 1 if cfg.slstm_every else cfg.n_layers
    n_groups = cfg.n_layers // period
    ks = jax.random.split(key, 3)
    mkeys = jax.random.split(ks[0], n_groups * n_m).reshape(n_groups, n_m, -1)
    mlstm_blocks = jax.vmap(jax.vmap(
        lambda k: {"norm": layers.init_rms_norm(cfg.d_model),
                   "cell": xlstm.init_mlstm(k, xs, _dtype(cfg))}
    ))(mkeys)
    params = init_embed_head(ks[1], cfg)
    params["mlstm_blocks"] = mlstm_blocks
    if cfg.slstm_every:
        skeys = jax.random.split(ks[2], n_groups)
        params["slstm_blocks"] = jax.vmap(
            lambda k: {"norm": layers.init_rms_norm(cfg.d_model),
                       "cell": xlstm.init_slstm(k, xs, _dtype(cfg))}
        )(skeys)
    return params


def xlstm_forward(params: Params, batch: Params, cfg: ModelConfig,
                  want_cache: bool = False):
    x = embed_inputs(params, batch, cfg)
    xs_spec = xlstm_spec(cfg)
    has_s = "slstm_blocks" in params

    def group_body(x, gp):
        mgp = gp[0]
        n_m = jax.tree_util.tree_leaves(mgp)[0].shape[0]
        for j in range(n_m):
            bp = jax.tree_util.tree_map(lambda t: t[j], mgp)
            h = layers.rms_norm(x, bp["norm"], cfg.norm_eps)
            mo, _ = xlstm.mlstm_block(bp["cell"], h, xs_spec)
            x = x + mo
        if has_s:
            sp = gp[1]
            h = layers.rms_norm(x, sp["norm"], cfg.norm_eps)
            so, _ = xlstm.slstm_scan(sp["cell"], h, xs_spec)
            x = x + so
        return x, None

    scan_xs = (params["mlstm_blocks"],)
    if has_s:
        scan_xs = (params["mlstm_blocks"], params["slstm_blocks"])
    body = group_body
    if cfg.remat and not want_cache:
        body = jax.checkpoint(group_body, prevent_cse=False)
    x, _ = _scan(body, x, scan_xs, cfg)
    logits = project_logits(params, x, cfg)
    return logits, 0.0, None   # recurrent caches built by serve-path prefill


def xlstm_decode(params: Params, cache: Params, tokens: jax.Array,
                 pos: jax.Array, cfg: ModelConfig):
    x = embed_inputs(params, {"tokens": tokens}, cfg)
    xs_spec = xlstm_spec(cfg)
    has_s = "slstm_blocks" in params

    def group_body(x, inp):
        if has_s:
            mgp, sp, mcache, scache = inp
        else:
            (mgp, mcache) = inp
        n_m = jax.tree_util.tree_leaves(mgp)[0].shape[0]
        new_m = []
        for j in range(n_m):
            bp = jax.tree_util.tree_map(lambda t: t[j], mgp)
            mc = jax.tree_util.tree_map(lambda t: t[j], mcache)
            h = layers.rms_norm(x, bp["norm"], cfg.norm_eps)
            mo, mc = xlstm.mlstm_block(bp["cell"], h, xs_spec,
                                       cache=mc, decode=True)
            x = x + mo
            new_m.append(mc)
        new_m = jax.tree_util.tree_map(lambda *t: jnp.stack(t), *new_m)
        if has_s:
            h = layers.rms_norm(x, sp["norm"], cfg.norm_eps)
            so, s_new = xlstm.slstm_scan(sp["cell"], h, xs_spec, state=scache)
            x = x + so
            return x, (new_m, s_new)
        return x, (new_m,)

    if has_s:
        xs_in = (params["mlstm_blocks"], params["slstm_blocks"],
                 cache["mlstm"], cache["slstm"])
    else:
        xs_in = (params["mlstm_blocks"], cache["mlstm"])
    x, ys = _scan(group_body, x, xs_in, cfg)
    logits = project_logits(params, x, cfg)
    new_cache = {"mlstm": ys[0]}
    if has_s:
        new_cache["slstm"] = ys[1]
    return logits, new_cache


def xlstm_cache_spec(cfg: ModelConfig) -> Params:
    """Axis roles of :func:`xlstm_init_cache`: mLSTM state stacked under
    (G, n_m), sLSTM state under (G,); all O(1) in sequence length."""
    spec = {"mlstm": cache_mod.shift_axes(xlstm.mlstm_cache_axes(), 2)}
    if cfg.slstm_every:
        spec["slstm"] = cache_mod.shift_axes(xlstm.slstm_cache_axes(), 1)
    return spec


def xlstm_init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    xs = xlstm_spec(cfg)
    period = cfg.slstm_every or cfg.n_layers
    n_m = period - 1 if cfg.slstm_every else cfg.n_layers
    n_groups = cfg.n_layers // period
    mc = xlstm.init_mlstm_cache(batch, xs, _dtype(cfg))
    cache = {
        "mlstm": jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(
                t, (n_groups, n_m) + t.shape).copy(), mc)
    }
    if cfg.slstm_every:
        sc = xlstm.init_slstm_cache(batch, xs)
        cache["slstm"] = jax.tree_util.tree_map(
            lambda t: jnp.broadcast_to(t, (n_groups,) + t.shape).copy(), sc)
    return cache
