"""Sharded checkpointing: async save, elastic restore.

Layout per step::

    <dir>/step_<N>/MANIFEST.msgpack      # treedef paths, shapes, dtypes
    <dir>/step_<N>/<leaf-index>.npy      # one array per leaf
    <dir>/step_<N>/COMMITTED             # write-completion marker

Restore is *elastic*: arrays are loaded host-side and ``jax.device_put`` with
whatever shardings the (possibly different-sized) restore mesh dictates —
re-sharding from a 16-way data axis to 8-way survivors is just a different
NamedSharding at restore.  The COMMITTED marker makes partially-written
checkpoints invisible (a crashed save is re-done, never restored).

Async mode snapshots to host (``jax.device_get``) synchronously — the step
loop never blocks on disk — and writes in a daemon thread.
"""
from __future__ import annotations

import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

Params = Any
_COMMITTED = "COMMITTED"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [jax.tree_util.keystr(p) for p, _ in flat]
    leaves = [l for _, l in flat]
    return names, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str | pathlib.Path, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # -- save -----------------------------------------------------------------
    def save(self, step: int, state: Params, blocking: bool = True):
        self.wait()   # one writer at a time; drain pending async saves
        names, leaves, _ = _flatten(state)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        if blocking:
            self._write(step, names, host)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, names, host), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, names, host_leaves):
        path = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}_{os.getpid()}_{id(host_leaves):x}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = []
        for i, (name, arr) in enumerate(zip(names, host_leaves)):
            dtype = str(arr.dtype)
            if dtype == "bfloat16":   # npy round-trip via uint16 bit view
                arr = arr.view(np.uint16)
            np.save(tmp / f"{i}.npy", arr)
            manifest.append({"name": name, "index": i,
                             "shape": list(arr.shape), "dtype": dtype})
        (tmp / "MANIFEST.msgpack").write_bytes(
            msgpack.packb({"step": step, "leaves": manifest}))
        (tmp / _COMMITTED).touch()
        if path.exists():
            shutil.rmtree(path)
        tmp.rename(path)
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / _COMMITTED).exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: Params,
                shardings: Params | None = None) -> Params:
        """Restore into ``template``'s structure; ``shardings`` may target a
        *different* mesh than the one that saved (elastic re-shard)."""
        path = self.dir / f"step_{step:08d}"
        if not (path / _COMMITTED).exists():
            raise FileNotFoundError(f"no committed checkpoint at {path}")
        manifest = msgpack.unpackb((path / "MANIFEST.msgpack").read_bytes())
        names, leaves, treedef = _flatten(template)
        by_name = {m["name"]: m for m in manifest["leaves"]}
        sh_flat = None
        if shardings is not None:
            sh_flat = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "device_set")
                or hasattr(x, "mesh"))
        out = []
        for i, (name, tmpl) in enumerate(zip(names, leaves)):
            if name not in by_name:
                raise KeyError(f"checkpoint missing leaf {name}")
            meta = by_name[name]
            arr = np.load(path / f"{meta['index']}.npy")
            if meta["dtype"] == "bfloat16":
                import ml_dtypes

                arr = arr.view(ml_dtypes.bfloat16)
            want_dt = getattr(tmpl, "dtype", arr.dtype)
            x = jnp.asarray(arr, dtype=want_dt)
            if sh_flat is not None:
                x = jax.device_put(x, sh_flat[i])
            out.append(x)
        return jax.tree_util.tree_unflatten(treedef, out)
