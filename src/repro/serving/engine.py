"""Continuous-batching inference engine over Sparse-on-Dense weights.

One :class:`Engine` owns a fixed number of *slots* (rows of the batched
decode step) and admits/evicts requests every step, so sequences of
different lengths join and leave the running batch continuously — the
regime where the paper's compressed weight storage pays off most, since
decode is weight-bytes-bound and every slot shares the one packed copy.

Two cache regimes, chosen by model family:

* **paged** (attention families): per-layer KV page pools
  (:func:`repro.models.transformer.transformer_init_paged_pool`) with a
  host-side free-list allocator (:class:`repro.serving.pool.PagePool`) and
  one block table per slot.  Admission runs the fused prefill on a
  page-aligned prompt bucket (exact for causal attention — padded
  positions are masked at decode and overwritten in order) and scatters
  the KV into freshly allocated pages; decode runs
  :func:`repro.launch.steps.make_paged_decode_step` with per-slot ``pos``
  vectors; completion returns the pages to the pool.
* **slot state** (hybrid / ssm): O(1) recurrent state lives in a
  max_slots-batched cache; admission replays the prompt through the
  batch-1 decode step (exactly the static serve path) and scatters the
  final state into the slot via the explicit cache-axes API
  (:func:`repro.models.cache.write_slot`).

Greedy tokens are bit-identical to per-request static-batch serve
(:func:`static_generate`) because every per-row computation is
batch-row-independent and padding/masked positions contribute exact
zeros.  One documented exception: MoE capacity-factor routing is
batch-global, so under expert-capacity pressure an engine batch can drop
different tokens than a batch-1 run.

All jit-compiled shapes are fixed by (max_slots, pool size, block-table
width, prompt buckets), so steady-state serving never recompiles;
:meth:`Engine.warmup` pre-compiles everything for the queued trace and is
timed separately from steady-state throughput.
"""
from __future__ import annotations

import math
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as steps_mod
from repro.models import cache as cache_mod
from repro.models.model import LM
from repro.serving.pool import PagePool, PoolExhausted
from repro.serving.scheduler import Request, Scheduler, SeqState

Params = dict[str, Any]


def bucket_len(plen: int, page_size: int, chunk: int | None = None) -> int:
    """Page-aligned prefill bucket for a prompt of ``plen`` tokens.

    Rounds up to the page size so prompt KV fills whole pages; prompts
    longer than the attention chunk additionally round to a multiple of
    the chunk (``chunked_attention`` requires divisibility there).
    """
    b = -(-plen // page_size) * page_size
    if chunk and b > chunk:
        lcm = math.lcm(page_size, chunk)
        b = -(-plen // lcm) * lcm
    return b


def _pool_write_pages(pool: Params, cache: Params, page_ids):
    """Scatter a whole prefill's KV into pages ``page_ids`` of every
    layer's pool in one shot — page j of the bucketed prompt (positions
    [j·page, (j+1)·page)) lands in pool page ``page_ids[j]``.  One pool
    copy per admission instead of one per page."""
    page_size = pool["k"].shape[3]

    def write(pl, cl):
        # cl (G, P, 1, S, KV, hd), S = len(page_ids)·page
        g, p = cl.shape[0], cl.shape[1]
        pages = cl[:, :, 0].reshape(
            g, p, -1, page_size, cl.shape[-2], cl.shape[-1])
        return pl.at[:, :, page_ids].set(pages)

    return {"k": write(pool["k"], cache["k"]),
            "v": write(pool["v"], cache["v"])}


class Engine:
    """Continuous-batching engine: paged KV pool + request scheduler +
    ragged batched decode over one shared (optionally SoD-packed) model."""

    def __init__(self, model: LM, params: Params, *, max_slots: int = 4,
                 page_size: int = 16, max_len: int = 256,
                 n_pages: int | None = None, plan=None, mesh=None):
        cfg = model.cfg
        if cfg.family in ("vlm", "audio"):
            raise NotImplementedError(
                f"engine serves token-in/token-out families; {cfg.family!r} "
                "needs frontend plumbing (prefix embeds / codebook stacks)")
        self.model = model
        self.params = params
        self.plan = plan
        self.max_slots = int(max_slots)
        self.max_len = int(max_len)
        self.paged = cfg.family not in ("hybrid", "ssm")
        self.sched = Scheduler(max_slots)
        self._step_idx = 0
        self._submitted: list[Request] = []
        self._first_seen: dict[int, float] = {}
        self._finished: dict[int, SeqState] = {}
        self.stats: dict[str, float] = {"warmup_s": 0.0}
        self._pos = np.zeros(self.max_slots, np.int32)
        self._tok = np.zeros((self.max_slots, 1), np.int32)

        if self.paged:
            self.page_size = int(page_size)
            self._chunk = cfg.attn_chunk
            self.max_pages = -(-self.max_len // self.page_size)
            if n_pages is None:
                n_pages = 1 + self.max_slots * self.max_pages
            self.page_pool = PagePool(n_pages, self.page_size)
            self.pool = model.init_paged_pool(n_pages, self.page_size)
            self.block_tables = np.full(
                (self.max_slots, self.max_pages), PagePool.TRASH_PAGE,
                np.int32)
            self._decode = jax.jit(
                steps_mod.make_paged_decode_step(model, mesh=mesh, plan=plan))
            self._prefill = jax.jit(
                steps_mod.make_prefill_full(model, mesh=mesh, plan=plan))
            self._page_write = jax.jit(_pool_write_pages)
        else:
            self.cache = model.init_cache(self.max_slots, self.max_len)
            spec = model.cache_spec()
            self._decode = jax.jit(
                steps_mod.make_decode_step(model, mesh=mesh, plan=plan))
            self._write_slot = jax.jit(
                lambda c, sub, slot: cache_mod.write_slot(c, sub, spec, slot))

    # -- admission ------------------------------------------------------------
    def _bucket(self, plen: int) -> int:
        return bucket_len(plen, self.page_size, self._chunk)

    def submit(self, req: Request) -> None:
        plen = len(req.tokens)
        end = plen + req.max_new - 1          # last cache position + 1
        if self.paged:
            need = max(self._bucket(plen), end)
            pages = self.page_pool.pages_for(need)
            if need > self.max_len or pages > self.page_pool.n_pages - 1:
                raise ValueError(
                    f"request {req.rid}: needs {need} positions / {pages} "
                    f"pages; engine max_len={self.max_len}, pool="
                    f"{self.page_pool.n_pages}")
        elif end > self.max_len:
            raise ValueError(
                f"request {req.rid}: needs {end} positions; engine "
                f"max_len={self.max_len}")
        self._submitted.append(req)
        self.sched.submit(req)

    def _lifetime_pages(self, req: Request) -> int:
        """Worst-case pages the request will ever hold: its prefill
        bucket plus decode growth out to its last write position."""
        plen = len(req.tokens)
        need = max(self._bucket(plen), plen + req.max_new - 1)
        return self.page_pool.pages_for(need)

    def _reserved_pages(self) -> int:
        """Pages the *running* sequences may still claim via growth.
        Admission holds these back, so mid-decode growth can never find
        the pool empty (no preemption exists to recover from that)."""
        r = 0
        for seq in self.sched.active.values():
            end = seq.pos + seq.remaining        # last write position + 1
            r += max(0, self.page_pool.pages_for(end) - len(seq.pages))
        return r

    def _admit_paged(self, req: Request) -> list[tuple[int, int]]:
        plen = len(req.tokens)
        bucket = self._bucket(plen)
        padded = np.zeros(bucket, np.int32)
        padded[:plen] = req.tokens
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(padded)[None]})
        first = int(jnp.argmax(logits[0, plen - 1]))
        pages = self.page_pool.alloc(self.page_pool.pages_for(bucket))
        self.pool = self._page_write(
            self.pool, cache, jnp.asarray(np.asarray(pages, np.int32)))
        seq = self.sched.place(req, pos=plen, first_token=first, pages=pages,
                               ready_wall=self._first_seen[req.rid])
        self.block_tables[seq.slot, :] = PagePool.TRASH_PAGE
        self.block_tables[seq.slot, :len(pages)] = pages
        return self._post_admit(seq)

    def _admit_state(self, req: Request) -> list[tuple[int, int]]:
        prompt = jnp.asarray(req.tokens, jnp.int32)[None]
        sub = self.model.init_cache(1, self.max_len)
        nxt = None
        for t in range(prompt.shape[1]):
            nxt, _, sub = self._decode(
                self.params, sub, prompt[:, t:t + 1],
                jnp.asarray(t, jnp.int32))
        first = int(np.asarray(nxt).reshape(-1)[0])
        seq = self.sched.place(req, pos=prompt.shape[1], first_token=first,
                               pages=[],
                               ready_wall=self._first_seen[req.rid])
        self.cache = self._write_slot(self.cache, sub,
                                      jnp.asarray(seq.slot))
        return self._post_admit(seq)

    def _post_admit(self, seq: SeqState) -> list[tuple[int, int]]:
        self._pos[seq.slot] = seq.pos
        self._tok[seq.slot, 0] = seq.generated[-1]
        events = [(seq.req.rid, seq.generated[-1])]
        if seq.remaining == 0:               # max_new == 1: done at prefill
            self._complete(seq.slot)
        return events

    def _complete(self, slot: int) -> None:
        seq = self.sched.release(slot)
        seq.done_wall = time.perf_counter()
        if self.paged:
            self.page_pool.free(seq.pages)
            self.block_tables[slot, :] = PagePool.TRASH_PAGE
        self._pos[slot] = 0
        self._tok[slot, 0] = 0
        self._finished[seq.req.rid] = seq

    # -- stepping -------------------------------------------------------------
    def step(self) -> list[tuple[int, int]]:
        """Advance virtual time one step: admit what fits, grow pages,
        run one ragged batched decode.  Returns (rid, token) emissions."""
        now = self._step_idx
        now_wall = time.perf_counter()
        # latency clock starts when a request becomes admissible, not when
        # it reaches the queue head — queue wait is part of tail latency
        for r in self.sched.pending:
            if r.arrival > now:
                break                        # pending is arrival-sorted
            self._first_seen.setdefault(r.rid, now_wall)
        events: list[tuple[int, int]] = []
        while self.sched.has_free_slot():
            req = self.sched.peek_ready(now)
            if req is None:
                break
            if self.paged:
                # head-of-line: admit only if the pool can cover this
                # request's lifetime AND every running sequence's
                # worst-case growth — mid-decode growth must never fail
                budget = (self.page_pool.free_count
                          - self._reserved_pages())
                if self._lifetime_pages(req) > budget:
                    break
                events += self._admit_paged(req)
            else:
                events += self._admit_state(req)

        if self.paged:
            for seq in self.sched.active.values():
                # next write position may cross into an unallocated page
                need_idx = seq.pos // self.page_size
                if need_idx >= len(seq.pages):
                    if not self.page_pool.can_alloc(1):
                        raise PoolExhausted(
                            "invariant violation: admission reserved too "
                            f"few pages for seq {seq.req.rid}'s growth")
                    (pg,) = self.page_pool.alloc(1)
                    seq.pages.append(pg)
                    self.block_tables[seq.slot, need_idx] = pg

        if self.sched.active:
            tok = jnp.asarray(self._tok)
            pos = jnp.asarray(self._pos)
            if self.paged:
                nxt, _, self.pool = self._decode(
                    self.params, self.pool, jnp.asarray(self.block_tables),
                    tok, pos)
            else:
                nxt, _, self.cache = self._decode(
                    self.params, self.cache, tok, pos)
            nxt = np.asarray(nxt).reshape(self.max_slots, -1)[:, 0]
            for slot, seq in list(self.sched.active.items()):
                t = int(nxt[slot])
                seq.generated.append(t)
                seq.pos += 1
                self._pos[slot] = seq.pos
                self._tok[slot, 0] = t
                events.append((seq.req.rid, t))
                if seq.remaining == 0:
                    self._complete(slot)

        self._step_idx += 1
        return events

    # -- warmup / run ---------------------------------------------------------
    def warmup(self) -> float:
        """Pre-compile every jitted shape the queued trace will hit, so
        steady-state throughput excludes compile time.  Results are
        discarded — no engine state changes."""
        t0 = time.perf_counter()
        if self.paged:
            buckets = sorted({self._bucket(len(r.tokens))
                              for r in self.sched.pending})
            for b in buckets:
                logits, cache = self._prefill(
                    self.params, {"tokens": jnp.zeros((1, b), jnp.int32)})
                trash = np.full(b // self.page_size, PagePool.TRASH_PAGE,
                                np.int32)
                jax.block_until_ready(self._page_write(
                    self.pool, cache, jnp.asarray(trash))["k"])
                jax.block_until_ready(logits)
            out = self._decode(
                self.params, self.pool, jnp.asarray(self.block_tables),
                jnp.asarray(self._tok), jnp.asarray(self._pos))
            jax.block_until_ready(out[0])
        else:
            sub = self.model.init_cache(1, self.max_len)
            out = self._decode(self.params, sub,
                               jnp.zeros((1, 1), jnp.int32),
                               jnp.asarray(0, jnp.int32))
            jax.block_until_ready(out[0])
            jax.block_until_ready(jax.tree_util.tree_leaves(
                self._write_slot(self.cache, sub, jnp.asarray(0)))[0])
            out = self._decode(self.params, self.cache,
                               jnp.asarray(self._tok),
                               jnp.asarray(self._pos))
            jax.block_until_ready(out[0])
        dt = time.perf_counter() - t0
        self.stats["warmup_s"] += dt
        return dt

    def run(self, requests: list[Request] | None = None, *,
            warmup: bool = True, max_steps: int | None = None) -> dict:
        """Drive the engine until every submitted request completes.

        Returns ``{"tokens": {rid: [...]}, "stats": {...}}`` with
        compile/warmup time reported separately from steady-state
        throughput (tokens/sec over the post-warmup serving loop).
        """
        for r in requests or []:
            self.submit(r)
        if warmup:
            self.warmup()
        if max_steps is None:
            max_steps = (max((r.arrival for r in self._submitted), default=0)
                         + sum(r.max_new for r in self._submitted)
                         + self.max_slots + 16)
        t0 = time.perf_counter()
        n_tok = 0
        start = self._step_idx
        while not self.sched.done:
            if self._step_idx - start > max_steps:
                raise RuntimeError(
                    f"engine stalled: {len(self.sched.pending)} pending / "
                    f"{len(self.sched.active)} active after {max_steps} steps")
            n_tok += len(self.step())
        steady_s = time.perf_counter() - t0
        lat = sorted(s.done_wall - s.ready_wall
                     for s in self._finished.values())
        self.stats.update({
            "steps": self._step_idx - start,
            "completed": len(self._finished),
            "generated_tokens": n_tok,
            "steady_s": round(steady_s, 4),
            "steady_tok_per_s": round(n_tok / max(steady_s, 1e-9), 2),
            "p50_latency_s": round(float(np.percentile(lat, 50)), 4)
            if lat else 0.0,
            "p99_latency_s": round(float(np.percentile(lat, 99)), 4)
            if lat else 0.0,
        })
        return {"tokens": {rid: list(s.generated)
                           for rid, s in sorted(self._finished.items())},
                "stats": dict(self.stats)}


# ---------------------------------------------------------------------------
# static-batch reference
# ---------------------------------------------------------------------------
# jit caches key on function identity, so building fresh closures per
# request would recompile identical shapes every call (the reference runs
# once per request per bench variant).  Keyed by object ids, which is safe
# here because the cached closures keep model/plan alive — their ids can't
# be recycled while an entry exists.
_STATIC_FNS: dict[tuple[int, int], tuple] = {}


def _static_fns(model: LM, plan):
    key = (id(model), id(plan))
    if key not in _STATIC_FNS:
        _STATIC_FNS[key] = (
            jax.jit(steps_mod.make_decode_step(model, plan=plan)),
            jax.jit(steps_mod.make_prefill_step(model, plan=plan)),
        )
    return _STATIC_FNS[key]


def static_generate(model: LM, params: Params, req: Request,
                    max_len: int | None = None, plan=None) -> list[int]:
    """Per-request static-batch greedy generation — the reference the
    engine must match token-for-token.  Mirrors the classic serve path:
    fused prefill for attention families, prompt replay through the
    batch-1 decode step for recurrent families."""
    cfg = model.cfg
    prompt = jnp.asarray(req.tokens, jnp.int32)[None]
    plen = prompt.shape[1]
    if max_len is None:
        max_len = plen + req.max_new
    decode, prefill = _static_fns(model, plan)
    if cfg.family in ("hybrid", "ssm"):
        cache = model.init_cache(1, max_len)
        nxt = None
        for t in range(plen):
            nxt, _, cache = decode(params, cache, prompt[:, t:t + 1],
                                   jnp.asarray(t, jnp.int32))
        first = int(np.asarray(nxt).reshape(-1)[0])
    else:
        nxt, cache = prefill(params, {"tokens": prompt})
        cache = model.grow_cache(cache, max_len)
        first = int(np.asarray(nxt).reshape(-1)[0])
    out = [first]
    tok = jnp.full((1, 1), first, jnp.int32)
    for t in range(req.max_new - 1):
        nxt, _, cache = decode(params, cache, tok,
                               jnp.asarray(plen + t, jnp.int32))
        out.append(int(np.asarray(nxt).reshape(-1)[0]))
        tok = jnp.asarray(nxt, jnp.int32).reshape(1, 1)
    return out
