"""Continuous-batching inference engine over Sparse-on-Dense weights.

One :class:`Engine` owns a fixed number of *slots* (rows of the batched
decode step) and admits/evicts requests every step, so sequences of
different lengths join and leave the running batch continuously — the
regime where the paper's compressed weight storage pays off most, since
decode is weight-bytes-bound and every slot shares the one packed copy.

Two cache regimes, chosen by model family:

* **paged** (attention families): per-layer KV page pools
  (:func:`repro.models.transformer.transformer_init_paged_pool`) with a
  host-side refcounted free-list allocator
  (:class:`repro.serving.pool.PagePool`) and one block table per slot.
* **slot state** (hybrid / ssm): O(1) recurrent state lives in a
  max_slots-batched cache; admission replays the prompt through the
  batch-1 decode step (exactly the static serve path) and scatters the
  final state into the slot via the explicit cache-axes API
  (:func:`repro.models.cache.write_slot`).

Three scheduler upgrades (paged families, all off by default) keep the
batch busy under real load:

* **chunked prefill** (``prefill_chunk=C``): admission splits a prompt
  into fixed C-token chunks run one per engine step, interleaved with the
  running batch's decode steps — a long prompt no longer freezes decode.
  The admitted sequence holds a slot in the *prefilling* state (its
  block-table row is masked to the trash page for decode) until its final
  chunk delivers the first token.
* **preemption with page-level swapping** (``preemption=True``): on pool
  pressure the engine swaps the lowest-priority (youngest-arrival)
  decoding sequence's pages to host memory instead of blocking — the
  worst-case-reservation admission rule is replaced by a
  preemption-backed one (admit when the *prompt* pages fit; growth
  recovers pages by preempting).  Swapped sequences resume ahead of any
  pending newcomer once pages free up; the KV bytes round-trip exactly,
  so tokens are unchanged.
* **prefix sharing** (``prefix_sharing=True``, requires chunked prefill):
  a prefix trie over page-sized prompt token chunks
  (:class:`repro.serving.pool.PrefixTrie`) maps shared prefixes to
  refcounted pages — identical few-shot prefixes pack once, admission
  maps them straight into the block table and prefill skips their
  positions.  Writes into a shared page (a fully shared prompt recomputes
  its last token for logits) copy-on-write fork it first.

One decode upgrade rides the same machinery: **sparsity-tiered
speculative decoding** (``spec_k=k`` with ``draft_params`` — a second,
aggressively compressed pack of the *same* weights, typically from
:func:`repro.runtime.planner.build_draft_plan`).  Each step, every
decoding slot drafts k tokens ahead with the cheap tier (its KV lives in
a parallel page pool addressed by the same block tables), then one
batched verify pass scores the whole k+1-token window with the target
weights; the longest draft prefix matching the target's greedy tokens is
accepted plus one bonus target token, and pages allocated past the new
position roll back to the pool.  Emitted tokens are always the *target's*
argmax, so output is bit-identical to non-speculative greedy decoding —
the draft tier only changes how many positions each step commits.
``spec_k=0`` (the default) leaves every code path byte-identical to the
non-speculative engine.

Every feature composes with every other.  :meth:`Engine.step` is an
explicit phase pipeline — admission (resume swapped, admit what fits) →
prefill (fused at admission, or one chunk per prefilling slot) →
capacity (grow pages out to each slot's decode or draft-window span,
preempting under pressure) → draft window → verify/decode →
commit/rollback — where each phase is a method over the shared slot
state and the feature flags select phase *implementations* rather than
gating ``ValueError``\\s.  The composition rules the pipeline enforces:

* a slot mid-chunked-prefill takes no decode or draft steps — its
  per-row write cutoff (``valid_len``) is 0, so one batched step safely
  covers a mix of prefilling and decoding slots without host-side
  block-table copies;
* draft-pool pages share the target pool's page ids, so the preemption
  reservation rule covers them for free; on preemption a slot's
  speculative pages are *trimmed* (rolled back, never swapped) and its
  draft-pool KV is dropped — the resumed sequence re-drafts from
  scratch, which can only lower acceptance, never change a token;
* rollback (:meth:`_trim_spec_pages`) returns pages through the
  refcount-aware :meth:`repro.serving.pool.PagePool.trim`, so a
  rollback on a prefix-sharing sequence can never free a page the trie
  still maps.

One retention layer sits on top: the **persistent multi-tier prefix
cache** (``prefix_cache_budget`` / ``prefix_cache_dir``, requires prefix
sharing).  Completed prompts' trie-held pages stay alive past sequence
completion under an LRU byte budget (HBM tier); cold pages demote to
host memory through the same per-page gather path preemption uses, and
optionally spill to disk keyed by token-prefix hash so the cache
survives engine restarts.  Admission promotes lower-tier chunks back
into fresh pages (skipping their re-prefill entirely), counts
cache-retained-but-sole-referenced pages as reclaimable capacity, and
demotes them on demand under pool pressure — so retention can never
starve admission.  See :mod:`repro.serving.prefix_cache` and
``docs/caching.md``.  With the cache off, every code path is
byte-identical to the cache-less engine.

Greedy tokens are bit-identical to per-request static-batch serve
(:func:`static_generate`) under any schedule because every per-row
computation is batch-row-independent and padding/masked positions
contribute exact zeros; shared pages hold KV bytes identical to what the
sharer's own prefill would have written, and swapped pages are restored
byte-for-byte.  One documented exception: MoE capacity-factor routing is
batch-global, so under expert-capacity pressure an engine batch can drop
different tokens than a batch-1 run.

All jit-compiled shapes are fixed by (max_slots, pool size, block-table
width, prompt buckets / the chunk size), so steady-state serving never
recompiles; :meth:`Engine.warmup` pre-compiles everything for the queued
trace and is timed separately from steady-state throughput.
"""
from __future__ import annotations

import math
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.launch import steps as steps_mod
from repro.models import cache as cache_mod
from repro.models.model import LM
from repro.serving.pool import PagePool, PoolExhausted, PrefixTrie
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import Request, Scheduler, SeqPhase, SeqState

Params = dict[str, Any]


def bucket_len(plen: int, page_size: int, chunk: int | None = None) -> int:
    """Page-aligned prefill bucket for a prompt of ``plen`` tokens.

    Rounds up to the page size so prompt KV fills whole pages; prompts
    longer than the attention chunk additionally round to a multiple of
    the chunk (``chunked_attention`` requires divisibility there).
    """
    b = -(-plen // page_size) * page_size
    if chunk and b > chunk:
        lcm = math.lcm(page_size, chunk)
        b = -(-plen // lcm) * lcm
    return b


def _pool_write_pages(pool: Params, cache: Params, page_ids):
    """Scatter a whole prefill's KV into pages ``page_ids`` of every
    layer's pool in one shot — page j of the bucketed prompt (positions
    [j·page, (j+1)·page)) lands in pool page ``page_ids[j]``.  One pool
    copy per admission instead of one per page."""
    page_size = pool["k"].shape[3]

    def write(pl, cl):
        # cl (G, P, 1, S, KV, hd), S = len(page_ids)·page
        g, p = cl.shape[0], cl.shape[1]
        pages = cl[:, :, 0].reshape(
            g, p, -1, page_size, cl.shape[-2], cl.shape[-1])
        return pl.at[:, :, page_ids].set(pages)

    return {"k": write(pool["k"], cache["k"]),
            "v": write(pool["v"], cache["v"])}


def _pool_copy_page(pool: Params, src, dst):
    """Copy-on-write fork: duplicate page ``src`` into ``dst`` across
    every layer's pool."""
    return {"k": pool["k"].at[:, :, dst].set(pool["k"][:, :, src]),
            "v": pool["v"].at[:, :, dst].set(pool["v"][:, :, src])}


def _pool_gather_pages(pool: Params, page_ids):
    """Swap-out: pull pages ``page_ids`` (padded with the trash page to a
    fixed width, so one compile serves every page count) out of every
    layer's pool — (G, P, n_ids, page, KV, hd)."""
    return {"k": pool["k"][:, :, page_ids], "v": pool["v"][:, :, page_ids]}


def _pool_scatter_pages(pool: Params, kv: Params, page_ids):
    """Swap-in: write a gathered snapshot back at fresh page ids.  Padding
    entries target the trash page, which is garbage by design."""
    return {"k": pool["k"].at[:, :, page_ids].set(kv["k"]),
            "v": pool["v"].at[:, :, page_ids].set(kv["v"])}


def _pool_get_page(pool: Params, page_id):
    """Cache demotion: slice one page out of every layer's pool —
    (G, P, page, KV, hd) per side."""
    return {"k": pool["k"][:, :, page_id], "v": pool["v"][:, :, page_id]}


def _pool_set_page(pool: Params, kv: Params, page_id):
    """Cache promotion: write one host-restored page's KV back into a
    freshly allocated page of every layer's pool."""
    return {"k": pool["k"].at[:, :, page_id].set(kv["k"]),
            "v": pool["v"].at[:, :, page_id].set(kv["v"])}


class Engine:
    """Continuous-batching engine: paged KV pool + request scheduler +
    ragged batched decode over one shared (optionally SoD-packed) model."""

    def __init__(self, model: LM, params: Params, *, max_slots: int = 4,
                 page_size: int = 16, max_len: int = 256,
                 n_pages: int | None = None, plan=None, mesh=None,
                 prefill_chunk: int | None = None, preemption: bool = False,
                 prefix_sharing: bool = False, spec_k: int = 0,
                 draft_params: Params | None = None, draft_plan=None,
                 prefix_cache_budget: int = 0,
                 prefix_cache_dir: str | None = None, tracer=None):
        cfg = model.cfg
        if cfg.family in ("vlm", "audio"):
            raise NotImplementedError(
                f"engine serves token-in/token-out families; {cfg.family!r} "
                "needs frontend plumbing (prefix embeds / codebook stacks)")
        self.model = model
        self.params = params
        self.plan = plan
        self.max_slots = int(max_slots)
        self.max_len = int(max_len)
        self.paged = cfg.family not in ("hybrid", "ssm")
        if not self.paged and (prefill_chunk or preemption or prefix_sharing
                               or prefix_cache_budget or prefix_cache_dir):
            raise ValueError(
                f"family {cfg.family!r} keeps O(1) recurrent state per slot; "
                "chunked prefill / preemption / prefix sharing / the prefix "
                "cache are paged-KV scheduler features")
        if prefix_sharing and not prefill_chunk:
            raise ValueError(
                "prefix sharing needs chunked prefill (prefill_chunk=...): "
                "admission skips shared positions, so prefill must be able "
                "to start mid-prompt")
        if (prefix_cache_budget or prefix_cache_dir) and not prefix_sharing:
            raise ValueError(
                "the prefix cache retains trie-held prompt pages: pass "
                "prefix_sharing=True (and prefill_chunk=...) to enable it")
        self.spec_k = int(spec_k or 0)
        if self.spec_k:
            if not self.paged:
                raise ValueError(
                    f"family {cfg.family!r} keeps O(1) recurrent state per "
                    "slot; speculative decoding verifies windows against "
                    "the paged KV cache")
            if draft_params is None:
                raise ValueError(
                    "spec_k > 0 needs draft_params — a second (aggressively "
                    "compressed) pack of the same weights, e.g. from "
                    "repro.runtime.planner.build_draft_plan")
        self.draft_params = draft_params
        self.draft_plan = draft_plan
        self.prefill_chunk = int(prefill_chunk) if prefill_chunk else None
        self.preemption = bool(preemption)
        self.prefix_sharing = bool(prefix_sharing)
        self.tracer = tracer if tracer is not None else obs.get_tracer()
        self.metrics = obs.Metrics()
        self.sched = Scheduler(max_slots, tracer=self.tracer)
        self._step_idx = 0
        self._submitted: list[Request] = []
        self._first_seen: dict[int, float] = {}
        self._finished: dict[int, SeqState] = {}
        self.preempt_log: list[int] = []      # rids in eviction order
        # the stats dict lives on the metrics registry's counter table —
        # a dict-compatible view, so every existing key and access stays
        # bit-identical while snapshots see the same numbers
        self.stats = self.metrics.stats_view()
        self.stats.update({
            "warmup_s": 0.0, "prefill_chunks": 0, "preemptions": 0,
            "swapped_out_pages": 0, "swapped_in_pages": 0, "cow_forks": 0,
            "shared_prompt_pages": 0, "prompt_pages_total": 0,
            "prompt_pages_fresh": 0, "spec_windows": 0,
            "draft_proposed": 0, "draft_accepted": 0,
            "spec_rollbacks": 0, "spec_rollback_pages": 0,
            "spec_window_preemptions": 0,
            "prefix_hits": 0, "prefix_misses": 0, "prefix_hbm_hits": 0,
            "prefix_host_hits": 0, "prefix_disk_hits": 0,
            "prefix_restored_pages": 0, "prefix_demotions_host": 0,
            "prefix_demotions_disk": 0, "reprefill_tokens_saved": 0,
            "prefix_bytes_hbm": 0, "prefix_bytes_host": 0,
            "prefix_bytes_disk": 0,
        })
        self._pos = np.zeros(self.max_slots, np.int32)
        self._tok = np.zeros((self.max_slots, 1), np.int32)
        self.prefix_cache: PrefixCache | None = None

        if self.paged:
            self.page_size = int(page_size)
            self._chunk = cfg.attn_chunk
            # speculative windows probe up to spec_k positions past a
            # sequence's own lifetime; widening the block tables keeps
            # those (trash-redirected) lookups in bounds so a clamped
            # gather can never alias a live page
            self.max_pages = -(-(self.max_len + self.spec_k)
                               // self.page_size)
            if n_pages is None:
                n_pages = 1 + self.max_slots * self.max_pages
            self.page_pool = PagePool(n_pages, self.page_size)
            self.trie = PrefixTrie(self.page_size) if prefix_sharing else None
            self.pool = model.init_paged_pool(n_pages, self.page_size)
            if prefix_cache_budget or prefix_cache_dir:
                k = self.pool["k"]
                page_nbytes = 2 * (k.size // k.shape[2]) * k.dtype.itemsize
                self._page_get = jax.jit(_pool_get_page)
                self._page_set = jax.jit(_pool_set_page)
                self.prefix_cache = PrefixCache(
                    self.page_pool, page_nbytes,
                    budget_bytes=int(prefix_cache_budget or 0),
                    cache_dir=prefix_cache_dir,
                    gather=self._gather_page_host,
                    on_page_freed=self.trie.drop)
            self.block_tables = np.full(
                (self.max_slots, self.max_pages), PagePool.TRASH_PAGE,
                np.int32)
            self._decode = jax.jit(
                steps_mod.make_paged_decode_step(model, mesh=mesh, plan=plan))
            self._prefill = jax.jit(
                steps_mod.make_prefill_full(model, mesh=mesh, plan=plan))
            self._page_write = jax.jit(_pool_write_pages)
            self._copy_page = jax.jit(_pool_copy_page)
            self._gather_pages = jax.jit(_pool_gather_pages)
            self._scatter_pages = jax.jit(_pool_scatter_pages)
            if self.prefill_chunk:
                self._chunk_prefill = jax.jit(
                    steps_mod.make_chunked_prefill_step(model, mesh=mesh,
                                                        plan=plan))
            if self.spec_k:
                # the draft tier's KV lives in a parallel page pool
                # addressed by the same block tables / page ids
                self.draft_pool = model.init_paged_pool(n_pages,
                                                        self.page_size)
                self._draft_decode = jax.jit(
                    steps_mod.make_paged_decode_step(model, mesh=mesh,
                                                     plan=draft_plan))
                self._draft_prefill = jax.jit(
                    steps_mod.make_prefill_full(model, mesh=mesh,
                                                plan=draft_plan))
                self._verify = jax.jit(
                    steps_mod.make_verify_step(model, mesh=mesh, plan=plan))
                if self.prefill_chunk:
                    self._draft_chunk_prefill = jax.jit(
                        steps_mod.make_chunked_prefill_step(
                            model, mesh=mesh, plan=draft_plan))
        else:
            self.cache = model.init_cache(self.max_slots, self.max_len)
            spec = model.cache_spec()
            self._decode = jax.jit(
                steps_mod.make_decode_step(model, mesh=mesh, plan=plan))
            self._write_slot = jax.jit(
                lambda c, sub, slot: cache_mod.write_slot(c, sub, spec, slot))

    # -- admission ------------------------------------------------------------
    def _bucket(self, plen: int) -> int:
        return bucket_len(plen, self.page_size, self._chunk)

    def submit(self, req: Request) -> None:
        """Queue a request, validating it can ever fit this engine
        (prompt + generation budget within ``max_len`` and the page
        pool); admission happens later, when a slot and pages free up."""
        plen = len(req.tokens)
        end = plen + req.max_new - 1          # last cache position + 1
        if self.paged:
            if self.prefill_chunk and self._chunk and plen > self._chunk:
                raise ValueError(
                    f"request {req.rid}: prompt of {plen} tokens exceeds "
                    f"attn_chunk={self._chunk}; chunked prefill's "
                    "single-block attention is only bit-identical to the "
                    "fused reference for prompts within one attention "
                    "chunk")
            need = end if self.prefill_chunk else max(self._bucket(plen), end)
            pages = self.page_pool.pages_for(need)
            if need > self.max_len or pages > self.page_pool.n_pages - 1:
                raise ValueError(
                    f"request {req.rid}: needs {need} positions / {pages} "
                    f"pages; engine max_len={self.max_len}, pool="
                    f"{self.page_pool.n_pages}")
        elif end > self.max_len:
            raise ValueError(
                f"request {req.rid}: needs {end} positions; engine "
                f"max_len={self.max_len}")
        self._submitted.append(req)
        self.sched.submit(req)

    @staticmethod
    def _seq_end(seq: SeqState) -> int:
        """Last cache position the sequence will ever write, + 1.  Holds
        for prefilling and decoding states alike (for a decoding sequence
        it equals ``pos + remaining``)."""
        return len(seq.req.tokens) + seq.req.max_new - 1

    def _lifetime_pages(self, req: Request) -> int:
        """Worst-case pages the request will ever hold: its prefill
        bucket (or bare prompt, chunked) plus decode growth out to its
        last write position."""
        plen = len(req.tokens)
        end = plen + req.max_new - 1
        need = end if self.prefill_chunk else max(self._bucket(plen), end)
        return self.page_pool.pages_for(need)

    def _reserved_pages(self) -> int:
        """Pages the *running* sequences may still claim via growth.
        Without preemption, admission holds these back so mid-decode
        growth can never find the pool empty."""
        r = self._pending_forks()
        for seq in self.sched.active.values():
            r += max(0, self.page_pool.pages_for(self._seq_end(seq))
                     - len(seq.pages))
        return r

    def _pending_forks(self) -> int:
        """Copy-on-write forks admitted-but-not-yet-taken: a prefilling
        sequence whose next write lands in a page it still shares will
        claim one fresh page at its next tick."""
        n = 0
        for seq in self.sched.active.values():
            if seq.is_prefilling and seq.pages:
                j = seq.prefilled // self.page_size
                if (j < len(seq.pages)
                        and self.page_pool.ref_count(seq.pages[j]) > 1):
                    n += 1
        return n

    def _share_plan(self, req: Request,
                    ) -> tuple[list[int], list[str], int, int]:
        """Prefix-trie + cache lookup for a prompt: (shared page ids,
        lower-tier restore keys, prefill start position, fresh pages
        needed now).  The trie walk finds HBM-resident prefix pages; with
        a prefix cache, the walk continues through the host/disk tiers —
        each further page-aligned chunk whose token-prefix hash is cached
        gets promoted at admission instead of prefilled (its page still
        counts as *fresh* for allocation).  A fully shared page-aligned
        prompt still recomputes its last token (the engine needs its
        logits); when that last page is trie-shared the write
        copy-on-write-forks it — budget one extra page — while a
        restored last page is private, so the recompute writes in place
        (byte-identical by determinism of the prefill math)."""
        plen = len(req.tokens)
        shared = self.trie.match(req.tokens) if self.trie is not None else []
        restore: list[str] = []
        if self.prefix_cache is not None:
            ps = self.page_size
            j = len(shared)
            while (j + 1) * ps <= plen:
                key = PrefixCache.key(req.tokens[:(j + 1) * ps])
                if self.prefix_cache.peek(key) is None:
                    break
                restore.append(key)
                j += 1
        start = (len(shared) + len(restore)) * self.page_size
        fresh = self.page_pool.pages_for(plen) - len(shared)
        if start >= plen:                 # fully covered, aligned prompt
            start = plen - 1
            if not restore:
                fresh += 1                # COW fork of the last page
        return shared, restore, start, fresh

    def _can_admit(self, req: Request,
                   share: tuple[list[int], list[str], int, int] | None = None,
                   ) -> bool:
        plen = len(req.tokens)
        end = plen + req.max_new - 1
        if self.prefill_chunk:
            share = share if share is not None else self._share_plan(req)
            fresh = share[3]
            growth = (self.page_pool.pages_for(end)
                      - self.page_pool.pages_for(plen))
        else:
            fresh = self.page_pool.pages_for(self._bucket(plen))
            growth = self._lifetime_pages(req) - fresh
        if self.preemption:
            # preemption-backed rule: admit when the prompt fits NOW
            # (counting forks already-admitted prefills will still take);
            # decode growth later recovers pages by evicting the youngest
            return fresh + self._pending_forks() <= self._headroom()
        # reservation rule: the pool must also cover this request's own
        # growth (incl. any COW fork) and every running sequence's
        # worst-case growth
        budget = self._headroom() - self._reserved_pages()
        return fresh + growth <= budget

    # -- cache-aware allocation -----------------------------------------------
    def _headroom(self) -> int:
        """Pages allocatable right now plus cache-retained pages whose
        only holder is the cache — those demote on demand, so admission
        treats them as reclaimable capacity."""
        free = self.page_pool.free_count
        if self.prefix_cache is not None:
            free += self.prefix_cache.reclaimable()
        return free

    def _provide(self, n: int) -> bool:
        """Make ``n`` pages allocatable without preempting anyone, by
        demoting reclaimable cache entries LRU-first.  Returns whether
        :meth:`PagePool.alloc` of ``n`` would now succeed."""
        if self.page_pool.can_alloc(n):
            return True
        if self.prefix_cache is not None:
            self.prefix_cache.reclaim(n - self.page_pool.free_count)
        return self.page_pool.can_alloc(n)

    def _alloc_pages(self, n: int) -> list[int]:
        """Allocate ``n`` pages, demoting cache entries under pressure."""
        if n and not self.page_pool.can_alloc(n):
            self._provide(n)
        return self.page_pool.alloc(n)

    def _admit_paged(self, req: Request) -> list[tuple[int, int]]:
        plen = len(req.tokens)
        bucket = self._bucket(plen)
        padded = np.zeros(bucket, np.int32)
        padded[:plen] = req.tokens
        logits, cache = self._prefill(
            self.params, {"tokens": jnp.asarray(padded)[None]})
        first = int(jnp.argmax(logits[0, plen - 1]))
        n = self.page_pool.pages_for(bucket)
        pages = self.page_pool.alloc(n)
        self.pool = self._page_write(
            self.pool, cache, jnp.asarray(np.asarray(pages, np.int32)))
        if self.spec_k:
            # the draft tier needs its own prompt KV: same pages, its own
            # pool, its own (cheaper) weights.  Draft logits are unused —
            # the first token must be the target's.
            _, dcache = self._draft_prefill(
                self.draft_params, {"tokens": jnp.asarray(padded)[None]})
            self.draft_pool = self._page_write(
                self.draft_pool, dcache,
                jnp.asarray(np.asarray(pages, np.int32)))
        seq = self.sched.place(req, pos=plen, first_token=first, pages=pages,
                               ready_wall=self._first_seen[req.rid])
        self.block_tables[seq.slot, :] = PagePool.TRASH_PAGE
        self.block_tables[seq.slot, :len(pages)] = pages
        self.stats["prompt_pages_total"] += n
        self.stats["prompt_pages_fresh"] += n
        return self._post_admit(seq)

    def _gather_page_host(self, page: int) -> dict:
        """Snapshot one page's KV bytes to host numpy arrays — the cache's
        demotion path (same per-page movement preemption's swap uses)."""
        snap = self._page_get(self.pool, jnp.asarray(page, jnp.int32))
        return jax.device_get(snap)

    def _restore_prefix(self, keys: list[str]) -> list[int]:
        """Promote cached chunks back into HBM: allocate one fresh page
        per key (demoting colder cache entries under pressure) and
        scatter the host/disk bytes in.  Stops at the first miss or at a
        snapshot whose shape/dtype doesn't match this engine's pool (a
        cache dir written by a different model config) — the remaining
        chunks just prefill normally."""
        k = self.pool["k"]
        expect = k.shape[:2] + k.shape[3:]
        pages: list[int] = []
        for key in keys:
            got = self.prefix_cache.fetch(key)
            if got is None:
                break
            kv, tier = got
            if (kv["k"].shape != expect or kv["v"].shape != expect
                    or str(kv["k"].dtype) != str(k.dtype)
                    or str(kv["v"].dtype) != str(k.dtype)):
                break
            (pg,) = self._alloc_pages(1)
            self.pool = self._page_set(
                self.pool,
                {"k": jnp.asarray(kv["k"]), "v": jnp.asarray(kv["v"])},
                jnp.asarray(pg, jnp.int32))
            self.stats["prefix_host_hits" if tier == "host"
                       else "prefix_disk_hits"] += 1
            self.stats["prefix_restored_pages"] += 1
            pages.append(pg)
        return pages

    def _admit_chunked(self, req: Request,
                       share: tuple[list[int], list[str], int, int]
                       | None = None) -> list[tuple[int, int]]:
        """Admit into the prefilling state: map shared prefix pages,
        promote any lower-tier cached chunks, allocate the rest, and let
        :meth:`_prefill_tick` advance one chunk per step.  No tokens are
        emitted until the final chunk.  Restored pages carry complete KV,
        so they register in the trie immediately and never count as
        *fresh prompt pages* — the second epoch of a repeated prompt
        prefills zero fresh pages."""
        plen = len(req.tokens)
        shared, restore, start, _ = share if share is not None else \
            self._share_plan(req)
        total = self.page_pool.pages_for(plen)
        if shared:
            # retain before any cache reclaim can run: a shared page now
            # has a sequence reference, so demotions can't free it
            self.page_pool.retain(shared)
        hbm_hits = 0
        if self.prefix_cache is not None:
            hbm_hits = sum(1 for p in shared if self.prefix_cache.held(p))
            # leaf-first LRU touch keeps parents younger than children
            for p in reversed(shared):
                self.prefix_cache.touch(p)
        restored = (self._restore_prefix(restore)
                    if self.prefix_cache is not None and restore else [])
        # recompute coverage from what actually promoted (a corrupt disk
        # file truncates the restore chain)
        start = (len(shared) + len(restored)) * self.page_size
        if start >= plen:
            start = plen - 1
        fresh = self._alloc_pages(total - len(shared) - len(restored))
        pages = list(shared) + restored + fresh
        seq = self.sched.place(req, pos=plen, pages=pages,
                               ready_wall=self._first_seen[req.rid],
                               prefilled=start)
        self.block_tables[seq.slot, :] = PagePool.TRASH_PAGE
        self.block_tables[seq.slot, :len(pages)] = pages
        if restored:
            # restored chunks are fully prefilled: share them immediately
            self.trie.register(req.tokens, pages,
                               len(shared) + len(restored))
        self.stats["shared_prompt_pages"] += len(shared)
        self.stats["prompt_pages_total"] += total
        self.stats["prompt_pages_fresh"] += total - len(shared) - len(restored)
        if self.prefix_cache is not None:
            seq.cached_prompt_pages = hbm_hits + len(restored)
            if seq.cached_prompt_pages:
                self.stats["prefix_hits"] += 1
                self.stats["prefix_hbm_hits"] += hbm_hits
                self.stats["reprefill_tokens_saved"] += (
                    self.page_size * seq.cached_prompt_pages)
            else:
                self.stats["prefix_misses"] += 1
        return []

    def _admit_state(self, req: Request) -> list[tuple[int, int]]:
        prompt = jnp.asarray(req.tokens, jnp.int32)[None]
        sub = self.model.init_cache(1, self.max_len)
        nxt = None
        for t in range(prompt.shape[1]):
            nxt, _, sub = self._decode(
                self.params, sub, prompt[:, t:t + 1],
                jnp.asarray(t, jnp.int32))
        first = int(np.asarray(nxt).reshape(-1)[0])
        seq = self.sched.place(req, pos=prompt.shape[1], first_token=first,
                               pages=[],
                               ready_wall=self._first_seen[req.rid])
        self.cache = self._write_slot(self.cache, sub,
                                      jnp.asarray(seq.slot))
        return self._post_admit(seq)

    def _post_admit(self, seq: SeqState) -> list[tuple[int, int]]:
        seq.first_token_wall = time.perf_counter()
        self._pos[seq.slot] = seq.pos
        self._tok[seq.slot, 0] = seq.generated[-1]
        events = [(seq.req.rid, seq.generated[-1])]
        if seq.remaining == 0:               # max_new == 1: done at prefill
            self._complete(seq.slot)
        return events

    def _complete(self, slot: int) -> None:
        seq = self.sched.release(slot)
        seq.done_wall = time.perf_counter()
        self.metrics.observe("queue_wait_s",
                             seq.admitted_wall - seq.ready_wall)
        self.metrics.observe("ttft_s", seq.first_token_wall - seq.ready_wall)
        self.metrics.observe("tpot_s",
                             (seq.done_wall - seq.first_token_wall)
                             / max(len(seq.generated) - 1, 1))
        if self.paged:
            if self.prefix_cache is not None:
                self._cache_hold(seq)
            freed = self.page_pool.free(seq.pages)
            if self.trie is not None:
                for p in freed:
                    self.trie.drop(p)
            self.block_tables[slot, :] = PagePool.TRASH_PAGE
        self._pos[slot] = 0
        self._tok[slot, 0] = 0
        self._finished[seq.req.rid] = seq

    def _cache_hold(self, seq: SeqState) -> None:
        """Retain the completed sequence's trie-resident prompt chain in
        the cache, so the pages outlive the sequence.  Holds run
        leaf-first so every parent ends more recently used than its
        children — LRU demotions then peel chains leaf-first and can
        never orphan a still-held subtree.  The chain is the *canonical*
        trie pages (another sequence's copy may have won registration),
        keyed by the full token prefix through each chunk."""
        tokens = seq.req.tokens
        matched = self.trie.match(tokens)
        ps = self.page_size
        for j in range(len(matched) - 1, -1, -1):
            self.prefix_cache.hold(
                PrefixCache.key(tokens[:(j + 1) * ps]), matched[j])

    # -- chunked prefill ------------------------------------------------------
    def _try_capacity(self, n: int) -> bool:
        """Try to make ``n`` pages allocatable, preempting youngest-first
        when allowed.  Returns False when every victim is exhausted (a
        victim holding only shared pages frees nothing) — the caller
        decides whether that means waiting or an invariant violation.
        Without preemption this raises: the reservation-based admission
        rule is supposed to make pressure here impossible.  Cache-retained
        pages are demoted first — they are capacity, not residents."""
        while not self._provide(n):
            if not self.preemption:
                raise PoolExhausted(
                    "invariant violation: admission reserved too few pages "
                    "(decode growth or copy-on-write fork)")
            victim = self.sched.preemption_victim()
            if victim is None:
                return False
            self._preempt(victim)
        return True

    def _ensure_exclusive(self, seq: SeqState, lo: int, hi: int) -> bool:
        """Copy-on-write: before writing cache positions [lo, hi), fork
        any page in that range the sequence shares with another.  Returns
        False when a needed fork cannot get a page even after preemption
        — the caller should wait a step, not die."""
        for j in range(lo // self.page_size,
                       (hi - 1) // self.page_size + 1):
            pid = seq.pages[j]
            if self.page_pool.ref_count(pid) > 1:
                if not self._try_capacity(1):
                    return False
                if self.page_pool.ref_count(pid) == 1:
                    # making room preempted the only other sharer — the
                    # page is private now, write in place
                    continue
                new = self.page_pool.fork(pid)
                self.pool = self._copy_page(
                    self.pool, jnp.asarray(pid, jnp.int32),
                    jnp.asarray(new, jnp.int32))
                if self.spec_k:
                    # the draft tier addresses the same page ids: its copy
                    # of the shared prompt KV must follow the fork
                    self.draft_pool = self._copy_page(
                        self.draft_pool, jnp.asarray(pid, jnp.int32),
                        jnp.asarray(new, jnp.int32))
                seq.pages[j] = new
                self.block_tables[seq.slot, j] = new
                self.stats["cow_forks"] += 1
        return True

    def _prefill_tick(self, seq: SeqState) -> list[tuple[int, int]]:
        """Advance one C-token chunk of a prefilling sequence; the final
        chunk (zero-padded past the prompt) yields the first token.  With
        a draft tier, the same chunk also prefills the draft pool (same
        pages, draft weights) so later draft windows see real prompt KV;
        draft logits are unused — the first token must be the target's."""
        c = self.prefill_chunk
        req = seq.req
        plen = len(req.tokens)
        start = seq.prefilled
        end = min(start + c, plen)
        if not self._ensure_exclusive(seq, start, end):
            return []                  # no page for the fork yet: wait
        chunk = np.zeros(c, np.int32)
        chunk[:end - start] = req.tokens[start:end]
        bt_row = jnp.asarray(self.block_tables[seq.slot][None])
        chunk_j = jnp.asarray(chunk)[None]
        start_j = jnp.asarray(start, jnp.int32)
        plen_j = jnp.asarray(plen, jnp.int32)
        logits, self.pool = self._chunk_prefill(
            self.params, self.pool, bt_row, chunk_j, start_j, plen_j)
        if self.spec_k:
            _, self.draft_pool = self._draft_chunk_prefill(
                self.draft_params, self.draft_pool, bt_row, chunk_j,
                start_j, plen_j)
        seq.prefilled = end
        self.stats["prefill_chunks"] += 1
        if self.trie is not None:
            self.trie.register(req.tokens, seq.pages,
                               end // self.page_size)
        if end < plen:
            return []
        first = int(jnp.argmax(logits[0, plen - 1 - start]))
        seq.generated.append(first)
        seq.pos = plen
        self.sched.set_phase(seq, SeqPhase.DECODING)
        return self._post_admit(seq)

    # -- preemption / swapping ------------------------------------------------
    def _padded_ids(self, pages: list[int]) -> jax.Array:
        ids = np.full(self.max_pages, PagePool.TRASH_PAGE, np.int32)
        ids[:len(pages)] = pages
        return jnp.asarray(ids)

    def _preempt(self, seq: SeqState) -> None:
        """Swap the sequence's pages to host memory and free them; the
        scheduler queues it for resume ahead of pending newcomers.

        Speculative pages — anything grown past the committed prefix for
        an in-flight draft window — are *trimmed* first, never swapped:
        their KV is uncommitted by definition, so the resumed sequence
        just re-drafts.  The draft pool's KV for the swapped pages is
        dropped with them (only the target pool round-trips to host);
        after resume the draft tier re-builds its KV as decode proceeds,
        which can lower acceptance for that sequence but never changes a
        token — emissions are always the target's argmax.
        """
        if self.spec_k and seq.phase is SeqPhase.DECODING:
            keep = self.page_pool.pages_for(seq.pos)
            if len(seq.pages) > keep:
                # a spec window was in flight for this slot: roll its
                # uncommitted pages back before the swap snapshot
                freed = self.page_pool.trim(seq.pages[keep:])
                if self.trie is not None:
                    for p in freed:
                        self.trie.drop(p)
                del seq.pages[keep:]
                self.block_tables[seq.slot, keep:] = PagePool.TRASH_PAGE
                self.stats["spec_window_preemptions"] += 1
                self.stats["spec_rollback_pages"] += len(freed)
        n = len(seq.pages)
        host = jax.device_get(
            self._gather_pages(self.pool, self._padded_ids(seq.pages)))
        seq.host_kv = (host, n)
        freed = self.page_pool.swap_out(seq.pages)
        if self.trie is not None:
            for p in freed:
                self.trie.drop(p)
        slot = seq.slot
        seq.pages = []
        self.block_tables[slot, :] = PagePool.TRASH_PAGE
        self._pos[slot] = 0
        self._tok[slot, 0] = 0
        self.sched.preempt(slot)
        self.preempt_log.append(seq.req.rid)
        self.stats["preemptions"] += 1
        # count pages that actually left the device — shared prefix pages
        # another sequence still references stay resident
        self.stats["swapped_out_pages"] += len(freed)

    def _swap_in(self, seq: SeqState) -> None:
        """Restore a preempted sequence: fresh pages, exact KV bytes."""
        host, n = seq.host_kv
        pages = self.page_pool.swap_in(n)
        self.pool = self._scatter_pages(
            self.pool, jax.tree_util.tree_map(jnp.asarray, host),
            self._padded_ids(pages))
        seq.host_kv = None
        seq.pages = pages
        self.sched.place_swapped(seq)
        self.block_tables[seq.slot, :] = PagePool.TRASH_PAGE
        self.block_tables[seq.slot, :n] = pages
        self._pos[seq.slot] = seq.pos
        self._tok[seq.slot, 0] = seq.generated[-1]
        self.stats["swapped_in_pages"] += n

    def _phase_capacity(self) -> None:
        """Capacity phase: grow every decoding sequence's pages out to
        the span the coming step will write — position ``pos`` for plain
        decode, ``[pos, min(pos + spec_k, seq_end - 1)]`` for a draft
        window (positions past ``seq_end`` redirect to the trash page, so
        the worst-case-reservation rule ``pages_for(seq_end)`` still
        bounds growth).  Under pressure, preemption evicts the youngest
        decoding sequence (possibly the needy one itself — re-checked per
        slot) instead of dying mid-decode; a preempted victim's own
        speculative pages are trimmed by :meth:`_preempt`, not swapped."""
        for slot in sorted(self.sched.active):
            seq = self.sched.active.get(slot)
            if seq is None or seq.phase is not SeqPhase.DECODING:
                continue
            need = self.page_pool.pages_for(
                min(seq.pos + self.spec_k + 1, self._seq_end(seq)))
            if need <= len(seq.pages):
                # in-place write: must be exclusive — only *complete*
                # prompt pages are ever shared, and decode writes land
                # strictly past them (the fully-shared boundary page is
                # forked during the recompute prefill tick)
                assert self.page_pool.ref_count(
                    seq.pages[seq.pos // self.page_size]) == 1, (
                    "decode write into shared page "
                    f"{seq.pages[seq.pos // self.page_size]}")
                continue
            ok = self._try_capacity(need - len(seq.pages))
            if self.sched.active.get(slot) is not seq:
                continue                     # the hunt preempted seq itself
            if not ok:
                raise PoolExhausted(
                    "pool exhausted with no preemptible sequence — "
                    "the pool cannot hold even one request")
            while len(seq.pages) < need:
                (pg,) = self.page_pool.alloc(1)
                seq.pages.append(pg)
                self.block_tables[slot, len(seq.pages) - 1] = pg

    # -- speculative decoding -------------------------------------------------
    def _trim_spec_pages(self, seq: SeqState) -> None:
        """Roll back pages allocated for rejected window positions: keep
        only what covers the committed prefix ``[0, pos)`` (never below
        the prompt bucket — ``pos > plen`` always) and return the rest to
        the pool via the refcount-aware :meth:`~repro.serving.pool.
        PagePool.trim`, so a sharer's rollback can never free a page the
        trie still maps (only pages whose last reference dropped leave
        the trie).  Stale KV beyond ``pos`` needs no scrubbing: the next
        window re-writes each position before any row can attend to it."""
        keep = self.page_pool.pages_for(seq.pos)
        if len(seq.pages) > keep:
            freed = self.page_pool.trim(seq.pages[keep:])
            if self.trie is not None:
                for p in freed:
                    self.trie.drop(p)
            del seq.pages[keep:]
            self.block_tables[seq.slot, keep:] = PagePool.TRASH_PAGE
            self.stats["spec_rollbacks"] += 1
            self.stats["spec_rollback_pages"] += len(freed)

    def _valid_lens(self) -> np.ndarray:
        """Per-slot write cutoffs for batched decode/draft/verify steps:
        a decoding slot may write up to its ``seq_end``; prefilling and
        idle slots get 0 (every write redirects to the trash page), which
        is what lets one batched step span a partially-prefilled batch
        without host-side block-table masking."""
        valid = np.zeros(self.max_slots, np.int32)
        for slot, seq in self.sched.active.items():
            if seq.phase is SeqPhase.DECODING:
                valid[slot] = self._seq_end(seq)
        return valid

    def _spec_window(self, decoding: dict[int, SeqState],
                     ) -> list[tuple[int, int]]:
        """One propose/verify/accept window for every decoding slot.

        The draft tier runs ``spec_k`` batched decode steps ahead (its KV
        goes to the parallel draft pool), then one batched verify pass
        scores the whole window ``[committed token, d_1, ..., d_k]`` with
        the target weights.  Per slot, the longest draft prefix matching
        the target's greedy tokens is accepted plus one bonus target
        token — every emission is the *target's* argmax, so the output
        equals sequential greedy decode token-for-token; rejected
        positions' pages roll back via :meth:`_trim_spec_pages`.  Slots
        mid-chunked-prefill ride along with write cutoff 0: their rows
        write to the trash page and their outputs are discarded, so a
        window can run while another slot's prompt is still streaming in.
        """
        k = self.spec_k
        btj = jnp.asarray(self.block_tables)
        valid = jnp.asarray(self._valid_lens())
        d_tok = self._tok.copy()
        d_pos = self._pos.copy()
        drafts = np.zeros((self.max_slots, k), np.int32)
        # k + 1 steps: step j < k proposes d_{j+1}; the extra step only
        # backfills draft KV for position pos + k, which full acceptance
        # commits without another draft read of it this window — skipping
        # it leaves stale pad KV behind the next window's proposals
        with self.tracer.span("draft", track="spec", k=k,
                              slots=len(decoding)):
            for j in range(k + 1):
                nxt, _, self.draft_pool = self._draft_decode(
                    self.draft_params, self.draft_pool, btj,
                    jnp.asarray(d_tok), jnp.asarray(d_pos), valid)
                if j == k:
                    break
                col = np.asarray(nxt).reshape(self.max_slots, -1)[:, 0]
                drafts[:, j] = col
                d_tok[:, 0] = col
                d_pos += 1

        v_tok = np.zeros((self.max_slots, k + 1), np.int32)
        v_tok[:, 0] = self._tok[:, 0]
        v_tok[:, 1:] = drafts
        with self.tracer.span("verify", track="spec", k=k,
                              slots=len(decoding)):
            nxt, _, self.pool = self._verify(
                self.params, self.pool, btj, jnp.asarray(v_tok),
                jnp.asarray(self._pos), valid)
            target = np.asarray(nxt).reshape(self.max_slots, k + 1)

        events: list[tuple[int, int]] = []
        for slot, seq in list(decoding.items()):
            m = 0
            while m < k and drafts[slot, m] == target[slot, m]:
                m += 1
            e = min(m + 1, seq.remaining)
            emitted = [int(target[slot, i]) for i in range(e)]
            seq.generated.extend(emitted)
            seq.pos += e
            seq.spec_proposed += k
            seq.spec_accepted += min(m, e)
            self.stats["spec_windows"] += 1
            self.stats["draft_proposed"] += k
            self.stats["draft_accepted"] += min(m, e)
            self._pos[slot] = seq.pos
            self._tok[slot, 0] = emitted[-1]
            events += [(seq.req.rid, t) for t in emitted]
            if seq.remaining == 0:
                self._complete(slot)
            else:
                self._trim_spec_pages(seq)
        return events

    # -- stepping: the per-step phase pipeline --------------------------------
    def _phase_admission(self, now: int) -> list[tuple[int, int]]:
        """Admission phase: resume swapped sequences first (they were
        admitted before anyone still pending), then admit queue heads
        while a slot and pages are free.  Fused-prefill admission emits
        the first token immediately; chunked admission places the slot in
        the prefilling phase for :meth:`_phase_prefill` to advance."""
        now_wall = time.perf_counter()
        # latency clock starts when a request becomes admissible, not when
        # it reaches the queue head — queue wait is part of tail latency
        for r in self.sched.pending:
            if r.arrival > now:
                break                        # pending is arrival-sorted
            self._first_seen.setdefault(r.rid, now_wall)
        events: list[tuple[int, int]] = []
        if self.paged:
            # swapped sequences were admitted first: resume before anyone
            while self.sched.swapped and self.sched.has_free_slot():
                seq = self.sched.peek_swapped()
                if not self._provide(seq.host_kv[1]):
                    break
                self._swap_in(seq)
        while self.sched.has_free_slot():
            if self.paged and self.sched.swapped:
                break                        # no admission past a swapped seq
            req = self.sched.peek_ready(now)
            if req is None:
                break
            if self.paged:
                # one trie walk per admission attempt, shared between the
                # capacity check and the admission itself
                share = (self._share_plan(req) if self.prefill_chunk
                         else None)
                if not self._can_admit(req, share):
                    break
                if self.prefill_chunk:
                    events += self._admit_chunked(req, share)
                else:
                    events += self._admit_paged(req)
            else:
                events += self._admit_state(req)
        return events

    def _phase_prefill(self) -> list[tuple[int, int]]:
        """Prefill phase: advance one chunk for every prefilling slot.
        A slot stays excluded from decode and draft windows (write cutoff
        0) until its final chunk delivers the first token."""
        events: list[tuple[int, int]] = []
        for seq in list(self.sched.active.values()):
            if seq.phase is SeqPhase.PREFILLING:
                events += self._prefill_tick(seq)
        return events

    def _phase_decode(self, decoding: dict[int, SeqState],
                      ) -> list[tuple[int, int]]:
        """Verify/decode + commit phase, non-speculative: one ragged
        batched decode step; every decoding slot commits one token.
        Prefilling/idle rows ride along with write cutoff 0 (paged) or an
        untouched slot cache (recurrent)."""
        events: list[tuple[int, int]] = []
        tok = jnp.asarray(self._tok)
        pos = jnp.asarray(self._pos)
        if self.paged:
            nxt, _, self.pool = self._decode(
                self.params, self.pool, jnp.asarray(self.block_tables),
                tok, pos, jnp.asarray(self._valid_lens()))
        else:
            nxt, _, self.cache = self._decode(
                self.params, self.cache, tok, pos)
        nxt = np.asarray(nxt).reshape(self.max_slots, -1)[:, 0]
        for slot, seq in list(decoding.items()):
            t = int(nxt[slot])
            seq.generated.append(t)
            seq.pos += 1
            self._pos[slot] = seq.pos
            self._tok[slot, 0] = t
            events.append((seq.req.rid, t))
            if seq.remaining == 0:
                self._complete(slot)
        return events

    def step(self) -> list[tuple[int, int]]:
        """Advance virtual time one step through the phase pipeline:
        admission (resume + admit) → prefill (chunk ticks) → capacity
        (page growth, preempting under pressure) → draft window →
        verify/decode → commit/rollback.  Feature flags select phase
        implementations — every combination of chunked prefill,
        preemption, prefix sharing, and speculative decoding runs through
        this one pipeline.  Returns (rid, token) emissions."""
        tr = self.tracer
        with tr.span("step", track="engine", step=self._step_idx):
            with tr.span("admission", track="engine"):
                events = self._phase_admission(self._step_idx)
            if self.paged:
                if self.prefill_chunk:
                    with tr.span("prefill", track="engine"):
                        events += self._phase_prefill()
                with tr.span("capacity", track="engine"):
                    self._phase_capacity()
            decoding = {slot: seq for slot, seq in self.sched.active.items()
                        if seq.phase is SeqPhase.DECODING}
            if decoding:
                if self.spec_k:
                    with tr.span("spec_window", track="engine"):
                        events += self._spec_window(decoding)
                else:
                    with tr.span("decode", track="engine"):
                        events += self._phase_decode(decoding)
            if self.paged:
                self._sample_pool()
        self._step_idx += 1
        return events

    def _sample_pool(self) -> None:
        """Record page-pool occupancy (free/live/swapped) as gauges and,
        when tracing, one sample on the ``pool`` counter track."""
        occ = self.page_pool.occupancy()
        swapped = sum(s.host_kv[1] for s in self.sched.swapped
                      if s.host_kv is not None)
        self.metrics.gauge("pool_free_pages", occ["free"])
        self.metrics.gauge("pool_live_pages", occ["live"])
        self.metrics.gauge("pool_swapped_pages", swapped)
        if self.prefix_cache is not None:
            self.metrics.gauge("pool_cached_pages",
                               len(self.prefix_cache.held_pages))
            self._sync_cache_stats()
        if self.tracer.enabled:
            self.tracer.counter(
                "pool_pages", {"free": occ["free"], "live": occ["live"],
                               "swapped": swapped}, track="pool")

    def _sync_cache_stats(self) -> None:
        """Mirror the cache's tier accounting into the stats dict (the
        per-tier byte counters land in ``BENCH_serving.json``)."""
        c = self.prefix_cache
        tiers = c.bytes_by_tier()
        self.stats["prefix_bytes_hbm"] = tiers["hbm"]
        self.stats["prefix_bytes_host"] = tiers["host"]
        self.stats["prefix_bytes_disk"] = tiers["disk"]
        self.stats["prefix_demotions_host"] = c.demotions_host
        self.stats["prefix_demotions_disk"] = c.demotions_disk

    def flush_prefix_cache(self) -> None:
        """Demote every HBM-resident cache entry — the drain path.  On an
        idle engine this returns the pool to fully-free and empties the
        trie; host/disk copies persist, so identical prompts submitted
        later (or to a fresh engine sharing the cache dir) still promote
        instead of re-prefilling."""
        if self.prefix_cache is not None:
            self.prefix_cache.flush()
            self._sync_cache_stats()

    # -- warmup / run ---------------------------------------------------------
    def warmup(self) -> float:
        """Pre-compile the union of jitted shapes the composed feature
        set can reach on the queued trace — prefill buckets or chunk
        shapes (target and draft tiers alike), the write-cutoff-gated
        batched decode, COW page copies, swap gathers/scatters, and
        draft/verify windows — so steady-state throughput excludes
        compile time.  Results are discarded — no engine state changes."""
        with self.tracer.span("warmup", track="engine"):
            return self._warmup_impl()

    def _warmup_impl(self) -> float:
        t0 = time.perf_counter()
        if self.paged:
            if self.prefill_chunk:
                trash_row = jnp.full((1, self.max_pages),
                                     PagePool.TRASH_PAGE, jnp.int32)
                logits, _ = self._chunk_prefill(
                    self.params, self.pool, trash_row,
                    jnp.zeros((1, self.prefill_chunk), jnp.int32),
                    jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32))
                jax.block_until_ready(logits)
            else:
                buckets = sorted({self._bucket(len(r.tokens))
                                  for r in self.sched.pending})
                for b in buckets:
                    logits, cache = self._prefill(
                        self.params,
                        {"tokens": jnp.zeros((1, b), jnp.int32)})
                    trash = np.full(b // self.page_size,
                                    PagePool.TRASH_PAGE, np.int32)
                    jax.block_until_ready(self._page_write(
                        self.pool, cache, jnp.asarray(trash))["k"])
                    jax.block_until_ready(logits)
            if self.prefix_sharing:
                jax.block_until_ready(self._copy_page(
                    self.pool, jnp.asarray(0, jnp.int32),
                    jnp.asarray(0, jnp.int32))["k"])
            if self.prefix_cache is not None:
                zero = jnp.asarray(PagePool.TRASH_PAGE, jnp.int32)
                snap = self._page_get(self.pool, zero)
                jax.block_until_ready(snap["k"])
                jax.block_until_ready(
                    self._page_set(self.pool, snap, zero)["k"])
            if self.preemption:
                ids = jnp.zeros(self.max_pages, jnp.int32)
                snap = self._gather_pages(self.pool, ids)
                jax.block_until_ready(snap["k"])
                jax.block_until_ready(
                    self._scatter_pages(self.pool, snap, ids)["k"])
            out = self._decode(
                self.params, self.pool, jnp.asarray(self.block_tables),
                jnp.asarray(self._tok), jnp.asarray(self._pos),
                jnp.zeros(self.max_slots, jnp.int32))
            jax.block_until_ready(out[0])
            if self.spec_k:
                if self.prefill_chunk:
                    # draft prompt KV streams in per chunk — same chunk
                    # shape as the target tier, draft weights
                    trash_row = jnp.full((1, self.max_pages),
                                         PagePool.TRASH_PAGE, jnp.int32)
                    dlogits, _ = self._draft_chunk_prefill(
                        self.draft_params, self.draft_pool, trash_row,
                        jnp.zeros((1, self.prefill_chunk), jnp.int32),
                        jnp.asarray(0, jnp.int32),
                        jnp.asarray(0, jnp.int32))
                    jax.block_until_ready(dlogits)
                else:
                    for b in sorted({self._bucket(len(r.tokens))
                                     for r in self.sched.pending}):
                        _, dcache = self._draft_prefill(
                            self.draft_params,
                            {"tokens": jnp.zeros((1, b), jnp.int32)})
                        trash = np.full(b // self.page_size,
                                        PagePool.TRASH_PAGE, np.int32)
                        jax.block_until_ready(self._page_write(
                            self.draft_pool, dcache,
                            jnp.asarray(trash))["k"])
                out = self._draft_decode(
                    self.draft_params, self.draft_pool,
                    jnp.asarray(self.block_tables), jnp.asarray(self._tok),
                    jnp.asarray(self._pos),
                    jnp.zeros(self.max_slots, jnp.int32))
                jax.block_until_ready(out[0])
                out = self._verify(
                    self.params, self.pool, jnp.asarray(self.block_tables),
                    jnp.zeros((self.max_slots, self.spec_k + 1), jnp.int32),
                    jnp.asarray(self._pos),
                    jnp.zeros(self.max_slots, jnp.int32))
                jax.block_until_ready(out[0])
        else:
            sub = self.model.init_cache(1, self.max_len)
            out = self._decode(self.params, sub,
                               jnp.zeros((1, 1), jnp.int32),
                               jnp.asarray(0, jnp.int32))
            jax.block_until_ready(out[0])
            jax.block_until_ready(jax.tree_util.tree_leaves(
                self._write_slot(self.cache, sub, jnp.asarray(0)))[0])
            out = self._decode(self.params, self.cache,
                               jnp.asarray(self._tok),
                               jnp.asarray(self._pos))
            jax.block_until_ready(out[0])
        dt = time.perf_counter() - t0
        self.stats["warmup_s"] += dt
        return dt

    def run(self, requests: list[Request] | None = None, *,
            warmup: bool = True, max_steps: int | None = None) -> dict:
        """Drive the engine until every submitted request completes.

        Returns ``{"tokens": {rid: [...]}, "stats": {...}}`` with
        compile/warmup time reported separately from steady-state
        throughput (tokens/sec over the post-warmup serving loop).
        """
        for r in requests or []:
            self.submit(r)
        if warmup:
            self.warmup()
        if max_steps is None:
            max_steps = (max((r.arrival for r in self._submitted), default=0)
                         + sum(r.max_new for r in self._submitted)
                         + self.max_slots + 16)
            if self.paged and self.prefill_chunk:
                max_steps += sum(
                    -(-len(r.tokens) // self.prefill_chunk) + 1
                    for r in self._submitted)
            if self.paged and self.preemption:
                max_steps *= 2               # slack for swap cycles
        t0 = time.perf_counter()
        n_tok = 0
        start = self._step_idx
        while not self.sched.done:
            if self._step_idx - start > max_steps:
                raise RuntimeError(
                    f"engine stalled: {len(self.sched.pending)} pending / "
                    f"{len(self.sched.active)} active / "
                    f"{len(self.sched.swapped)} swapped after "
                    f"{max_steps} steps")
            n_tok += len(self.step())
        if self.paged and self.prefix_cache is not None:
            # final completions' demotions happen inside the last step;
            # re-sync so the returned stats carry the end-state tiers
            self._sync_cache_stats()
        steady_s = time.perf_counter() - t0
        fin = list(self._finished.values())
        lat = sorted(s.done_wall - s.ready_wall for s in fin)
        queue = [s.admitted_wall - s.ready_wall for s in fin]
        ttft = [s.first_token_wall - s.ready_wall for s in fin]
        tpot = [(s.done_wall - s.first_token_wall)
                / max(len(s.generated) - 1, 1) for s in fin]

        def _pct(vals: list[float], q: float) -> float:
            return round(float(np.percentile(vals, q)), 6) if vals else 0.0

        self.stats.update({
            "steps": self._step_idx - start,
            "completed": len(self._finished),
            "generated_tokens": n_tok,
            "tokens_per_step": round(
                n_tok / max(self._step_idx - start, 1), 4),
            "acceptance_rate": round(
                self.stats["draft_accepted"]
                / max(self.stats["draft_proposed"], 1), 4),
            "steady_s": round(steady_s, 4),
            "steady_tok_per_s": round(n_tok / max(steady_s, 1e-9), 2),
            "p50_latency_s": round(float(np.percentile(lat, 50)), 4)
            if lat else 0.0,
            "p99_latency_s": round(float(np.percentile(lat, 99)), 4)
            if lat else 0.0,
            "queue_wait_p50_s": _pct(queue, 50),
            "queue_wait_p99_s": _pct(queue, 99),
            "ttft_p50_s": _pct(ttft, 50),
            "ttft_p99_s": _pct(ttft, 99),
            "tpot_p50_s": _pct(tpot, 50),
            "tpot_p99_s": _pct(tpot, 99),
        })
        return {"tokens": {rid: list(s.generated)
                           for rid, s in sorted(self._finished.items())},
                "stats": dict(self.stats)}


# ---------------------------------------------------------------------------
# static-batch reference
# ---------------------------------------------------------------------------
# jit caches key on function identity, so building fresh closures per
# request would recompile identical shapes every call (the reference runs
# once per request per bench variant).  Keyed by object ids, which is safe
# here because the cached closures keep model/plan alive — their ids can't
# be recycled while an entry exists.
_STATIC_FNS: dict[tuple[int, int], tuple] = {}


def _static_fns(model: LM, plan):
    key = (id(model), id(plan))
    if key not in _STATIC_FNS:
        _STATIC_FNS[key] = (
            jax.jit(steps_mod.make_decode_step(model, plan=plan)),
            jax.jit(steps_mod.make_prefill_step(model, plan=plan)),
        )
    return _STATIC_FNS[key]


def static_generate(model: LM, params: Params, req: Request,
                    max_len: int | None = None, plan=None) -> list[int]:
    """Per-request static-batch greedy generation — the reference the
    engine must match token-for-token.  Mirrors the classic serve path:
    fused prefill for attention families, prompt replay through the
    batch-1 decode step for recurrent families."""
    cfg = model.cfg
    prompt = jnp.asarray(req.tokens, jnp.int32)[None]
    plen = prompt.shape[1]
    if max_len is None:
        max_len = plen + req.max_new
    decode, prefill = _static_fns(model, plan)
    if cfg.family in ("hybrid", "ssm"):
        cache = model.init_cache(1, max_len)
        nxt = None
        for t in range(plen):
            nxt, _, cache = decode(params, cache, prompt[:, t:t + 1],
                                   jnp.asarray(t, jnp.int32))
        first = int(np.asarray(nxt).reshape(-1)[0])
    else:
        nxt, cache = prefill(params, {"tokens": prompt})
        cache = model.grow_cache(cache, max_len)
        first = int(np.asarray(nxt).reshape(-1)[0])
    out = [first]
    tok = jnp.full((1, 1), first, jnp.int32)
    for t in range(req.max_new - 1):
        nxt, _, cache = decode(params, cache, tok,
                               jnp.asarray(plen + t, jnp.int32))
        out.append(int(np.asarray(nxt).reshape(-1)[0]))
        tok = jnp.asarray(nxt, jnp.int32).reshape(1, 1)
    return out
