"""Synthetic request traces for the serving engine.

Arrivals follow a Poisson process on the engine's *step* clock (exponential
inter-arrival times at ``rate`` requests/step, accumulated and floored), so
a trace replays deterministically for a given seed regardless of wall-clock
speed — the property the engine-vs-static equality gates rely on.  Prompt
and generation lengths are drawn uniformly from ``[max//2, max]``, giving
the ragged mix (staggered arrivals, mixed lengths) continuous batching
exists to serve.
"""
from __future__ import annotations

import numpy as np

from repro.serving.scheduler import Request


def poisson_trace(
    n_requests: int,
    rate: float,
    max_prompt: int,
    max_new: int,
    vocab: int,
    seed: int = 0,
) -> list[Request]:
    """``n_requests`` requests with Poisson(``rate``/step) arrivals."""
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    arrivals = np.floor(np.cumsum(rng.exponential(1.0 / rate, n_requests))
                        ).astype(int)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(max(1, max_prompt // 2), max_prompt + 1))
        gen = int(rng.integers(max(1, max_new // 2), max_new + 1))
        reqs.append(Request(
            rid=i,
            tokens=rng.integers(0, vocab, plen, dtype=np.int32),
            max_new=gen,
            arrival=int(arrivals[i]),
        ))
    return reqs


def shared_prefix_trace(
    n_requests: int,
    prefix_len: int,
    max_prompt: int,
    max_new: int,
    vocab: int,
    seed: int = 0,
    arrival_gap: int = 1,
) -> list[Request]:
    """Requests sharing one common prompt prefix — the few-shot-template
    workload prefix sharing exists for.

    Every prompt is the same ``prefix_len`` tokens followed by a
    per-request random suffix (lengths drawn from
    ``[max(prefix_len + 1, max_prompt // 2), max_prompt]``); arrivals are
    spaced ``arrival_gap`` engine steps apart, so earlier requests'
    prefix pages are prefilled (and trie-registered) before later ones
    look them up.  Deterministic for a given seed.
    """
    if not 0 < prefix_len < max_prompt:
        raise ValueError(
            f"need 0 < prefix_len < max_prompt, got {prefix_len} / "
            f"{max_prompt}")
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, prefix_len, dtype=np.int32)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(max(prefix_len + 1, max_prompt // 2),
                                max_prompt + 1))
        suffix = rng.integers(0, vocab, plen - prefix_len, dtype=np.int32)
        gen = int(rng.integers(max(1, max_new // 2), max_new + 1))
        reqs.append(Request(
            rid=i,
            tokens=np.concatenate([prefix, suffix]),
            max_new=gen,
            arrival=i * arrival_gap,
        ))
    return reqs


def repeated_prompt_trace(
    n_requests: int,
    prefix_len: int,
    suffix_len: int,
    max_new: int,
    vocab: int,
    page_size: int,
    seed: int = 0,
    arrival_gap: int = 1,
    rid_base: int = 0,
) -> list[Request]:
    """One epoch of the prefix-cache workload: page-aligned prompts that
    repeat *verbatim* across epochs.

    Every prompt is the same ``prefix_len``-token system prompt plus a
    per-request ``suffix_len``-token suffix, with the total forced to a
    multiple of ``page_size``.  Page alignment is what lets a repeated
    prompt resolve entirely from cached pages on its second epoch: a
    prompt's unaligned tail page is never trie-registered, so it would
    re-prefill every time.  Calling twice with the same seed and a
    different ``rid_base`` yields two identical epochs with fresh request
    ids — the workload behind the second-epoch zero-fresh-prefill gate
    (``docs/caching.md``).  Deterministic for a given seed.
    """
    if prefix_len < 1 or suffix_len < 1:
        raise ValueError(
            f"need prefix_len >= 1 and suffix_len >= 1, got {prefix_len} / "
            f"{suffix_len}")
    if (prefix_len + suffix_len) % page_size:
        raise ValueError(
            f"prompt length {prefix_len + suffix_len} must be a multiple of "
            f"page_size={page_size} — unaligned tail pages never register "
            "in the trie, so the repeated epoch could not hit the cache")
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, prefix_len, dtype=np.int32)
    reqs = []
    for i in range(n_requests):
        suffix = rng.integers(0, vocab, suffix_len, dtype=np.int32)
        reqs.append(Request(
            rid=rid_base + i,
            tokens=np.concatenate([prefix, suffix]),
            max_new=max_new,
            arrival=i * arrival_gap,
        ))
    return reqs


def stress_spec_trace(
    n_requests: int,
    prefix_len: int,
    max_prompt: int,
    max_new: int,
    vocab: int,
    seed: int = 0,
    burst: int = 2,
    rate: float = 0.5,
) -> list[Request]:
    """High-pressure trace for the fully composed engine: shared prompt
    prefixes + bursty Poisson arrivals + mixed prompt lengths.

    Requests land in bursts of ``burst`` simultaneous arrivals, with
    Poisson(``rate``/step) gaps *between* bursts — bursts pile admission
    pressure onto a small pool (forcing preemption mid-window) while the
    shared ``prefix_len``-token prefix exercises the trie under
    speculative rollback.  Prompt lengths are drawn uniformly from
    ``[prefix_len + 1, max_prompt]`` (full mix, not the ``max//2`` floor
    of :func:`poisson_trace` — short and long prompts must coexist in one
    chunked-prefill schedule).  Deterministic for a given seed.
    """
    if not 0 < prefix_len < max_prompt:
        raise ValueError(
            f"need 0 < prefix_len < max_prompt, got {prefix_len} / "
            f"{max_prompt}")
    if burst < 1 or rate <= 0:
        raise ValueError(f"need burst >= 1 and rate > 0, got {burst} / "
                         f"{rate}")
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, prefix_len, dtype=np.int32)
    reqs = []
    arrival = 0
    for i in range(n_requests):
        if i and i % burst == 0:
            arrival += max(1, int(rng.exponential(1.0 / rate)))
        plen = int(rng.integers(prefix_len + 1, max_prompt + 1))
        suffix = rng.integers(0, vocab, plen - prefix_len, dtype=np.int32)
        gen = int(rng.integers(max(1, max_new // 2), max_new + 1))
        reqs.append(Request(
            rid=i,
            tokens=np.concatenate([prefix, suffix]),
            max_new=gen,
            arrival=arrival,
        ))
    return reqs
