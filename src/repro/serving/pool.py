"""Host-side page accounting for the paged KV cache.

The device half of the paged cache is a plain pytree of page arrays
(:func:`repro.models.attention.init_paged_pool` stacked per layer); this
module owns the *allocation* half: a free list of page ids plus the
invariants the engine's tests gate on — a page is never handed to two
sequences at once unless both hold an explicit reference, and every page
whose last reference is dropped returns to the pool.

Four capabilities layered on the free list:

* **refcounts** — prefix sharing maps one physical page into several
  sequences' block tables; :meth:`PagePool.retain` adds a reference and
  :meth:`PagePool.free` only recycles a page when its count hits zero.
* **copy-on-write forks** — a sequence about to *write* into a page it
  shares calls :meth:`PagePool.fork`: it gets a fresh private page id and
  drops its reference on the shared one (the engine copies the page's
  device bytes alongside).
* **swap accounting** — preemption moves a sequence's pages to host
  memory: :meth:`PagePool.swap_out` releases the ids (tallying how many
  actually left the device) and :meth:`PagePool.swap_in` re-allocates on
  resume.  The byte movement itself is the engine's job; the pool keeps
  the id bookkeeping and the counters CI gates on.
* **cache-tier retention** — the persistent prefix cache
  (:class:`repro.serving.prefix_cache.PrefixCache`) keeps completed
  prompt pages alive past sequence completion by holding one extra
  reference per retained page.  To the pool it is just another sharer:
  demotions go through :meth:`PagePool.free` (so a page shared with a
  live sequence stays resident), and the partition invariant
  ``free + live == n_pages - 1`` is untouched.  Retained pages whose
  only holder is the cache are *reclaimable* — admission counts them as
  free-able capacity and demotes them on demand (``docs/caching.md``).

Page 0 is reserved as the trash page: inactive engine slots point their
whole block table at it so their (ignored) per-step writes can never touch
a live sequence.  The allocator never hands it out.
"""
from __future__ import annotations

import collections
import dataclasses


class PoolExhausted(RuntimeError):
    """No free pages left — the trace needs a bigger pool (or admission
    should back off / preempt, which the engine's scheduler does)."""


class PagePool:
    """Refcounted free-list allocator over ``n_pages`` fixed-size KV pages."""

    TRASH_PAGE = 0

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self._free: collections.deque[int] = collections.deque(
            range(1, n_pages))
        self._refs: dict[int, int] = {}
        self.swapped_out_pages = 0
        self.swapped_in_pages = 0
        self.forks = 0
        self.trimmed_pages = 0

    @property
    def free_count(self) -> int:
        """Pages currently available to :meth:`alloc`."""
        return len(self._free)

    @property
    def allocated(self) -> frozenset[int]:
        """Ids of every page currently held (refcount >= 1)."""
        return frozenset(self._refs)

    def ref_count(self, page: int) -> int:
        """Holders of ``page`` (0 = free); >1 means prefix-shared."""
        return self._refs.get(page, 0)

    def occupancy(self) -> dict[str, int]:
        """Free vs live (refcount >= 1) page counts — one gauge sample.

        The engine records this each step onto the ``pool`` counter track
        and the ``pool_free_pages`` / ``pool_live_pages`` gauges.
        """
        return {"free": len(self._free), "live": len(self._refs)}

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache positions."""
        return -(-int(n_tokens) // self.page_size)

    def can_alloc(self, n: int) -> bool:
        """Whether :meth:`alloc` of ``n`` pages would succeed right now."""
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` pages off the free list.  All-or-nothing."""
        if n > len(self._free):
            raise PoolExhausted(
                f"asked for {n} pages, {len(self._free)} free "
                f"(pool of {self.n_pages})")
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            assert p not in self._refs, f"page {p} double-allocated"
            self._refs[p] = 1
        return pages

    def retain(self, pages: list[int]) -> None:
        """Add one reference per page (prefix sharing: a second sequence
        maps an already-live page into its block table)."""
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"retaining unallocated page {p}")
            self._refs[p] += 1

    def free(self, pages: list[int]) -> list[int]:
        """Drop one reference per page; pages whose count hits zero return
        to the free list and are reported back (so the engine can drop
        their prefix-trie entries).  Freeing a page that is not currently
        allocated (double free, or the reserved trash page) raises."""
        freed = []
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"freeing unallocated page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)
                freed.append(p)
        return freed

    def trim(self, pages: list[int]) -> list[int]:
        """Roll back speculatively grown pages (rejected draft-window
        positions, or draft state dropped on preemption).  Identical to
        :meth:`free` — refcounted, so trimming a sharer's reference on a
        prefix page another sequence (or the trie) still maps never
        recycles it — but tallied separately so the serving bench can
        gate that rollbacks actually happened."""
        freed = self.free(pages)
        self.trimmed_pages += len(freed)
        return freed

    def fork(self, page: int) -> int:
        """Copy-on-write fork: exchange the caller's reference on a shared
        ``page`` for a fresh private page id.  The caller must copy the
        device bytes itself before writing.  Forking a page the caller
        holds exclusively is a bug (just write in place)."""
        if self.ref_count(page) < 2:
            raise ValueError(
                f"fork of page {page} with refcount {self.ref_count(page)} "
                "— copy-on-write only applies to shared pages")
        (new,) = self.alloc(1)
        self._refs[page] -= 1
        self.forks += 1
        return new

    # -- preemption / swapping ------------------------------------------------
    def swap_out(self, pages: list[int]) -> list[int]:
        """Release a preempted sequence's pages.  Returns the ids that
        actually left the device (refcount hit zero) — shared prefix pages
        another sequence still references stay resident."""
        freed = self.free(pages)
        self.swapped_out_pages += len(freed)
        return freed

    def swap_in(self, n: int) -> list[int]:
        """Re-allocate ``n`` pages for a sequence resuming from host
        memory."""
        pages = self.alloc(n)
        self.swapped_in_pages += len(pages)
        return pages


# ---------------------------------------------------------------------------
# prefix sharing
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _TrieNode:
    tokens: tuple[int, ...]
    page: int
    parent: "_TrieNode | None"
    children: dict[tuple[int, ...], "_TrieNode"] = dataclasses.field(
        default_factory=dict)


class PrefixTrie:
    """Trie over page-sized prompt token chunks → live KV page ids.

    Each node keys one full page worth of tokens (children are hashed by
    the token tuple, so lookup is exact — no collision risk) and records
    the page holding that chunk's KV.  A chain root→node therefore names a
    shared prompt prefix whose KV is entirely resident; admission walks
    the new prompt down the trie and maps every matched page straight into
    the block table (:meth:`PagePool.retain`).

    The trie holds **no references of its own**: a node exists only while
    its page is allocated to at least one holder, and the engine calls
    :meth:`drop` for every page the pool reports as actually freed.
    Because every sharer references its *whole* prefix chain, a parent's
    refcount never falls below a child's — drops cascade leaf-first and a
    dangling interior node is unreachable by construction.

    The prefix cache preserves that ordering: it touches each completed
    chain leaf-first so a parent is always more recently used than every
    child, and its LRU demotions therefore also drop leaf-first (see
    ``docs/caching.md``).
    """

    def __init__(self, page_size: int):
        self.page_size = int(page_size)
        self._root = _TrieNode(tokens=(), page=PagePool.TRASH_PAGE,
                               parent=None)
        self._by_page: dict[int, _TrieNode] = {}

    def __len__(self) -> int:
        return len(self._by_page)

    def _chunks(self, tokens) -> list[tuple[int, ...]]:
        ps = self.page_size
        n_full = len(tokens) // ps
        return [tuple(int(t) for t in tokens[j * ps:(j + 1) * ps])
                for j in range(n_full)]

    def match(self, tokens) -> list[int]:
        """Longest registered prefix of ``tokens`` at whole-page
        granularity; returns the matched page ids in chain order."""
        node, out = self._root, []
        for chunk in self._chunks(tokens):
            child = node.children.get(chunk)
            if child is None:
                break
            out.append(child.page)
            node = child
        return out

    def register(self, tokens, pages: list[int], upto_page: int) -> None:
        """Record that ``pages[:upto_page]`` hold the KV of the first
        ``upto_page`` full pages of ``tokens`` (i.e. their prefill is
        complete).  Existing nodes win — if another sequence already
        registered a chunk, its page stays the canonical shared copy."""
        node = self._root
        for j, chunk in enumerate(self._chunks(tokens)[:upto_page]):
            child = node.children.get(chunk)
            if child is None:
                page = pages[j]
                if page in self._by_page:      # page already names a chunk
                    break
                child = _TrieNode(tokens=chunk, page=page, parent=node)
                node.children[chunk] = child
                self._by_page[page] = child
            node = child

    def drop(self, page: int) -> None:
        """Forget a freed page's node (no-op for unregistered pages)."""
        node = self._by_page.pop(page, None)
        if node is not None and node.parent is not None:
            if node.parent.children.get(node.tokens) is node:
                del node.parent.children[node.tokens]
