"""Host-side page accounting for the paged KV cache.

The device half of the paged cache is a plain pytree of page arrays
(:func:`repro.models.attention.init_paged_pool` stacked per layer); this
module owns the *allocation* half: a free list of page ids plus the
invariants the engine's tests gate on — a page is never handed to two
sequences at once, and every freed page returns to the pool.

Page 0 is reserved as the trash page: inactive engine slots point their
whole block table at it so their (ignored) per-step writes can never touch
a live sequence.  The allocator never hands it out.
"""
from __future__ import annotations

import collections


class PoolExhausted(RuntimeError):
    """No free pages left — the trace needs a bigger pool (or admission
    should back off, which the engine's scheduler does)."""


class PagePool:
    """Free-list allocator over ``n_pages`` fixed-size KV pages."""

    TRASH_PAGE = 0

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self._free: collections.deque[int] = collections.deque(
            range(1, n_pages))
        self._allocated: set[int] = set()

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def allocated(self) -> frozenset[int]:
        return frozenset(self._allocated)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache positions."""
        return -(-int(n_tokens) // self.page_size)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> list[int]:
        """Take ``n`` pages off the free list.  All-or-nothing."""
        if n > len(self._free):
            raise PoolExhausted(
                f"asked for {n} pages, {len(self._free)} free "
                f"(pool of {self.n_pages})")
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            assert p not in self._allocated, f"page {p} double-allocated"
        self._allocated.update(pages)
        return pages

    def free(self, pages: list[int]) -> None:
        """Return pages to the pool.  Freeing a page that is not currently
        allocated (double free, or the reserved trash page) raises."""
        for p in pages:
            if p not in self._allocated:
                raise ValueError(f"freeing unallocated page {p}")
            self._allocated.discard(p)
            self._free.append(p)
