"""Persistent multi-tier prefix cache: HBM -> host memory -> disk.

``PrefixTrie`` (PR 5) alone drops a prompt prefix the moment its last
sequence completes, so a popular system prompt re-prefills on every
arrival gap even though the page-swap machinery to keep it is already
built.  :class:`PrefixCache` keeps those trie-held pages alive past
sequence completion and tiers them down a memory hierarchy under an LRU
byte budget:

* **HBM** - the page stays resident in the device pool *and* in the
  trie; the cache holds one pool reference on it, so admission hits it
  through the ordinary trie walk with zero byte movement.  An LRU byte
  budget (``budget_bytes``) bounds this tier.
* **host** - when the budget overflows, the least-recently-used entry is
  *demoted*: its page bytes are gathered to host numpy arrays (the same
  per-page snapshot path preemption uses), the cache's pool reference is
  dropped (freeing the page when no live sequence still shares it), and
  the trie forgets the chunk.  A later hit re-allocates a page and
  scatters the bytes back - a *promotion* - skipping the re-prefill.
* **disk** - demotions write through to ``cache_dir/<sha256>.npz`` keyed
  by the *token-prefix content* (not page ids), so a freshly constructed
  engine pointed at the same directory resolves the same prompts with
  zero prefill compute: the cache survives restarts.

The cache owns policy and host/disk storage only.  Device byte movement
is delegated to the ``gather`` callback (the engine's jitted per-page
gather), and page-id bookkeeping stays in ``PagePool`` - the cache is
just another reference holder, so every pool invariant the test suite
gates on (free + live partition, refcount conservation) is unchanged.

Keys cover the *entire* token prefix up to and including a page-sized
chunk, so two prompts sharing a chunk's tokens but differing earlier can
never alias: the KV bytes of chunk *j* depend on all tokens ``< (j+1) *
page_size`` through attention, and the key hashes exactly those tokens.

See ``docs/caching.md`` for the tier diagram, the LRU/touch ordering
rationale, and the counter glossary.
"""

from __future__ import annotations

import hashlib
import os
from pathlib import Path
from typing import Callable

import numpy as np

__all__ = ["PrefixCache"]


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype string, falling back to ml_dtypes for bf16/fp8."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


class PrefixCache:
    """LRU-tiered retention of completed prompt prefix pages.

    Parameters
    ----------
    pool:
        The ``PagePool`` whose pages are being retained.  The cache holds
        at most one reference per page (idempotent ``hold``).
    page_bytes:
        KV bytes of one page across all layers/heads; the unit of the
        LRU budget and of ``bytes_by_tier`` accounting.
    budget_bytes:
        HBM-tier byte budget.  ``0`` keeps nothing resident: every
        ``hold`` demotes immediately (a pure host/disk cache).
    cache_dir:
        Optional directory for the disk tier.  When set, demotions write
        through to ``<sha256(prefix tokens)>.npz`` and a fresh engine
        pointed here inherits the spilled chunks.
    host_budget_bytes:
        Optional cap on the host tier; overflow drops the oldest host
        entries (their disk copies, if any, persist).
    gather:
        ``page_id -> {"k": ndarray, "v": ndarray}`` host snapshot of one
        live page.  Called at demotion time, while the page is still
        allocated.
    on_page_freed:
        Called with the page id whenever a demotion actually frees the
        page (refcount hit zero) - the engine passes ``PrefixTrie.drop``
        so the trie never points at a freed page.
    """

    def __init__(
        self,
        pool,
        page_bytes: int,
        *,
        budget_bytes: int = 0,
        cache_dir: str | os.PathLike | None = None,
        host_budget_bytes: int | None = None,
        gather: Callable[[int], dict] | None = None,
        on_page_freed: Callable[[int], None] | None = None,
    ):
        if page_bytes <= 0:
            raise ValueError(f"page_bytes must be positive, got {page_bytes}")
        if budget_bytes < 0:
            raise ValueError(f"budget_bytes must be >= 0, got {budget_bytes}")
        self.pool = pool
        self.page_bytes = int(page_bytes)
        self.budget_bytes = int(budget_bytes)
        self.host_budget_bytes = host_budget_bytes
        self.gather = gather
        self.on_page_freed = on_page_freed
        # Insertion order is LRU order: oldest first, most-recent last.
        self._hbm: dict[str, int] = {}
        self._page2key: dict[int, str] = {}
        self._host: dict[str, dict] = {}
        self.cache_dir: Path | None = None
        self._disk_index: set[str] = set()
        self._disk_bytes = 0
        self.demotions_host = 0
        self.demotions_disk = 0
        if cache_dir is not None:
            self.cache_dir = Path(cache_dir)
            self.cache_dir.mkdir(parents=True, exist_ok=True)
            for p in self.cache_dir.glob("*.npz"):
                self._disk_index.add(p.stem)
                self._disk_bytes += p.stat().st_size

    # ------------------------------------------------------------------
    # keys

    @staticmethod
    def key(tokens) -> str:
        """Content hash of a token prefix (sha256 over int64 token bytes).

        The caller passes *all* tokens up to and including the chunk
        being keyed, so the key pins the full attention context of the
        chunk's KV, never just the chunk's own tokens.
        """
        arr = np.ascontiguousarray(np.asarray(tokens, dtype=np.int64))
        return hashlib.sha256(arr.tobytes()).hexdigest()

    # ------------------------------------------------------------------
    # HBM tier

    @property
    def held_pages(self) -> tuple[int, ...]:
        """Pages currently retained in the HBM tier, LRU-first."""
        return tuple(self._hbm.values())

    @property
    def host_keys(self) -> tuple[str, ...]:
        """Keys currently resident in the host tier, LRU-first."""
        return tuple(self._host)

    def held(self, page: int) -> bool:
        """True when the cache holds a reference on ``page``."""
        return page in self._page2key

    def hold(self, key: str, page: int) -> None:
        """Retain ``page`` in the HBM tier under ``key`` (idempotent).

        Re-holding a page the cache already tracks is just an LRU touch.
        If ``key`` maps to a *different* page (the chunk was re-prefilled
        at a new page after its old entry became unreachable), the stale
        entry is released first - both pages carry identical bytes, so
        either is a valid cache of the chunk.
        """
        if page in self._page2key:
            self.touch(page)
            return
        stale = self._hbm.get(key)
        if stale is not None:
            del self._hbm[key]
            del self._page2key[stale]
            if self.pool.free([stale]) and self.on_page_freed is not None:
                self.on_page_freed(stale)
        self.pool.retain([page])
        self._hbm[key] = page
        self._page2key[page] = key
        # The HBM copy supersedes any host copy of the same chunk.
        self._host.pop(key, None)
        self._enforce()

    def touch(self, page: int) -> None:
        """Move a held page to the MRU end of the HBM tier."""
        key = self._page2key.get(page)
        if key is not None:
            self._hbm[key] = self._hbm.pop(key)

    def reclaimable(self) -> int:
        """HBM-tier pages only the cache still references.

        These can be demoted on demand to satisfy an allocation, so the
        scheduler's admission budget counts them as free-able capacity.
        """
        return sum(1 for p in self._hbm.values() if self.pool.ref_count(p) == 1)

    def reclaim(self, n: int) -> int:
        """Demote LRU single-reference entries until ``n`` pages freed.

        Returns the number of pages actually freed (may be < ``n`` when
        the HBM tier runs out of reclaimable entries).  Entries shared
        with a live sequence are skipped - demoting them would snapshot
        bytes but free nothing.
        """
        freed = 0
        for key in list(self._hbm):
            if freed >= n:
                break
            if self.pool.ref_count(self._hbm[key]) == 1 and self._demote(key):
                freed += 1
        return freed

    def flush(self) -> None:
        """Demote every HBM entry (drain: cache holds no pool pages)."""
        for key in list(self._hbm):
            self._demote(key)

    def _enforce(self) -> None:
        """Demote LRU entries until the HBM tier fits its byte budget."""
        while self._hbm and len(self._hbm) * self.page_bytes > self.budget_bytes:
            self._demote(next(iter(self._hbm)))

    def _demote(self, key: str) -> bool:
        """Move one HBM entry down a tier.

        Snapshots the page's bytes to the host tier (writing through to
        disk when configured), drops the cache's pool reference, and
        notifies ``on_page_freed`` if the page actually freed.  Returns
        True when the page left the device.
        """
        page = self._hbm.pop(key)
        del self._page2key[page]
        kv = {k: np.asarray(v) for k, v in self.gather(page).items()}
        self._host[key] = kv
        self.demotions_host += 1
        if self.cache_dir is not None:
            self._disk_write(key, kv)
        self._enforce_host()
        freed = self.pool.free([page])
        if freed and self.on_page_freed is not None:
            self.on_page_freed(page)
        return bool(freed)

    # ------------------------------------------------------------------
    # host + disk tiers

    def _enforce_host(self) -> None:
        """Drop oldest host entries past the host budget (disk persists)."""
        if self.host_budget_bytes is None:
            return
        while self._host and len(self._host) * self.page_bytes > self.host_budget_bytes:
            del self._host[next(iter(self._host))]

    def peek(self, key: str) -> str | None:
        """Non-consuming lower-tier lookup: ``"host"``, ``"disk"`` or None.

        Admission planning uses this to count how far a prompt's chunk
        chain extends through the cache before committing allocations.
        """
        if key in self._host:
            return "host"
        if self.cache_dir is not None and (key in self._disk_index or self._disk_path(key).exists()):
            return "disk"
        return None

    def fetch(self, key: str) -> tuple[dict, str] | None:
        """Consume a lower-tier entry: ``(kv arrays, tier name)`` or None.

        A host hit pops its entry - the promoting sequence re-registers
        the chunk in the trie, and its completion re-holds the new page,
        so the chunk re-enters the hierarchy from the top.  Disk files
        are never consumed; an unreadable file is treated as a miss.
        """
        kv = self._host.pop(key, None)
        if kv is not None:
            return kv, "host"
        if self.cache_dir is not None:
            kv = self._disk_read(key)
            if kv is not None:
                return kv, "disk"
        return None

    def _disk_path(self, key: str) -> Path:
        """Disk-tier file for ``key``."""
        return self.cache_dir / f"{key}.npz"

    def _disk_write(self, key: str, kv: dict) -> None:
        """Atomically persist one chunk (skipped when already on disk).

        Arrays are stored as raw uint8 views plus dtype-name sidecars so
        ml_dtypes types (bf16, fp8) survive the npz round trip.
        """
        path = self._disk_path(key)
        if key in self._disk_index or path.exists():
            self._disk_index.add(key)
            return
        payload = {}
        for name, arr in kv.items():
            a = np.ascontiguousarray(np.asarray(arr))
            payload[name] = a.view(np.uint8)
            payload[name + "_dtype"] = np.asarray(str(a.dtype))
        tmp = path.with_suffix(".npz.tmp")
        with open(tmp, "wb") as f:
            np.savez(f, **payload)
        os.replace(tmp, path)
        self._disk_index.add(key)
        self._disk_bytes += path.stat().st_size
        self.demotions_disk += 1

    def _disk_read(self, key: str) -> dict | None:
        """Load one chunk from disk; corrupt/missing files read as a miss."""
        path = self._disk_path(key)
        if not path.exists():
            self._disk_index.discard(key)
            return None
        try:
            with np.load(path) as f:
                out = {}
                for name in ("k", "v"):
                    out[name] = f[name].view(_np_dtype(str(f[name + "_dtype"])))
                return out
        except Exception:
            return None

    # ------------------------------------------------------------------
    # accounting

    def bytes_by_tier(self) -> dict[str, int]:
        """Bytes resident per tier: ``{"hbm", "host", "disk"}``.

        HBM and host count retained pages at ``page_bytes`` each; disk is
        the on-disk npz file sizes (including chunks inherited from a
        previous engine's run against the same directory).
        """
        return {
            "hbm": len(self._hbm) * self.page_bytes,
            "host": len(self._host) * self.page_bytes,
            "disk": self._disk_bytes,
        }
