"""Continuous-batching serving engine (paged KV cache + request
scheduler) over Sparse-on-Dense packed weights."""
from repro.serving.engine import Engine, bucket_len, static_generate
from repro.serving.pool import PagePool, PoolExhausted
from repro.serving.scheduler import Request, Scheduler, SeqState
from repro.serving.trace import poisson_trace

__all__ = [
    "Engine", "PagePool", "PoolExhausted", "Request", "Scheduler",
    "SeqState", "bucket_len", "poisson_trace", "static_generate",
]
