"""Continuous-batching serving engine (paged KV cache + request
scheduler, with chunked prefill, preemption/page swapping, copy-on-write
prefix sharing, and a persistent multi-tier prefix cache) over
Sparse-on-Dense packed weights."""
from repro.serving.engine import Engine, bucket_len, static_generate
from repro.serving.pool import PagePool, PoolExhausted, PrefixTrie
from repro.serving.prefix_cache import PrefixCache
from repro.serving.scheduler import Request, Scheduler, SeqState
from repro.serving.trace import (poisson_trace, repeated_prompt_trace,
                                 shared_prefix_trace, stress_spec_trace)

__all__ = [
    "Engine", "PagePool", "PoolExhausted", "PrefixCache", "PrefixTrie",
    "Request", "Scheduler", "SeqState", "bucket_len", "poisson_trace",
    "repeated_prompt_trace", "shared_prefix_trace", "static_generate",
    "stress_spec_trace",
]
