"""Continuous-batching serving engine (paged KV cache + request
scheduler, with chunked prefill, preemption/page swapping, and
copy-on-write prefix sharing) over Sparse-on-Dense packed weights."""
from repro.serving.engine import Engine, bucket_len, static_generate
from repro.serving.pool import PagePool, PoolExhausted, PrefixTrie
from repro.serving.scheduler import Request, Scheduler, SeqState
from repro.serving.trace import (poisson_trace, shared_prefix_trace,
                                 stress_spec_trace)

__all__ = [
    "Engine", "PagePool", "PoolExhausted", "PrefixTrie", "Request",
    "Scheduler", "SeqState", "bucket_len", "poisson_trace",
    "shared_prefix_trace", "static_generate", "stress_spec_trace",
]
