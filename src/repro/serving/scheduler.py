"""Request scheduler: admission queue + slot assignment + completion.

The scheduler owns the *who runs where* state of the engine: a FIFO
admission queue ordered by arrival step, the map of engine slots to
running sequences, and the free-slot list.  It is deliberately free of
any device state — the engine asks it what to admit, tells it what
completed, and keeps the page pool / cache arrays itself.
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np


@dataclasses.dataclass
class Request:
    """One inference request: a prompt and a generation budget.

    ``arrival`` is in engine *steps* (virtual time) — the trace generator
    produces Poisson arrivals on this clock and the engine admits a
    request once its arrival step is reached and a slot + pages are free.
    """

    rid: int
    tokens: np.ndarray            # (S,) int prompt token ids
    max_new: int                  # generation budget (incl. prefill token)
    arrival: int = 0

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new < 1:
            raise ValueError(f"request {self.rid}: max_new must be >= 1")


@dataclasses.dataclass
class SeqState:
    """Book-keeping for one running sequence in an engine slot."""

    req: Request
    slot: int
    pos: int                      # next cache position to write
    generated: list[int]
    pages: list[int]              # paged families: allocated page ids
    ready_wall: float = 0.0       # wall clock when first admissible
    done_wall: float = 0.0

    @property
    def remaining(self) -> int:
        return self.req.max_new - len(self.generated)


class Scheduler:
    """FIFO admission + slot assignment over ``max_slots`` engine slots.

    Head-of-line order is strict: if the oldest admissible request does
    not fit (no slot, or the engine reports no pages), nothing younger
    jumps it — keeps engine-vs-static token equality trivially auditable.
    """

    def __init__(self, max_slots: int):
        self.max_slots = int(max_slots)
        self._pending: list[Request] = []      # sorted by (arrival, rid)
        self.active: dict[int, SeqState] = {}  # slot -> running sequence
        self._free_slots: list[int] = list(range(max_slots))[::-1]

    # -- admission queue ------------------------------------------------------
    def submit(self, req: Request) -> None:
        bisect.insort(self._pending, req,
                      key=lambda r: (r.arrival, r.rid))

    @property
    def pending(self) -> tuple[Request, ...]:
        return tuple(self._pending)

    def peek_ready(self, now_step: int) -> Request | None:
        """Oldest request whose arrival step has been reached."""
        if self._pending and self._pending[0].arrival <= now_step:
            return self._pending[0]
        return None

    def has_free_slot(self) -> bool:
        return bool(self._free_slots)

    def place(self, req: Request, *, pos: int, first_token: int,
              pages: list[int], ready_wall: float) -> SeqState:
        """Admit the queue head into a free slot."""
        assert self._pending and self._pending[0].rid == req.rid
        self._pending.pop(0)
        slot = self._free_slots.pop()
        seq = SeqState(req=req, slot=slot, pos=pos,
                       generated=[first_token], pages=pages,
                       ready_wall=ready_wall)
        self.active[slot] = seq
        return seq

    def release(self, slot: int) -> SeqState:
        """Eviction on completion: free the slot, hand back the state."""
        seq = self.active.pop(slot)
        self._free_slots.append(slot)
        return seq

    @property
    def done(self) -> bool:
        return not self._pending and not self.active
