"""Request scheduler: admission queue + slot assignment + preemption.

The scheduler owns the *who runs where* state of the engine: a FIFO
admission queue ordered by arrival step, the map of engine slots to
running sequences, the free-slot list, and the queue of sequences
preempted to host memory (swapped out) awaiting resume.  It is
deliberately free of any device state — the engine asks it what to admit,
tells it what completed or got evicted, and keeps the page pool / cache
arrays itself.

Admission capacity is likewise the engine's call: with the persistent
prefix cache enabled, the engine's admission rule counts cache-retained
pages whose only holder is the cache as *reclaimable* — head-of-line
order stays strict, but a queue head blocked only by cold cached pages
admits by demoting them (see ``docs/caching.md``).
"""
from __future__ import annotations

import bisect
import dataclasses
import enum
import time
from typing import Any

import numpy as np

from repro import obs


class SeqPhase(enum.Enum):
    """Lifecycle of a sequence after admission — the single source of
    truth the engine's phase pipeline branches on.

    ``PREFILLING``: chunked prefill in flight, no first token yet — the
    slot takes no decode/draft steps (its write cutoff is 0) and is
    excluded from speculative windows.  ``DECODING``: emitting tokens;
    eligible for decode steps, draft windows, and preemption.
    ``SWAPPED``: preempted to host memory, queued for resume.  ``DONE``:
    released (generation budget exhausted).
    """

    PREFILLING = "prefilling"
    DECODING = "decoding"
    SWAPPED = "swapped"
    DONE = "done"


@dataclasses.dataclass
class Request:
    """One inference request: a prompt and a generation budget.

    ``arrival`` is in engine *steps* (virtual time) — the trace generator
    produces Poisson arrivals on this clock and the engine admits a
    request once its arrival step is reached and a slot + pages are free.
    """

    rid: int
    tokens: np.ndarray            # (S,) int prompt token ids
    max_new: int                  # generation budget (incl. prefill token)
    arrival: int = 0

    def __post_init__(self):
        self.tokens = np.asarray(self.tokens, np.int32).reshape(-1)
        if self.tokens.size == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new < 1:
            raise ValueError(f"request {self.rid}: max_new must be >= 1")

    @property
    def priority(self) -> tuple[int, int]:
        """FIFO priority: earlier arrival (then lower rid) ranks higher.
        Preemption evicts the *lowest*-priority running sequence, i.e. the
        max of this key — the youngest arrival backs off first, so the
        oldest requests always make progress."""
        return (self.arrival, self.rid)


@dataclasses.dataclass
class SeqState:
    """Book-keeping for one running sequence in an engine slot."""

    req: Request
    slot: int
    pos: int                      # next decode cache position to write
    generated: list[int]
    pages: list[int]              # paged families: allocated page ids
    prefilled: int = 0            # prompt tokens whose KV is resident
    phase: SeqPhase = SeqPhase.DECODING
    host_kv: Any = None           # swapped-out KV snapshot (host arrays)
    ready_wall: float = 0.0       # wall clock when first admissible
    admitted_wall: float = 0.0    # wall clock when placed into a slot
    first_token_wall: float = 0.0  # wall clock when the first token exists
    done_wall: float = 0.0
    spec_proposed: int = 0        # draft tokens proposed for this sequence
    spec_accepted: int = 0        # draft tokens that became emitted tokens
    cached_prompt_pages: int = 0  # prompt pages served by the prefix cache
    #                               (HBM holds + host/disk promotions)

    @property
    def remaining(self) -> int:
        """Generation budget left (``max_new`` minus tokens emitted)."""
        return self.req.max_new - len(self.generated)

    @property
    def is_prefilling(self) -> bool:
        """Chunked prefill in flight: no first token yet, so the slot must
        not decode (its per-row write cutoff is 0)."""
        return self.phase is SeqPhase.PREFILLING


class Scheduler:
    """FIFO admission + slot assignment over ``max_slots`` engine slots.

    Head-of-line order is strict: if the oldest admissible request does
    not fit (no slot, or the engine reports no pages), nothing younger
    jumps it — keeps engine-vs-static token equality trivially auditable.
    Sequences preempted under pool pressure queue in ``swapped`` and
    resume ahead of any pending newcomer (they were admitted first).
    """

    def __init__(self, max_slots: int, tracer=None):
        self.max_slots = int(max_slots)
        self.tracer = tracer if tracer is not None else obs.get_tracer()
        self._pending: list[Request] = []      # sorted by (arrival, rid)
        self.active: dict[int, SeqState] = {}  # slot -> running sequence
        self._swapped: list[SeqState] = []     # sorted by priority
        self._free_slots: list[int] = list(range(max_slots))[::-1]

    def set_phase(self, seq: SeqState, phase: SeqPhase) -> None:
        """Move ``seq`` to ``phase``, emitting the transition as an
        instant event on the ``lifecycle`` trace track."""
        seq.phase = phase
        self.tracer.instant(f"rid{seq.req.rid}:{phase.value}",
                            track="lifecycle", cat="phase",
                            rid=seq.req.rid, slot=seq.slot)

    # -- admission queue ------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Queue a request for admission, keeping arrival order."""
        bisect.insort(self._pending, req, key=lambda r: r.priority)

    @property
    def pending(self) -> tuple[Request, ...]:
        """Requests awaiting admission, in (arrival, rid) order."""
        return tuple(self._pending)

    @property
    def swapped(self) -> tuple[SeqState, ...]:
        """Preempted sequences awaiting resume, in priority order."""
        return tuple(self._swapped)

    def peek_ready(self, now_step: int) -> Request | None:
        """Oldest request whose arrival step has been reached."""
        if self._pending and self._pending[0].arrival <= now_step:
            return self._pending[0]
        return None

    def has_free_slot(self) -> bool:
        """Whether an engine slot is free for admission/resume."""
        return bool(self._free_slots)

    def place(self, req: Request, *, pos: int, pages: list[int],
              ready_wall: float, first_token: int | None = None,
              prefilled: int = 0) -> SeqState:
        """Admit the queue head into a free slot.  ``first_token=None``
        places the sequence in the prefilling phase (chunked prefill will
        deliver the first token later)."""
        assert self._pending and self._pending[0].rid == req.rid
        self._pending.pop(0)
        slot = self._free_slots.pop()
        seq = SeqState(req=req, slot=slot, pos=pos,
                       generated=[] if first_token is None
                       else [first_token],
                       pages=pages, prefilled=prefilled,
                       phase=(SeqPhase.PREFILLING if first_token is None
                              else SeqPhase.DECODING),
                       ready_wall=ready_wall)
        seq.admitted_wall = time.perf_counter()
        self.active[slot] = seq
        self.tracer.begin(f"req{req.rid}", track=f"slot{slot}",
                          cat="request", rid=req.rid)
        self.set_phase(seq, seq.phase)
        return seq

    def release(self, slot: int) -> SeqState:
        """Eviction on completion: free the slot, hand back the state."""
        seq = self.active.pop(slot)
        self.tracer.end(f"req{seq.req.rid}", track=f"slot{slot}",
                        cat="request")
        self.set_phase(seq, SeqPhase.DONE)
        self._free_slots.append(slot)
        return seq

    # -- preemption -----------------------------------------------------------
    def preemption_victim(self) -> SeqState | None:
        """Lowest-priority *decoding* sequence (youngest arrival, ties by
        rid).  Prefilling sequences are not preempted — their state is
        cheap to hold and they are about to produce their first token."""
        victims = [s for s in self.active.values()
                   if s.phase is SeqPhase.DECODING]
        if not victims:
            return None
        return max(victims, key=lambda s: s.req.priority)

    def preempt(self, slot: int) -> SeqState:
        """Evict a running sequence to the swapped queue; its slot frees
        immediately.  The engine swaps the KV pages to host around this."""
        seq = self.active.pop(slot)
        self.tracer.end(f"req{seq.req.rid}", track=f"slot{slot}",
                        cat="request")
        self.set_phase(seq, SeqPhase.SWAPPED)
        self._free_slots.append(slot)
        bisect.insort(self._swapped, seq, key=lambda s: s.req.priority)
        return seq

    def peek_swapped(self) -> SeqState | None:
        """Highest-priority preempted sequence awaiting resume."""
        return self._swapped[0] if self._swapped else None

    def place_swapped(self, seq: SeqState) -> SeqState:
        """Resume a swapped sequence into a free slot."""
        self._swapped.remove(seq)
        seq.slot = self._free_slots.pop()
        self.active[seq.slot] = seq
        self.tracer.begin(f"req{seq.req.rid}", track=f"slot{seq.slot}",
                          cat="request", rid=seq.req.rid, resumed=True)
        self.set_phase(seq, SeqPhase.DECODING)
        return seq

    @property
    def done(self) -> bool:
        """True when no work remains anywhere (pending/active/swapped)."""
        return (not self._pending and not self.active
                and not self._swapped)
