"""Zero-dependency observability: tracing spans + a metrics registry.

The package has two halves:

* :mod:`repro.obs.tracer` — a ``Tracer`` that records context-manager
  spans, instant events, and counter samples into a bounded ring buffer
  and exports Chrome trace-event JSON (loadable in Perfetto or
  ``chrome://tracing``).  A process-global tracer is installed with
  :func:`install_tracer`; the default is a no-op ``NullTracer`` so that
  instrumented code paths cost one attribute lookup when tracing is off.
* :mod:`repro.obs.metrics` — a ``Metrics`` registry of counters, gauges,
  and fixed log-bucket ``Histogram`` objects with p50/p90/p99 summaries.
  ``Metrics.stats_view()`` exposes the counter table as a plain mutable
  mapping so existing ``stats`` dicts can migrate onto it unchanged.

Everything here is stdlib-only; see ``docs/observability.md`` for the
span/track taxonomy and the metric glossary.
"""

from repro.obs.metrics import Histogram, Metrics
from repro.obs.tracer import (
    NULL_TRACER,
    NullTracer,
    Tracer,
    get_tracer,
    install_tracer,
)

__all__ = [
    "Histogram",
    "Metrics",
    "NULL_TRACER",
    "NullTracer",
    "Tracer",
    "get_tracer",
    "install_tracer",
]
