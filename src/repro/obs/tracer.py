"""Monotonic-clock tracing with Chrome trace-event JSON export.

A :class:`Tracer` records three kinds of events into a bounded ring
buffer, each tagged with a *track* (rendered as one timeline row):

* **spans** — ``with tracer.span("decode", track="engine"): ...`` emits a
  ``B``/``E`` pair; spans nest LIFO per track.
* **instants** — ``tracer.instant(...)`` emits a zero-duration ``i``
  event (e.g. a SeqPhase transition or a kernel dispatch).
* **counters** — ``tracer.counter("pool_pages", {"free": 3, ...})``
  emits a ``C`` sample rendered as a stacked area chart.

Timestamps come from :func:`time.perf_counter_ns` (monotonic, immune to
NTP wall-clock jumps) and are stored as microseconds relative to tracer
construction, which is what the trace-event format expects in ``ts``.

:func:`Tracer.export` writes ``{"traceEvents": [...]}`` JSON loadable in
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``.  Any span
still open at export time is closed at the export timestamp so every
``B`` has a matching ``E``.

The module keeps a process-global tracer (default: the shared no-op
:data:`NULL_TRACER`) behind :func:`get_tracer` / :func:`install_tracer`;
instrumentation sites fetch it once and pay only a no-op method call
when tracing is disabled.
"""

from __future__ import annotations

import json
import pathlib
import threading
import time
from collections import deque
from typing import Any

_PID = "repro"


def _clean(args: dict[str, Any]) -> dict[str, Any]:
    """Coerce span args to JSON-serializable scalars (repr for the rest)."""
    out: dict[str, Any] = {}
    for k, v in args.items():
        if v is None or isinstance(v, (bool, int, float, str)):
            out[k] = v
        else:
            out[k] = repr(v)
    return out


class _NullSpan:
    """Context manager that does nothing; shared by all NullTracer spans."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class NullTracer:
    """No-op tracer: every method returns immediately.

    Installed by default so instrumented code paths cost one attribute
    lookup plus an empty call when tracing is off.  ``enabled`` is
    ``False`` so hot paths can skip building event arguments entirely.
    """

    enabled = False

    def span(self, name, track="engine", cat=None, **args):
        """Return the shared no-op context manager."""
        return _NULL_SPAN

    def begin(self, name, track="engine", cat=None, **args):
        """No-op."""

    def end(self, name=None, track="engine", cat=None):
        """No-op."""

    def instant(self, name, track="engine", cat=None, **args):
        """No-op."""

    def counter(self, name, values, track=None):
        """No-op."""

    def export(self, path):
        """No-op; returns ``None`` (there is nothing to export)."""
        return None


NULL_TRACER = NullTracer()


class _Span:
    """Context manager emitting a ``B`` on enter and ``E`` on exit."""

    __slots__ = ("_tracer", "_name", "_track", "_cat", "_args")

    def __init__(self, tracer, name, track, cat, args):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._cat = cat
        self._args = args

    def __enter__(self):
        self._tracer.begin(self._name, self._track, self._cat, **self._args)
        return self

    def __exit__(self, *exc):
        self._tracer.end(self._name, self._track, self._cat)
        return False


class Tracer:
    """Ring-buffer span/event recorder with Chrome trace-event export.

    ``capacity`` bounds the number of retained events (oldest dropped
    first), so long runs cannot grow memory without bound.  All methods
    are thread-safe; timestamps are monotonic microseconds relative to
    construction.
    """

    enabled = True

    def __init__(self, capacity: int = 200_000):
        self._events: deque[dict[str, Any]] = deque(maxlen=capacity)
        self._open: dict[str, list[str]] = {}  # track -> stack of span names
        self._lock = threading.Lock()
        self._t0 = time.perf_counter_ns()

    def _ts(self) -> float:
        """Microseconds since tracer construction (monotonic clock)."""
        return (time.perf_counter_ns() - self._t0) / 1e3

    def _emit(self, ev: dict[str, Any]) -> None:
        with self._lock:
            self._events.append(ev)

    def span(self, name: str, track: str = "engine", cat: str | None = None,
             **args) -> _Span:
        """Return a context manager timing ``name`` on ``track``."""
        return _Span(self, name, track, cat, args)

    def begin(self, name: str, track: str = "engine", cat: str | None = None,
              **args) -> None:
        """Open a span (``B`` event) on ``track``; pair with :meth:`end`."""
        ev: dict[str, Any] = {"name": name, "ph": "B", "ts": self._ts(),
                              "pid": _PID, "tid": track}
        if cat is not None:
            ev["cat"] = cat
        if args:
            ev["args"] = _clean(args)
        with self._lock:
            self._events.append(ev)
            self._open.setdefault(track, []).append(name)

    def end(self, name: str | None = None, track: str = "engine",
            cat: str | None = None) -> None:
        """Close the innermost open span on ``track`` (``E`` event)."""
        with self._lock:
            stack = self._open.get(track)
            top = stack.pop() if stack else None
            ev: dict[str, Any] = {"name": name if name is not None else top,
                                  "ph": "E", "ts": self._ts(),
                                  "pid": _PID, "tid": track}
            if cat is not None:
                ev["cat"] = cat
            self._events.append(ev)

    def instant(self, name: str, track: str = "engine",
                cat: str | None = None, **args) -> None:
        """Emit a zero-duration instant event (``i``, thread scope)."""
        ev: dict[str, Any] = {"name": name, "ph": "i", "s": "t",
                              "ts": self._ts(), "pid": _PID, "tid": track}
        if cat is not None:
            ev["cat"] = cat
        if args:
            ev["args"] = _clean(args)
        self._emit(ev)

    def counter(self, name: str, values: dict[str, float],
                track: str | None = None) -> None:
        """Emit a counter sample (``C``); ``values`` maps series to number."""
        self._emit({"name": name, "ph": "C", "ts": self._ts(), "pid": _PID,
                    "tid": track if track is not None else name,
                    "args": {k: float(v) for k, v in values.items()}})

    def export(self, path: str | pathlib.Path) -> pathlib.Path:
        """Write Chrome trace-event JSON to ``path`` and return it.

        Spans still open at export time are closed at the current
        timestamp so the emitted file always has balanced ``B``/``E``
        pairs per track.
        """
        with self._lock:
            events = list(self._events)
            ts = self._ts()
            for track, stack in self._open.items():
                for name in reversed(stack):
                    events.append({"name": name, "ph": "E", "ts": ts,
                                   "pid": _PID, "tid": track})
        out = pathlib.Path(path)
        out.write_text(json.dumps(
            {"traceEvents": events, "displayTimeUnit": "ms"}))
        return out


_TRACER: Tracer | NullTracer = NULL_TRACER


def get_tracer() -> Tracer | NullTracer:
    """Return the process-global tracer (the no-op tracer by default)."""
    return _TRACER


def install_tracer(tracer: Tracer | None) -> Tracer | NullTracer:
    """Install ``tracer`` as the process-global tracer; ``None`` resets.

    Returns the tracer now in effect.  Call sites that construct their
    own ``Tracer`` for a run (``--trace`` flags) install it before any
    instrumented object is built and reset with ``install_tracer(None)``
    after export.
    """
    global _TRACER
    _TRACER = tracer if tracer is not None else NULL_TRACER
    return _TRACER
