"""Counters, gauges, and log-bucket histograms for run-level metrics.

The :class:`Metrics` registry is deliberately tiny: counters are a plain
insertion-ordered dict (so an existing ``stats`` dict can migrate onto
it via :meth:`Metrics.stats_view` without changing any key, value type,
or arithmetic), gauges are last-write-wins, and histograms use fixed
log-spaced buckets so percentile queries are O(buckets) with bounded
relative error.

Nothing here imports outside the stdlib; see ``docs/observability.md``
for the metric glossary.
"""

from __future__ import annotations

import bisect
import math
from collections.abc import MutableMapping
from typing import Any

# Bucket edges grow by 2**(1/8) ≈ 1.09 per bucket, bounding the relative
# error of an interpolated percentile to roughly half a bucket (~5%).
_GROWTH = 2.0 ** 0.125


class Histogram:
    """Fixed log-bucket histogram of non-negative samples.

    Buckets span ``[0, lo)`` then log-spaced edges from ``lo`` to at
    least ``hi`` (growth factor ``growth``); samples beyond either end
    clamp into the boundary bucket.  Percentiles interpolate linearly
    within the selected bucket and are clamped to the observed min/max,
    which keeps them within ~half a bucket width of the exact
    (numpy-style) quantile.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 growth: float = _GROWTH):
        if not (lo > 0.0 and hi > lo and growth > 1.0):
            raise ValueError("need 0 < lo < hi and growth > 1")
        edges = [0.0, lo]
        while edges[-1] < hi:
            edges.append(edges[-1] * growth)
        self._edges = edges
        self._counts = [0] * (len(edges) - 1)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, value: float) -> None:
        """Record one sample (negative values clamp into the first bucket)."""
        v = float(value)
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        idx = bisect.bisect_right(self._edges, v) - 1
        self._counts[min(max(idx, 0), len(self._counts) - 1)] += 1

    def percentile(self, q: float) -> float:
        """Approximate the ``q``-th percentile (0..100) of the samples."""
        if self.count == 0:
            return 0.0
        target = (q / 100.0) * self.count
        cum = 0
        for i, c in enumerate(self._counts):
            if c and cum + c >= target:
                frac = (target - cum) / c
                lo_e, hi_e = self._edges[i], self._edges[i + 1]
                val = lo_e + frac * (hi_e - lo_e)
                return min(max(val, self.vmin), self.vmax)
            cum += c
        return self.vmax

    def summary(self) -> dict[str, float]:
        """Count/mean/min/max plus p50/p90/p99 as a JSON-ready dict."""
        if self.count == 0:
            return {"count": 0, "mean": 0.0, "min": 0.0, "max": 0.0,
                    "p50": 0.0, "p90": 0.0, "p99": 0.0}
        return {"count": self.count,
                "mean": self.total / self.count,
                "min": self.vmin,
                "max": self.vmax,
                "p50": self.percentile(50),
                "p90": self.percentile(90),
                "p99": self.percentile(99)}


class _StatsView(MutableMapping):
    """Mutable-mapping facade over a Metrics counter table.

    Behaves exactly like the dict it wraps — same keys, same value
    objects, same iteration order — so an engine can assign it to its
    ``stats`` attribute and keep every existing ``stats[...]`` read,
    write, ``update``, and ``dict(...)`` call bit-identical.
    """

    __slots__ = ("_table",)

    def __init__(self, table: dict[str, Any]):
        self._table = table

    def __getitem__(self, key):
        return self._table[key]

    def __setitem__(self, key, value):
        self._table[key] = value

    def __delitem__(self, key):
        del self._table[key]

    def __iter__(self):
        return iter(self._table)

    def __len__(self):
        return len(self._table)

    def __repr__(self):
        return repr(self._table)


class Metrics:
    """Registry of named counters, gauges, and histograms."""

    def __init__(self):
        self._counters: dict[str, Any] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, Histogram] = {}

    def counter(self, name: str, inc: float = 1) -> None:
        """Add ``inc`` to counter ``name`` (created at 0)."""
        self._counters[name] = self._counters.get(name, 0) + inc

    def gauge(self, name: str, value: float) -> None:
        """Set gauge ``name`` to ``value`` (last write wins)."""
        self._gauges[name] = value

    def histogram(self, name: str, **kwargs) -> Histogram:
        """Return (creating on first use) the histogram named ``name``."""
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(**kwargs)
        return h

    def observe(self, name: str, value: float) -> None:
        """Record ``value`` into histogram ``name``."""
        self.histogram(name).observe(value)

    def stats_view(self) -> _StatsView:
        """Dict-compatible live view of the counter table.

        The engine assigns this to ``self.stats`` so its pre-existing
        counter keys live in the registry while every access pattern
        stays unchanged.
        """
        return _StatsView(self._counters)

    def snapshot(self) -> dict[str, Any]:
        """JSON-ready snapshot: counters, gauges, histogram summaries."""
        return {"counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: h.summary()
                               for k, h in self._hists.items()}}
