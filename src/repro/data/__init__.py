from repro.data.pipeline import SyntheticLMData  # noqa: F401
