"""Deterministic synthetic data pipeline.

Production posture without an external corpus: batches are a pure function
of (seed, step), so every host in a multi-host job can independently build
its local shard (`host_slice`), restarts resume mid-epoch with zero
coordination, and straggler mitigation can *skip* a step deterministically
(runtime/fault.py) — every surviving host skips the same data.

The token stream is a fixed random bigram chain, giving a learnable
distribution (entropy well below uniform) so the end-to-end example shows a
real loss curve on CPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass
class SyntheticLMData:
    cfg: ModelConfig
    batch_size: int
    seq_len: int
    seed: int = 0
    branching: int = 4     # out-degree of the bigram chain

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.cfg.vocab
        # each token has `branching` likely successors
        self._succ = rng.integers(0, v, size=(v, self.branching))

    # -- pure-function batch -----------------------------------------------
    def batch(self, step: int) -> dict:
        key = jax.random.PRNGKey(self.seed * 1_000_003 + step)
        b, s = self.batch_size, self.seq_len
        cfg = self.cfg
        if cfg.family == "vlm":
            s_txt = s - cfg.n_patches
            toks = self._chain(key, (b, s_txt + 1))
            k2 = jax.random.fold_in(key, 1)
            patches = jax.random.normal(
                k2, (b, cfg.n_patches, cfg.frontend_dim), jnp.float32
            ).astype(jnp.dtype(cfg.dtype))
            return {"tokens": toks[:, :-1], "targets": toks[:, 1:],
                    "patch_embeds": patches}
        if cfg.family == "audio":
            toks = jnp.stack(
                [self._chain(jax.random.fold_in(key, c), (b, s + 1))
                 for c in range(cfg.n_codebooks)], axis=-1)
            return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        toks = self._chain(key, (b, s + 1))
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def _chain(self, key, shape) -> jax.Array:
        """Vectorised bigram walk over the fixed successor table."""
        b, s = shape
        succ = jnp.asarray(self._succ)
        k0, k1 = jax.random.split(key)
        start = jax.random.randint(k0, (b,), 0, self.cfg.vocab)
        choices = jax.random.randint(k1, (b, s), 0, self.branching)

        def step(tok, choice):
            nxt = succ[tok, choice]
            return nxt, nxt

        _, toks = jax.lax.scan(step, start, choices.T)
        return toks.T.astype(jnp.int32)

    # -- multi-host slicing ---------------------------------------------------
    def host_slice(self, batch: dict, host_id: int, n_hosts: int) -> dict:
        per = self.batch_size // n_hosts
        return jax.tree_util.tree_map(
            lambda t: t[host_id * per:(host_id + 1) * per], batch)

    def batches(self, start_step: int = 0):
        step = start_step
        while True:
            yield step, self.batch(step)
            step += 1
