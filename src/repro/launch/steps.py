"""Jittable train / prefill / decode step builders.

Every builder takes an optional ``mesh``: when given, the step body traces
inside ``with mesh:``, so the SPMD kernel routing
(:mod:`repro.runtime.spmd`) sees the mesh even if the caller jits the step
without an enclosing mesh context — packed matmuls then dispatch
shard_map-wrapped Pallas kernels instead of falling back to the XLA oracle.

Every builder also takes an optional ``plan`` (a
:class:`repro.core.plan.ModelPlan`): the step body traces inside
:func:`repro.core.plan.use_plan`, so each packed matmul dispatches with its
layer's :class:`~repro.core.plan.PackPlan` (impl hint, tuned dispatch
params, per-layer SPMD partition plan) instead of rediscovering a choice
per call.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.plan import use_plan
from repro.launch.mesh import mesh_context
from repro.models.model import LM
from repro.optim.adamw import AdamW

Params = Any


def make_train_step(model: LM, optimizer: AdamW, mesh=None, plan=None):
    def train_step(params: Params, opt_state: Params, batch: Params):
        def loss_fn(p):
            return model.loss(p, batch)

        with mesh_context(mesh), use_plan(plan):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True, allow_int=True)(params)
            params, opt_state, opt_metrics = optimizer.update(
                params, grads, opt_state)
        return params, opt_state, {**metrics, **opt_metrics}

    return train_step


def make_loss_and_grads(model: LM, mesh=None, plan=None):
    def loss_and_grads(params: Params, batch: Params):
        with mesh_context(mesh), use_plan(plan):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch), has_aux=True, allow_int=True
            )(params)
        return loss, metrics, grads

    return loss_and_grads


def make_prefill_step(model: LM, mesh=None, plan=None):
    def prefill_step(params: Params, batch: Params):
        with mesh_context(mesh), use_plan(plan):
            last_logits, cache = model.prefill(params, batch)
        next_tokens = jnp.argmax(last_logits, axis=-1)
        return next_tokens, cache

    return prefill_step


def make_decode_step(model: LM, greedy: bool = True, mesh=None, plan=None):
    def decode_step(params: Params, cache: Params, tokens, pos):
        with mesh_context(mesh), use_plan(plan):
            logits, cache = model.decode_step(params, cache, tokens, pos)
        next_tokens = jnp.argmax(logits, axis=-1)
        return next_tokens, logits, cache

    return decode_step


def make_prefill_full(model: LM, mesh=None, plan=None):
    """Prefill returning *all* positions' logits (not just the last).

    The serving engine pads prompts to a page-aligned bucket before the
    fused prefill, so the last *real* token's logits live at ``len - 1``
    rather than ``-1`` — the engine slices them out on the host.
    """
    def prefill_full(params: Params, batch: Params):
        with mesh_context(mesh), use_plan(plan):
            logits, _, cache = model.apply(params, batch, want_cache=True)
        return logits, cache

    return prefill_full


def make_chunked_prefill_step(model: LM, mesh=None, plan=None):
    """One chunk of prompt prefill into the paged KV pool (continuous
    batching): admission splits a prompt into fixed-token chunks that
    interleave with running decode steps, so a long prompt never freezes
    the batch.  ``tokens`` (B, C) covers positions [start, start+C);
    logits for every chunk position come back so the engine can slice the
    last real prompt token's row out on the host."""
    def chunked_prefill_step(params: Params, pool: Params, block_tables,
                             tokens, start, valid_len):
        with mesh_context(mesh), use_plan(plan):
            logits, pool = model.prefill_chunk(
                params, pool, block_tables, tokens, start, valid_len)
        return logits, pool

    return chunked_prefill_step


def make_verify_step(model: LM, mesh=None, plan=None):
    """Speculative-window verification step (continuous batching): every
    engine slot scores its committed token + k draft proposals in one
    batched pass.  ``tokens`` is (B, C=k+1) covering cache positions
    [start[b], start[b]+C) per row; returns per-position greedy tokens,
    the raw logits, and the updated pool.  Row ``(b, i)`` of the greedy
    tokens is bitwise what the sequential paged decode step would emit at
    that position — the engine's accept rule depends on it."""
    def verify_step(params: Params, pool: Params, block_tables,
                    tokens, start, valid_len):
        with mesh_context(mesh), use_plan(plan):
            logits, pool = model.verify_chunk(
                params, pool, block_tables, tokens, start, valid_len)
        next_tokens = jnp.argmax(logits, axis=-1)
        return next_tokens, logits, pool

    return verify_step


def make_paged_decode_step(model: LM, mesh=None, plan=None):
    """Ragged decode step over the paged KV pool (continuous batching):
    every engine slot decodes at its own ``pos`` against its own pages.
    ``valid_len`` (optional, (B,)) is the per-row write cutoff the engine
    uses to batch decoding rows with prefilling/idle ones — rows at or
    beyond their cutoff write to the trash page."""
    def paged_decode_step(params: Params, pool: Params, block_tables,
                          tokens, pos, valid_len=None):
        with mesh_context(mesh), use_plan(plan):
            logits, pool = model.paged_decode_step(
                params, pool, block_tables, tokens, pos,
                valid_len=valid_len)
        next_tokens = jnp.argmax(logits, axis=-1)
        return next_tokens, logits, pool

    return paged_decode_step
