"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  The dry-run forces 512 host platform devices before any
jax import; everything else sees the real device count.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (launch/dryrun.py does this)."
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for unit tests (requires forced host devices)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


FAKE_MESH_FLAG = "--xla_force_host_platform_device_count=8"


def make_fake_mesh(shape=(4, 2), axes=("data", "model")) -> Mesh:
    """The spmd-tier mesh: 8 forced CPU host devices as (data=4, model=2).

    Callers must export ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    (:data:`FAKE_MESH_FLAG`) *before* the first jax import — this is what
    the CI ``spmd-tier`` job and ``tests/test_spmd.py`` do.
    """
    return make_test_mesh(shape, axes)


def mesh_context(mesh: Mesh | None):
    """``with mesh_context(m):`` — the mesh, or a no-op when None.  Step
    builders use this so tracing under a mesh activates the SPMD kernel
    routing even when the caller forgets the ``with mesh:`` block."""
    import contextlib

    return mesh if mesh is not None else contextlib.nullcontext()


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes that shard the batch (pod absorbs into data parallelism)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh: Mesh) -> str:
    return "model"
