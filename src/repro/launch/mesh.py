"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  The dry-run forces 512 host platform devices before any
jax import; everything else sees the real device count.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (launch/dryrun.py does this)."
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")) -> Mesh:
    """Small mesh for unit tests (requires forced host devices)."""
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes that shard the batch (pod absorbs into data parallelism)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh: Mesh) -> str:
    return "model"
