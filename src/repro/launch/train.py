"""Training driver: end-to-end LM training with SoD, checkpointing, fault
tolerance.  CPU-runnable (reduced configs) and mesh-ready (full configs).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \\
      --steps 200 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \\
      --steps 100 --sod tiled_csc --density 0.3
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro import configs, obs
from repro.checkpoint import Checkpointer
from repro.core.sod import SoDConfig, sodify_params
from repro.data.pipeline import SyntheticLMData
from repro.launch import steps as steps_mod
from repro.models.model import LM
from repro.optim import AdamW, AdamWConfig, cosine_schedule
from repro.runtime.fault import FaultConfig, ResilientRunner


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--sod", choices=("tiled_csc", "block_csr"), default=None)
    ap.add_argument("--density", type=float, default=0.3)
    ap.add_argument("--quantize", default="none",
                    choices=("none", "int8", "fp8", "codebook", "auto"),
                    help="packed value quantization: int8/fp8 store "
                         "per-tile-scaled codes, codebook an EIE-style "
                         "shared-value table + 4-bit indices; 'auto' lets "
                         "the planner pick per layer under its accuracy "
                         "drift budget (requires --plan auto)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--autotune", action="store_true",
                    help="warm the kernel tuning cache for this model's "
                         "packed weight shapes before training")
    ap.add_argument("--tuning-cache", default=None,
                    help="tuning-cache JSON path (default: "
                         "$REPRO_TUNING_CACHE or ~/.cache/repro/"
                         "tuning_cache.json)")
    ap.add_argument("--plan", default=None,
                    help="pack plan: JSON path to replay (e.g. dumped by "
                         "dryrun --plan-json), or 'auto' to build one with "
                         "the planner; default: global-config packing")
    ap.add_argument("--plan-json", default=None,
                    help="write the effective pack plan to this path")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON timeline "
                         "(train steps, autotune measurements, kernel "
                         "dispatch) to PATH — open in Perfetto or "
                         "chrome://tracing; see docs/observability.md")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write a counters/gauges/histograms metrics "
                         "snapshot to PATH")
    args = ap.parse_args(argv)

    tracer = None
    if args.trace:
        # install before any instrumented call (autotune, dispatch)
        tracer = obs.install_tracer(obs.Tracer())
    mets = obs.Metrics() if args.metrics_json else None

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    if args.quantize != "none" and not args.sod:
        ap.error("--quantize requires Sparse-on-Dense packing "
                 "(pass --sod tiled_csc|block_csr)")
    if args.quantize == "auto" and args.plan != "auto":
        ap.error("--quantize auto needs the planner (pass --plan auto)")
    if args.sod:
        cfg = cfg.with_(sod=SoDConfig(
            mode=args.sod, density=args.density, min_dim=64,
            qmode=args.quantize if args.quantize != "auto" else "none"))
    model = LM(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    plan = None
    if args.plan and not cfg.sod.enabled:
        ap.error("--plan requires Sparse-on-Dense packing "
                 "(pass --sod tiled_csc|block_csr)")
    if cfg.sod.enabled:
        from repro.kernels import autotune
        from repro.runtime import planner

        # install the cache BEFORE planning: the planner's dispatch hints
        # must come from the same cache file dispatch will read
        cache = autotune.install_cache(args.tuning_cache)
        plan = planner.load_or_build(
            args.plan, params, cfg.sod, cfg=cfg, cache=cache,
            m_values=(args.batch * args.seq,),
            qmode="auto" if args.quantize == "auto" else None)
        if plan is not None:
            n_dense = sum(e.mode == "dense" for e in plan.entries.values())
            print(f"pack plan: {len(plan)} layers "
                  f"({len(plan) - n_dense} packed, {n_dense} dense), "
                  f"{plan.compressed_bytes():,} planned bytes")
        params = sodify_params(params, cfg.sod, plan=plan)
        from repro.core.sod import tree_weight_bytes
        print("sod weight bytes:", tree_weight_bytes(params))
        if args.autotune:
            if plan is not None:
                stats = planner.warmup_plan(
                    plan, (args.batch * args.seq,), cache=cache)
            else:
                stats = autotune.warmup_params(
                    params, (args.batch * args.seq,), cache=cache)
            print(f"autotune: {stats} -> {cache.path}")
    if args.plan_json and plan is not None:
        print(f"pack plan -> {plan.save(args.plan_json)}")

    opt = AdamW(AdamWConfig(lr=args.lr),
                schedule=cosine_schedule(args.lr, args.warmup, args.steps))
    opt_state = opt.init(params)
    data = SyntheticLMData(cfg, args.batch, args.seq, seed=args.seed)
    train_step = jax.jit(steps_mod.make_train_step(model, opt, plan=plan))
    ckpt = Checkpointer(args.ckpt_dir)

    state = {"params": params, "opt": opt_state}
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start = ckpt.latest_step()
        state = ckpt.restore(start, state)
        print(f"resumed from step {start}")

    def do_step(step, state):
        batch = data.batch(step)
        p, o, metrics = train_step(state["params"], state["opt"], batch)
        state["params"], state["opt"] = p, o
        return metrics

    runner = ResilientRunner(
        step_fn=lambda step: do_step(step, state),
        checkpointer=ckpt,
        fault=FaultConfig(ckpt_every=args.ckpt_every),
        state_of=lambda: state,
        load_state=lambda s: state.update(s),
    )

    losses = []
    tr = obs.get_tracer()
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        with tr.span("train_step", track="train", step=step):
            res = runner.run_step(step)
        loss = float(res.metrics["loss"])
        losses.append(loss)
        if mets is not None:
            mets.counter("train_steps")
            mets.observe("train_step_s", res.seconds)
        if step % args.log_every == 0 or step == args.steps - 1:
            toks = args.batch * args.seq
            print(f"step {step:5d}  loss {loss:7.4f}  "
                  f"lr {float(res.metrics['lr']):.2e}  "
                  f"gnorm {float(res.metrics['grad_norm']):6.3f}  "
                  f"{toks / max(res.seconds, 1e-9):,.0f} tok/s", flush=True)
    ckpt.save(args.steps - 1, state, blocking=True)
    dt = time.perf_counter() - t0
    summary = {
        "arch": cfg.name, "steps": args.steps,
        "first_loss": losses[0], "last_loss": losses[-1],
        "mean_last10": sum(losses[-10:]) / min(len(losses), 10),
        "wall_s": round(dt, 1),
    }
    if plan is not None:
        summary["plan_layers"] = len(plan)
        summary["plan_bytes"] = plan.compressed_bytes()
    if mets is not None:
        mets.gauge("wall_s", dt)
        pathlib.Path(args.metrics_json).write_text(
            json.dumps(mets.snapshot(), indent=2))
    if tracer is not None:
        summary["trace"] = str(tracer.export(args.trace))
        obs.install_tracer(None)
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
