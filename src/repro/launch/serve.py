"""Serving driver: batched prefill + greedy decode.

Attention families use the fused prefill (single forward building the KV
cache); recurrent/hybrid families rebuild their O(1) state by stepping the
prompt (exact, and how their caches behave in production continuation).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \\
      --batch 4 --prompt-len 32 --gen 16 --sod tiled_csc --density 0.3
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import plan as plan_mod
from repro.core.sod import SoDConfig, sodify_params
from repro.data.pipeline import SyntheticLMData
from repro.launch import steps as steps_mod
from repro.models.model import LM


def prefill_cache(model: LM, params, prompt, max_len: int, plan=None):
    """Family-appropriate cache construction for a (B, S) prompt batch."""
    cfg = model.cfg
    b, s = prompt["tokens"].shape[:2]
    with plan_mod.use_plan(plan):
        if cfg.family in ("hybrid", "ssm"):
            cache = model.init_cache(b, max_len)
            logits = None
            step = jax.jit(model.decode_step)
            for t in range(s):
                tok = prompt["tokens"][:, t:t + 1]
                logits, cache = step(params, cache, tok, jnp.asarray(t))
            return logits[:, -1], cache, s
        last_logits, cache = jax.jit(
            lambda p, b_: model.prefill(p, b_))(params, prompt)
    # right-size the cache to max_len
    def grow(t):
        if t.ndim >= 4 and t.shape[-3] == s:  # (..., S, KV, hd)
            pad = [(0, 0)] * t.ndim
            pad[-3] = (0, max_len - s)
            return jnp.pad(t, pad)
        return t
    cache = jax.tree_util.tree_map(grow, cache)
    return last_logits, cache, s


def _sample_tokens(outs, limit: int = 8) -> list[int]:
    """First generated token id per step for batch row 0, shape-agnostic.

    Step outputs differ by family — (B, 1) for token models, (B, 1, C) for
    the audio codebook stack — and the list may be shorter than ``limit``
    for small ``--gen`` (or empty for ``--gen 0``); indexing each step's
    array defensively handles all of them.
    """
    toks: list[int] = []
    for o in outs:
        a = np.asarray(o)
        if a.size == 0:
            continue
        toks.append(int(a.reshape(a.shape[0], -1)[0, 0]) if a.ndim >= 1
                    else int(a))
        if len(toks) >= limit:
            break
    return toks


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sod", choices=("tiled_csc", "block_csr"), default=None)
    ap.add_argument("--density", type=float, default=0.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--autotune", action="store_true",
                    help="warm the kernel tuning cache for this model's "
                         "packed weight shapes before serving")
    ap.add_argument("--tuning-cache", default=None,
                    help="tuning-cache JSON path (default: "
                         "$REPRO_TUNING_CACHE or ~/.cache/repro/"
                         "tuning_cache.json)")
    ap.add_argument("--plan", default=None,
                    help="pack plan: JSON path to replay (e.g. dumped by "
                         "dryrun --plan-json), or 'auto' to build one with "
                         "the planner; default: global-config packing")
    ap.add_argument("--plan-json", default=None,
                    help="write the effective pack plan to this path")
    args = ap.parse_args(argv)

    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    if args.sod:
        cfg = cfg.with_(sod=SoDConfig(mode=args.sod, density=args.density,
                                      min_dim=64))
    model = LM(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    tune_stats = None
    plan = None
    if args.plan and not cfg.sod.enabled:
        ap.error("--plan requires Sparse-on-Dense packing "
                 "(pass --sod tiled_csc|block_csr)")
    # prefill consumes (batch·prompt_len, K); decode (batch, K)
    m_values = (args.batch * args.prompt_len, args.batch)
    if cfg.sod.enabled:
        from repro.kernels import autotune
        from repro.runtime import planner

        # install the cache BEFORE planning: the planner's dispatch hints
        # must come from the same cache file dispatch will read
        cache = autotune.install_cache(args.tuning_cache)
        plan = planner.load_or_build(args.plan, params, cfg.sod, cfg=cfg,
                                     cache=cache, m_values=m_values)
        params = sodify_params(params, cfg.sod, plan=plan)
        if args.autotune:
            if plan is not None:
                tune_stats = planner.warmup_plan(plan, m_values, cache=cache)
            else:
                tune_stats = autotune.warmup_params(params, m_values,
                                                    cache=cache)
            print(f"autotune: {tune_stats} -> {cache.path}")
    if args.plan_json and plan is not None:
        print(f"pack plan -> {plan.save(args.plan_json)}")

    data = SyntheticLMData(cfg, args.batch, args.prompt_len, seed=args.seed)
    prompt = {k: v for k, v in data.batch(0).items() if k != "targets"}
    max_len = args.prompt_len + args.gen

    t0 = time.time()
    last_logits, cache, pos0 = prefill_cache(model, params, prompt, max_len,
                                             plan=plan)
    prefill_s = time.time() - t0

    decode = jax.jit(steps_mod.make_decode_step(model, plan=plan))
    tok = jnp.argmax(last_logits, axis=-1)
    if cfg.family == "audio":
        tok = tok.reshape(args.batch, 1, cfg.n_codebooks)
    else:
        tok = tok.reshape(args.batch, 1)
    outs = []
    t0 = time.time()
    for t in range(args.gen):
        nxt, logits, cache = decode(params, cache, tok,
                                    jnp.asarray(pos0 + t, jnp.int32))
        tok = nxt.reshape(tok.shape)
        outs.append(nxt)
    decode_s = time.time() - t0

    summary = {
        "arch": cfg.name, "batch": args.batch,
        "prompt_len": args.prompt_len, "generated": args.gen,
        "prefill_s": round(prefill_s, 3),
        "decode_tok_per_s": round(args.batch * args.gen / max(decode_s, 1e-9), 1),
        "sample": _sample_tokens(outs),
    }
    if tune_stats is not None:
        summary["autotune"] = tune_stats
    if plan is not None:
        summary["plan_layers"] = len(plan)
        summary["plan_bytes"] = plan.compressed_bytes()
    print(json.dumps(summary))
    return summary


if __name__ == "__main__":
    main()
