"""Serving driver: batched prefill + greedy decode.

Attention families use the fused prefill (single forward building the KV
cache); recurrent/hybrid families rebuild their O(1) state by stepping the
prompt (exact, and how their caches behave in production continuation).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \\
      --batch 4 --prompt-len 32 --gen 16 --sod tiled_csc --density 0.3
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs, obs
from repro.core import plan as plan_mod
from repro.core.sod import SoDConfig, sodify_params
from repro.data.pipeline import SyntheticLMData
from repro.kernels import registry as kreg
from repro.launch import steps as steps_mod
from repro.models.model import LM


def prefill_cache(model: LM, params, prompt, max_len: int, plan=None):
    """Family-appropriate cache construction for a (B, S) prompt batch."""
    cfg = model.cfg
    b, s = prompt["tokens"].shape[:2]
    with plan_mod.use_plan(plan):
        if cfg.family in ("hybrid", "ssm"):
            cache = model.init_cache(b, max_len)
            logits = None
            step = jax.jit(model.decode_step)
            for t in range(s):
                tok = prompt["tokens"][:, t:t + 1]
                logits, cache = step(params, cache, tok, jnp.asarray(t))
            return logits[:, -1], cache, s
        last_logits, cache = jax.jit(
            lambda p, b_: model.prefill(p, b_))(params, prompt)
    # right-size the cache to max_len via the explicit per-family cache
    # geometry (the old shape-matching heuristic mis-grew any leaf whose
    # unrelated dim happened to equal the prompt length)
    cache = model.grow_cache(cache, max_len)
    return last_logits, cache, s


def _sample_tokens(outs, limit: int = 8) -> list[int]:
    """First generated token id per step for batch row 0, shape-agnostic.

    Step outputs differ by family — (B, 1) for token models, (B, 1, C) for
    the audio codebook stack — and the list may be shorter than ``limit``
    for small ``--gen`` (or empty for ``--gen 0``); indexing each step's
    array defensively handles all of them.
    """
    toks: list[int] = []
    for o in outs:
        a = np.asarray(o)
        if a.size == 0:
            continue
        toks.append(int(a.reshape(a.shape[0], -1)[0, 0]) if a.ndim >= 1
                    else int(a))
        if len(toks) >= limit:
            break
    return toks


def engine_main(args, model, params, plan, draft_params=None,
                draft_plan=None):
    """``--engine``: continuous batching over a synthetic Poisson trace."""
    from repro.serving import Engine, bucket_len, poisson_trace

    cfg = model.cfg
    page = args.page_size
    if cfg.family in ("hybrid", "ssm"):
        max_len = args.prompt_len + args.gen
    else:
        max_len = bucket_len(args.prompt_len, page, cfg.attn_chunk) + args.gen
    eng = Engine(model, params, max_slots=args.max_slots, page_size=page,
                 max_len=max_len, plan=plan,
                 prefill_chunk=args.prefill_chunk,
                 preemption=args.preemption,
                 prefix_sharing=args.prefix_sharing,
                 spec_k=args.spec_decode,
                 draft_params=draft_params, draft_plan=draft_plan,
                 prefix_cache_budget=args.prefix_cache_budget,
                 prefix_cache_dir=args.prefix_cache_dir)
    trace = poisson_trace(args.requests, args.arrival_rate,
                          max_prompt=args.prompt_len, max_new=args.gen,
                          vocab=cfg.vocab, seed=args.seed)
    res = eng.run(trace)
    if args.metrics_json:
        pathlib.Path(args.metrics_json).write_text(
            json.dumps(eng.metrics.snapshot(), indent=2))
    summary = {
        "engine": True, "arch": cfg.name, "requests": args.requests,
        "max_slots": args.max_slots,
        "page_size": page if eng.paged else None,
        "prefill_chunk": args.prefill_chunk,
        "preemption": args.preemption,
        "prefix_sharing": args.prefix_sharing,
        "spec_decode": args.spec_decode,
        "prefix_cache_budget": args.prefix_cache_budget,
        "prefix_cache_dir": args.prefix_cache_dir,
        "sample": res["tokens"][trace[0].rid][:8],
        **res["stats"],
    }
    if draft_plan is not None:
        summary["draft_density"] = draft_plan.meta.get("density_choice",
                                                       {}).get("chosen")
        summary["draft_bytes"] = draft_plan.compressed_bytes()
    return summary


def main(argv=None):
    """CLI entry point: static batched serving or the continuous-batching
    engine (``--engine``), with optional Sparse-on-Dense packing and
    speculative decoding.  Prints and returns a JSON summary."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sod", choices=("tiled_csc", "block_csr"), default=None)
    ap.add_argument("--density", type=float, default=0.3)
    ap.add_argument("--quantize", default="none",
                    choices=("none", "int8", "fp8", "codebook", "auto"),
                    help="packed value quantization: int8/fp8 store "
                         "per-tile-scaled codes, codebook an EIE-style "
                         "shared-value table + 4-bit indices; 'auto' lets "
                         "the planner pick per layer under its accuracy "
                         "drift budget (requires --plan auto)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--engine", action="store_true",
                    help="continuous-batching engine mode: replay a "
                         "synthetic Poisson request trace (ragged "
                         "prompt/gen lengths) instead of one static batch")
    ap.add_argument("--requests", type=int, default=8,
                    help="engine mode: number of requests in the trace")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="engine mode: Poisson arrival rate, requests per "
                         "engine step")
    ap.add_argument("--max-slots", type=int, default=4,
                    help="engine mode: running-batch capacity")
    ap.add_argument("--page-size", type=int, default=16,
                    help="engine mode: KV page size (attention families)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="engine mode: split prompt prefill into chunks of "
                         "this many tokens, interleaved with running decode "
                         "steps (attention families; default: fused "
                         "whole-prompt prefill)")
    ap.add_argument("--preemption", action="store_true",
                    help="engine mode: under pool pressure, swap the "
                         "youngest running sequence's KV pages to host "
                         "memory instead of blocking admission")
    ap.add_argument("--prefix-sharing", action="store_true",
                    help="engine mode: map identical prompt prefixes onto "
                         "refcounted KV pages (copy-on-write); requires "
                         "--prefill-chunk")
    ap.add_argument("--prefix-cache-budget", type=int, default=0,
                    metavar="BYTES",
                    help="engine mode: keep completed prompts' prefix "
                         "pages alive in HBM under this LRU byte budget, "
                         "demoting cold pages to host memory instead of "
                         "freeing them; requires --prefix-sharing "
                         "(0 with --prefix-cache-dir: pure host/disk "
                         "cache, nothing stays HBM-resident)")
    ap.add_argument("--prefix-cache-dir", default=None, metavar="DIR",
                    help="engine mode: spill demoted prefix pages to "
                         "DIR/<token-hash>.npz so the cache survives "
                         "engine restarts; requires --prefix-sharing")
    ap.add_argument("--spec-decode", type=int, default=0, metavar="K",
                    help="engine mode: speculative decoding — a second, "
                         "aggressively sparse pack of the same weights "
                         "drafts K tokens ahead per slot, verified in one "
                         "batched pass (greedy output stays bit-identical; "
                         "default: off).  Composes with --prefill-chunk, "
                         "--preemption, and --prefix-sharing: slots "
                         "mid-prefill sit out draft windows, and a "
                         "preempted slot's speculative pages are rolled "
                         "back, never swapped")
    ap.add_argument("--draft-sparsity", type=float, default=None,
                    help="fraction of draft-tier weights pruned away "
                         "(density = 1 - sparsity); default: let the "
                         "planner's cost model pick from its ladder")
    ap.add_argument("--autotune", action="store_true",
                    help="warm the kernel tuning cache for this model's "
                         "packed weight shapes before serving")
    ap.add_argument("--tuning-cache", default=None,
                    help="tuning-cache JSON path (default: "
                         "$REPRO_TUNING_CACHE or ~/.cache/repro/"
                         "tuning_cache.json)")
    ap.add_argument("--plan", default=None,
                    help="pack plan: JSON path to replay (e.g. dumped by "
                         "dryrun --plan-json), or 'auto' to build one with "
                         "the planner; default: global-config packing")
    ap.add_argument("--plan-json", default=None,
                    help="write the effective pack plan to this path")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON timeline "
                         "(engine phases, request lifecycle, kernel "
                         "dispatch) to PATH — open in Perfetto or "
                         "chrome://tracing; see docs/observability.md")
    ap.add_argument("--metrics-json", default=None, metavar="PATH",
                    help="write a counters/gauges/histograms metrics "
                         "snapshot to PATH")
    args = ap.parse_args(argv)

    tracer = None
    if args.trace:
        # install before any instrumented object exists: the engine,
        # scheduler, and kernel registry capture the global tracer
        tracer = obs.install_tracer(obs.Tracer())

    if args.prefix_sharing and not args.prefill_chunk:
        ap.error("--prefix-sharing requires --prefill-chunk (prefill must "
                 "be able to start mid-prompt to skip shared positions)")
    if ((args.prefix_cache_budget or args.prefix_cache_dir)
            and not args.prefix_sharing):
        ap.error("--prefix-cache-budget/--prefix-cache-dir require "
                 "--prefix-sharing (the cache retains trie-held pages)")
    if args.spec_decode and not args.engine:
        ap.error("--spec-decode requires --engine (draft/verify windows "
                 "run against the paged KV cache)")
    if args.draft_sparsity is not None and not args.spec_decode:
        ap.error("--draft-sparsity requires --spec-decode")
    cfg = configs.get_config(args.arch)
    if args.reduced:
        cfg = configs.reduced(cfg)
    if args.quantize != "none" and not args.sod:
        ap.error("--quantize requires Sparse-on-Dense packing "
                 "(pass --sod tiled_csc|block_csr)")
    if args.quantize == "auto" and args.plan != "auto":
        ap.error("--quantize auto needs the planner (pass --plan auto)")
    if args.sod:
        cfg = cfg.with_(sod=SoDConfig(
            mode=args.sod, density=args.density, min_dim=64,
            qmode=args.quantize if args.quantize != "auto" else "none"))
    model = LM(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = model.init(key)
    tune_stats = None
    plan = None
    if args.plan and not cfg.sod.enabled:
        ap.error("--plan requires Sparse-on-Dense packing "
                 "(pass --sod tiled_csc|block_csr)")
    # prefill consumes (batch·prompt_len, K); decode (batch, K).  Engine
    # mode decodes max_slots rows and prefills one prompt at a time, at
    # the page-aligned bucket length for attention families (batch-1
    # decode-step replay, M=1, for the recurrent ones).
    if args.engine:
        if cfg.family in ("hybrid", "ssm"):
            m_values = (1, args.max_slots)
        elif args.prefill_chunk:
            m_values = (args.prefill_chunk, args.max_slots)
        else:
            from repro.serving import bucket_len

            m_values = (bucket_len(args.prompt_len, args.page_size,
                                   cfg.attn_chunk), args.max_slots)
    else:
        m_values = (args.batch * args.prompt_len, args.batch)
    draft_params = draft_plan = None
    if cfg.sod.enabled or args.spec_decode:
        from repro.kernels import autotune
        from repro.runtime import planner

        # install the cache BEFORE planning: the planner's dispatch hints
        # must come from the same cache file dispatch will read
        cache = autotune.install_cache(args.tuning_cache)
        if cfg.sod.enabled:
            plan = planner.load_or_build(
                args.plan, params, cfg.sod, cfg=cfg, cache=cache,
                m_values=m_values,
                qmode="auto" if args.quantize == "auto" else None)
        if args.spec_decode:
            # draft packs the RAW weights — must happen before the target
            # tier's sodify_params prunes them in place below
            draft_density = (None if args.draft_sparsity is None
                             else 1.0 - args.draft_sparsity)
            draft_cfg, draft_plan = planner.build_draft_plan(
                params, cfg.sod, draft_density=draft_density,
                spec_k=args.spec_decode, cfg=cfg, cache=cache,
                m_values=m_values)
            draft_params = sodify_params(params, draft_cfg, plan=draft_plan)
    if cfg.sod.enabled:
        params = sodify_params(params, cfg.sod, plan=plan)
        if args.autotune:
            if plan is not None:
                tune_stats = planner.warmup_plan(plan, m_values, cache=cache)
            else:
                tune_stats = autotune.warmup_params(params, m_values,
                                                    cache=cache)
            print(f"autotune: {tune_stats} -> {cache.path}")
    if args.plan_json and plan is not None:
        print(f"pack plan -> {plan.save(args.plan_json)}")

    if args.engine:
        with kreg.record_dispatches() as dispatch_log:
            summary = engine_main(args, model, params, plan,
                                  draft_params=draft_params,
                                  draft_plan=draft_plan)
        summary["kernel_dispatch"] = kreg.dispatch_counts(dispatch_log)
        if tune_stats is not None:
            summary["autotune"] = tune_stats
        if plan is not None:
            summary["plan_layers"] = len(plan)
            summary["plan_bytes"] = plan.compressed_bytes()
        _finish_trace(tracer, args, summary)
        print(json.dumps(summary))
        return summary

    data = SyntheticLMData(cfg, args.batch, args.prompt_len, seed=args.seed)
    prompt = {k: v for k, v in data.batch(0).items() if k != "targets"}
    max_len = args.prompt_len + args.gen

    tr = obs.get_tracer()
    mets = obs.Metrics() if args.metrics_json else None
    with kreg.record_dispatches() as dispatch_log:
        t0 = time.perf_counter()
        with tr.span("prefill", track="serve", batch=args.batch,
                     prompt_len=args.prompt_len):
            last_logits, cache, pos0 = prefill_cache(
                model, params, prompt, max_len, plan=plan)
        prefill_s = time.perf_counter() - t0

        decode = jax.jit(steps_mod.make_decode_step(model, plan=plan))
        tok = jnp.argmax(last_logits, axis=-1)
        if cfg.family == "audio":
            tok = tok.reshape(args.batch, 1, cfg.n_codebooks)
        else:
            tok = tok.reshape(args.batch, 1)
        outs = []
        # The first decode step pays the jit compile; timing it with the
        # rest is why the historical tokens/sec numbers were so noisy.
        # Report it as warmup and the remaining steps as steady-state
        # throughput.
        warmup_s = steady_s = 0.0
        t0 = time.perf_counter()
        for t in range(args.gen):
            ts = time.perf_counter()
            with tr.span("decode_step", track="serve", t=t):
                nxt, logits, cache = decode(params, cache, tok,
                                            jnp.asarray(pos0 + t, jnp.int32))
            tok = nxt.reshape(tok.shape)
            outs.append(nxt)
            if mets is not None:
                # host-side dispatch time per step (the device compute is
                # async past step 0); step 0 includes the jit compile
                mets.observe("decode_step_s", time.perf_counter() - ts)
            if t == 0:
                jax.block_until_ready(nxt)
                warmup_s = time.perf_counter() - t0
                t0 = time.perf_counter()
        if args.gen:
            jax.block_until_ready(outs[-1])
            steady_s = time.perf_counter() - t0 if args.gen > 1 else 0.0
        decode_s = warmup_s + steady_s
    if mets is not None:
        mets.counter("generated_tokens", args.batch * args.gen)
        mets.gauge("prefill_s", prefill_s)
        mets.gauge("warmup_s", warmup_s)
        mets.gauge("steady_s", steady_s)
        pathlib.Path(args.metrics_json).write_text(
            json.dumps(mets.snapshot(), indent=2))

    summary = {
        "arch": cfg.name, "batch": args.batch,
        "prompt_len": args.prompt_len, "generated": args.gen,
        "prefill_s": round(prefill_s, 3),
        "warmup_s": round(warmup_s, 3),
        "decode_tok_per_s": round(args.batch * args.gen / max(decode_s, 1e-9), 1),
        "steady_tok_per_s": round(
            args.batch * (args.gen - 1) / max(steady_s, 1e-9), 1)
        if args.gen > 1 else 0.0,
        "sample": _sample_tokens(outs),
    }
    summary["kernel_dispatch"] = kreg.dispatch_counts(dispatch_log)
    if tune_stats is not None:
        summary["autotune"] = tune_stats
    if plan is not None:
        summary["plan_layers"] = len(plan)
        summary["plan_bytes"] = plan.compressed_bytes()
    _finish_trace(tracer, args, summary)
    print(json.dumps(summary))
    return summary


def _finish_trace(tracer, args, summary) -> None:
    """Export the run's trace (when ``--trace``) and uninstall the global
    tracer so later runs in the same process start clean."""
    if tracer is None:
        return
    out = tracer.export(args.trace)
    obs.install_tracer(None)
    summary["trace"] = str(out)


if __name__ == "__main__":
    main()
