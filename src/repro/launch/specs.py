"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the exact abstract inputs for one
(architecture × input-shape) cell:
  * train   → {tokens, targets [, patch_embeds]}
  * prefill → {tokens [, patch_embeds]}
  * decode  → (cache, tokens, pos) with the cache at full seq_len occupancy
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import LM

Params = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def token_specs(cfg: ModelConfig, batch: int, seq: int,
                with_targets: bool) -> dict:
    if cfg.family == "audio":
        toks = _sds((batch, seq, cfg.n_codebooks), jnp.int32)
    elif cfg.family == "vlm":
        toks = _sds((batch, seq - cfg.n_patches), jnp.int32)
    else:
        toks = _sds((batch, seq), jnp.int32)
    out = {"tokens": toks}
    if cfg.family == "vlm":
        out["patch_embeds"] = _sds(
            (batch, cfg.n_patches, cfg.frontend_dim), jnp.dtype(cfg.dtype))
    if with_targets:
        out["targets"] = _sds(toks.shape, jnp.int32)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for one dry-run cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"batch": token_specs(cfg, b, s, with_targets=True)}
    if shape.kind == "prefill":
        return {"batch": token_specs(cfg, b, s, with_targets=False)}
    # decode: one new token against a seq_len-deep cache
    model = LM(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    if cfg.family == "audio":
        toks = _sds((b, 1, cfg.n_codebooks), jnp.int32)
    else:
        toks = _sds((b, 1), jnp.int32)
    return {
        "cache": cache,
        "tokens": toks,
        "pos": _sds((), jnp.int32),
    }


def abstract_params(model: LM, sod_cfg=None, plan=None) -> Params:
    """eval_shape of init (+ optional abstract Sparse-on-Dense packing).

    ``plan`` (a :class:`repro.core.plan.ModelPlan`) packs each leaf at its
    planned format/capacity instead of the global config — the shapes then
    match a concrete ``sodify_params(..., plan=plan)`` exactly.
    """
    from repro.core import sod as sod_mod

    params = jax.eval_shape(
        lambda: model.init(jax.random.PRNGKey(0)))
    if plan is not None or (sod_cfg is not None and sod_cfg.enabled):
        params = sod_mod.sodify_abstract(params, sod_cfg or model.cfg.sod,
                                         plan=plan)
    return params
