import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (the env var MUST precede any jax-importing module)
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this script:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. constructs abstract params / optimizer state / inputs (ShapeDtypeStruct,
     zero allocation) with full sharding specs (DP/TP/EP + ZeRO-1, optional
     Sparse-on-Dense packed weights),
  3. ``jax.jit(step).lower(...).compile()`` — proving the distribution config
     is coherent: sharding mismatches, compile-time OOM or unsupported
     collectives all fail here,
  4. records ``memory_analysis`` / ``cost_analysis`` / per-collective bytes
     parsed from the partitioned HLO into a JSON row consumed by the
     roofline report (EXPERIMENTS.md §Dry-run/§Roofline).

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--sod tiled_csc]
"""
import argparse
import json
import pathlib
import re
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, shape_applicable
from repro.core.sod import SoDConfig
from repro.launch import specs as specs_mod
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models.model import LM
from repro.optim.adamw import AdamW, AdamWConfig
from repro.runtime import sharding as shard_mod

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / \
    "results" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Per-chip bytes moved by each collective family (partitioned module →
    shapes are per-device).  all-reduce counts 2× (ring RS+AG)."""
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip().lstrip("%")
        m = re.match(r"[\w.\-]+\s*=\s*(.+)", stripped)
        if not m:
            continue
        body = m.group(1)
        for kind in _COLLECTIVES:
            if re.search(rf"\b{kind}(-start|-done)?\(", body):
                if kind == "all-to-all" and "all-to-all(" not in body:
                    pass
                shapes = _SHAPE_RE.findall(body.split("(")[0]) or \
                    _SHAPE_RE.findall(body)
                if not shapes:
                    continue
                nbytes = max(_shape_bytes(d, s) for d, s in shapes)
                mult = 2 if kind == "all-reduce" else 1
                out[kind] += nbytes * mult
                count[kind] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


def _build_from_cfg(cfg, shape, mesh, plan=None):
    """jit'd step + abstract args for one (config × shape) on a mesh."""
    model = LM(cfg)
    params = specs_mod.abstract_params(
        model, cfg.sod if cfg.sod.enabled else None, plan=plan)
    p_specs = shard_mod.param_specs(params, cfg, mesh)
    p_sh = shard_mod.to_shardings(p_specs, mesh)
    inputs = specs_mod.input_specs(cfg, shape)

    if shape.kind == "train":
        opt = AdamW(AdamWConfig())
        opt_state = jax.eval_shape(opt.init, params)
        o_specs = shard_mod.opt_state_specs(opt_state, p_specs, mesh)
        o_sh = shard_mod.to_shardings(o_specs, mesh)
        b_specs = shard_mod.batch_specs(inputs["batch"], mesh)
        b_sh = shard_mod.to_shardings(b_specs, mesh)
        step = steps_mod.make_train_step(model, opt, mesh=mesh, plan=plan)
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None))
        args = (params, opt_state, inputs["batch"])
    elif shape.kind == "prefill":
        b_specs = shard_mod.batch_specs(inputs["batch"], mesh)
        b_sh = shard_mod.to_shardings(b_specs, mesh)
        step = steps_mod.make_prefill_step(model, mesh=mesh, plan=plan)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        args = (params, inputs["batch"])
    else:  # decode
        c_specs = shard_mod.cache_specs(
            inputs["cache"], cfg, mesh, shape.global_batch,
            seq_len=shape.seq_len,
            seq_shard=os.environ.get("SOD_SEQ_SHARD_CACHE", "1") == "1")
        c_sh = shard_mod.to_shardings(c_specs, mesh)
        step = steps_mod.make_decode_step(model, mesh=mesh, plan=plan)
        jitted = jax.jit(
            step, in_shardings=(p_sh, c_sh, None, None),
            out_shardings=(None, None, c_sh),
            donate_argnums=(1,))
        args = (params, inputs["cache"], inputs["tokens"], inputs["pos"])
    return jitted, args


def _plan_for_cell(cfg, shape, mesh, plan_path: str | None):
    """Per-layer pack plan for a dry-run cell: replayed from ``plan_path``
    when given, else built by the planner against the cell's abstract
    shapes, mesh, and the persisted tuning cache."""
    if not cfg.sod.enabled:
        return None
    from repro.core.plan import ModelPlan
    from repro.runtime import planner

    if plan_path:
        return ModelPlan.load(plan_path)
    shapes = jax.eval_shape(lambda: LM(cfg).init(jax.random.PRNGKey(0)))
    m_probe = shape.global_batch * (shape.seq_len
                                    if shape.kind != "decode" else 1)
    return planner.build_plan(
        shapes, cfg.sod, cfg=cfg, mesh=mesh,
        m_values=(max(min(m_probe, 4096), 1), shape.global_batch))


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               sod_mode: str | None, density: float,
               scan_layers: bool = True, n_layers: int | None = None,
               plan_path: str | None = None):
    cfg = configs.get_config(arch).with_(scan_layers=scan_layers)
    if n_layers is not None:
        cfg = cfg.with_(n_layers=n_layers)
    if sod_mode:
        cfg = cfg.with_(sod=SoDConfig(mode=sod_mode, density=density))
    if cfg.family == "moe" and os.environ.get("SOD_MOE_BLOCKS", "1") == "1":
        dp = 32 if multi_pod else 16
        cfg = cfg.with_(moe_dispatch_blocks=dp)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = _plan_for_cell(cfg, shape, mesh, plan_path)
    jitted, args = _build_from_cfg(cfg, shape, mesh, plan=plan)
    return cfg, shape, mesh, jitted, args, plan


def _analyze(compiled) -> dict:
    out = {}
    try:
        mem = compiled.memory_analysis()
        out["memory"] = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or
                              getattr(mem, "temp_size_in_bytes", 0)),
        }
    except Exception as e:  # CPU backend may not implement it
        out["memory"] = {"error": str(e)[:200]}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        out["cost"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
            "transcendentals": float(cost.get("transcendentals", 0.0)),
        }
    except Exception as e:
        out["cost"] = {"error": str(e)[:200]}
    try:
        out["collectives"] = collective_bytes(compiled.as_text())
    except Exception as e:
        out["collectives"] = {"error": str(e)[:200]}
    return out


def _group_size(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.hybrid_attn_every
    if cfg.family == "ssm":
        return cfg.slstm_every or 1
    return cfg.pattern_period


def _extrapolate(a1: dict, a2: dict, g1: int, g2: int, g_full: int) -> dict:
    """Linear-in-depth extrapolation from two shallow unrolled probes.

    Layer stacks are homogeneous per group, so every cost counter is affine
    in the group count: total(g) = outside + per_group·g.  Exact — no
    modelling assumption beyond homogeneity.
    """
    out = {}
    for sec in ("cost",):
        if "error" in a1.get(sec, {}) or "error" in a2.get(sec, {}):
            out[sec] = {"error": "probe failed"}
            continue
        out[sec] = {}
        for key in a1[sec]:
            per = (a2[sec][key] - a1[sec][key]) / (g2 - g1)
            outside = a1[sec][key] - per * g1
            out[sec][key] = outside + per * g_full
    c1, c2 = a1.get("collectives", {}), a2.get("collectives", {})
    coll = {}
    for key in _COLLECTIVES + ("total",):
        if key in c1 and key in c2:
            per = (c2[key] - c1[key]) / (g2 - g1)
            coll[key] = max(c1[key] - per * g1 + per * g_full, 0.0)
    out["collectives"] = coll
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             sod_mode: str | None = None, density: float = 0.3,
             probes: bool | None = None, plan_path: str | None = None,
             plan_out: str | None = None) -> dict:
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "sod": sod_mode or "dense", "density": density if sod_mode else 1.0,
    }
    cfg = configs.get_config(arch)
    if not shape_applicable(cfg, SHAPES[shape_name]):
        rec["status"] = "skipped"
        rec["reason"] = "full-attention arch; long_500k requires sub-quadratic"
        return rec

    # ---- 1) full-config compile (scan layers): THE dry-run gate ----------
    t0 = time.perf_counter()
    cfg, shape, mesh, jitted, args, plan = build_cell(
        arch, shape_name, multi_pod, sod_mode, density, scan_layers=True,
        plan_path=plan_path)
    from repro.kernels import registry as kreg

    with mesh, kreg.record_dispatches() as dispatch_log:
        compiled = jitted.lower(*args).compile()
    rec["compile_s"] = round(time.perf_counter() - t0, 1)
    # which registry impls the traced step really ran (mesh fallbacks to
    # the XLA oracle are visible here instead of silent), plus compact
    # per-impl×source totals for tuned-cache coverage at a glance
    rec["kernel_dispatch"] = kreg.dispatch_summary(dispatch_log)
    rec["dispatch_counts"] = kreg.dispatch_counts(dispatch_log)
    if plan is not None:
        # the chosen per-layer plan, path → one-liner (format, tile, cap,
        # dispatch hint, SPMD partitioning)
        rec["pack_plan"] = plan.summary()
        rec["pack_plan_bytes"] = plan.compressed_bytes()
        if plan_out:
            plan.save(plan_out)
            rec["pack_plan_file"] = str(plan_out)
    full = _analyze(compiled)
    rec["memory"] = full["memory"]
    rec["cost_scan_hlo"] = full["cost"]          # while-bodies counted once
    rec["collectives_scan_hlo"] = full["collectives"]
    del compiled

    # ---- 2) depth-probe pair (unrolled) → exact extrapolated costs -------
    # XLA counts while-loop bodies once, so the scan numbers above undercount
    # by ~n_groups; two shallow unrolled probes give the exact affine law.
    if probes is None:
        probes = not multi_pod   # roofline table is single-pod only
    if probes:
        g = _group_size(cfg)
        g_full = cfg.n_layers // g
        analyses = []
        for n_groups in (1, 2):
            t0 = time.perf_counter()
            # probes replay the same plan as the gated cell (a replayed
            # plan's concrete-observed caps differ from freshly built
            # abstract budgets; probe shapes must match the cell's)
            _, _, pmesh, pjit, pargs, _ = build_cell(
                arch, shape_name, multi_pod, sod_mode, density,
                scan_layers=False, n_layers=g * n_groups,
                plan_path=plan_path)
            with pmesh:
                pcomp = pjit.lower(*pargs).compile()
            analyses.append(_analyze(pcomp))
            rec[f"probe{n_groups}_compile_s"] = round(time.perf_counter() - t0, 1)
            del pcomp
        ext = _extrapolate(analyses[0], analyses[1], 1, 2, g_full)
        rec["cost"] = ext["cost"]
        rec["collectives"] = ext["collectives"]
        rec["collectives"]["counts"] = analyses[1]["collectives"].get(
            "counts", {})
    rec["n_devices"] = mesh.devices.size
    rec["params_b"] = cfg.param_count()
    rec["active_params_b"] = cfg.active_param_count()
    rec["status"] = "ok"
    return rec


def _result_path(arch, shape, multi_pod, sod_mode) -> pathlib.Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    return RESULTS_DIR / f"{arch}__{shape}__{mesh}__{sod_mode or 'dense'}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--sod", choices=("tiled_csc", "block_csr"), default=None)
    ap.add_argument("--density", type=float, default=0.3)
    ap.add_argument("--plan", default=None,
                    help="replay a pack-plan JSON instead of building one")
    ap.add_argument("--plan-json", default=None,
                    help="dump the cell's per-layer pack plan to this path "
                         "(replayable by train/serve --plan)")
    ap.add_argument("--all", action="store_true",
                    help="run every cell in subprocesses")
    ap.add_argument("--force", action="store_true", help="recompute cached")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    if args.all:
        jobs = []
        for arch in configs.ARCH_NAMES:
            for shape in SHAPES:
                for mp in (False, True):
                    jobs.append((arch, shape, mp))
        failures = 0
        for arch, shape, mp in jobs:
            path = _result_path(arch, shape, mp, args.sod)
            if path.exists() and not args.force:
                print(f"[cached] {path.name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape]
            if mp:
                cmd.append("--multi-pod")
            if args.sod:
                cmd += ["--sod", args.sod, "--density", str(args.density)]
            print(f"[run] {' '.join(cmd[3:])}", flush=True)
            r = subprocess.run(cmd, timeout=args.timeout,
                               cwd=pathlib.Path(__file__).resolve().parents[3])
            if r.returncode:
                failures += 1
        sys.exit(1 if failures else 0)

    if not (args.arch and args.shape):
        ap.error("--arch and --shape required (or --all)")
    if (args.plan or args.plan_json) and not args.sod:
        ap.error("--plan/--plan-json require --sod tiled_csc|block_csr")
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod, args.sod,
                       args.density, plan_path=args.plan,
                       plan_out=args.plan_json)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "sod": args.sod or "dense",
               "status": "error", "traceback": traceback.format_exc()[-4000:]}
    path = _result_path(args.arch, args.shape, args.multi_pod, args.sod)
    path.write_text(json.dumps(rec, indent=2))
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback",)}, indent=2))
    if rec["status"] == "error":
        print(rec.get("traceback", ""), file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
