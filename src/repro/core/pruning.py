"""Pruning — produces the sparse networks that Sparse-on-Dense consumes.

The paper evaluates unstructured magnitude pruning (Han et al. [16]) for
AlexNet/VGG-16 and movement pruning (Sanh et al. [15]) for BERT, plus the
structured N:M sparsity of STA/S2TA as the "skip decompression" mode.  We
implement the pruning *mechanics* (mask derivation at a target density,
layerwise schedules, N:M, VREG-block) so every assigned architecture can be
pruned to the paper's density profiles; the accuracy recipes themselves are
out of scope (the paper evaluates efficiency, not accuracy).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "magnitude_prune",
    "nm_prune",
    "block_prune",
    "random_sparse",
    "SparsityProfile",
    "PAPER_PROFILES",
    "prune_tree",
]


def magnitude_prune(w: jax.Array, density: float) -> jax.Array:
    """Keep the ``density`` fraction of largest-|w| entries (unstructured)."""
    if not 0.0 < density <= 1.0:
        raise ValueError(f"density must be in (0, 1], got {density}")
    if density >= 1.0:
        return w
    k = max(int(round(w.size * density)), 1)
    flat = jnp.abs(w.reshape(-1))
    # threshold = k-th largest magnitude
    thresh = jax.lax.top_k(flat, k)[0][-1]
    mask = jnp.abs(w) >= thresh
    return jnp.where(mask, w, 0).astype(w.dtype)


def nm_prune(w: jax.Array, n: int = 4, m: int = 8, axis: int = 0) -> jax.Array:
    """Structured N:M pruning: keep ``n`` largest-|w| of every ``m`` along axis.

    STA/S2TA's 4/8 structured sparsity; Sparse-on-Dense runs this by
    *skipping the decompression unit* (Section V-A).
    """
    if w.shape[axis] % m:
        raise ValueError(f"axis size {w.shape[axis]} not divisible by m={m}")
    wm = jnp.moveaxis(w, axis, -1)
    lead = wm.shape[:-1]
    groups = wm.reshape(*lead, wm.shape[-1] // m, m)
    rank = jnp.argsort(jnp.argsort(-jnp.abs(groups), axis=-1), axis=-1)
    mask = rank < n
    pruned = jnp.where(mask, groups, 0).reshape(wm.shape)
    return jnp.moveaxis(pruned, -1, axis).astype(w.dtype)


def block_prune(
    w: jax.Array, density: float, block: tuple[int, int] = (8, 128)
) -> jax.Array:
    """Prune whole (br, bc) blocks by block L2 norm (VREG-granular mode)."""
    br, bc = block
    k, n = w.shape
    kp = (k + br - 1) // br * br
    np_ = (n + bc - 1) // bc * bc
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    blocks = wp.reshape(kp // br, br, np_ // bc, bc)
    norms = jnp.sqrt(jnp.sum(blocks.astype(jnp.float32) ** 2, axis=(1, 3)))
    nb = norms.size
    keep = max(int(round(nb * density)), 1)
    thresh = jax.lax.top_k(norms.reshape(-1), keep)[0][-1]
    mask = (norms >= thresh)[:, None, :, None]
    pruned = jnp.where(mask, blocks, 0).reshape(kp, np_)
    return pruned[:k, :n].astype(w.dtype)


def random_sparse(
    key: jax.Array, shape: tuple[int, ...], density: float, dtype=jnp.float32
) -> jax.Array:
    """Random matrix with exact-ish Bernoulli(density) support (test helper)."""
    kv, km = jax.random.split(key)
    vals = jax.random.normal(kv, shape, jnp.float32)
    mask = jax.random.uniform(km, shape) < density
    # ensure no all-zero matrix for density > 0
    vals = jnp.where(mask, vals, 0)
    return vals.astype(dtype)


# ---------------------------------------------------------------------------
# Layerwise density profiles (paper Table III)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SparsityProfile:
    """Per-matrix-family target densities for a pruned network."""

    name: str
    weight_density: float                 # average over layers
    input_density: float                  # 1.0 = dense activations
    layer_densities: tuple[float, ...] = ()   # optional per-layer detail
    method: str = "magnitude"             # magnitude | movement | nm | block

    def density_for_layer(self, i: int) -> float:
        if self.layer_densities:
            return self.layer_densities[i % len(self.layer_densities)]
        return self.weight_density


# Table III of the paper + per-layer ranges quoted in Section IV-D.
PAPER_PROFILES: Mapping[str, SparsityProfile] = {
    "alexnet_conv": SparsityProfile(
        name="alexnet_conv",
        weight_density=0.41,
        input_density=0.69,
        layer_densities=(0.84, 0.38, 0.35, 0.37, 0.34),
        method="magnitude",
    ),
    "vgg16_conv": SparsityProfile(
        name="vgg16_conv",
        weight_density=0.33,
        input_density=0.61,
        layer_densities=(0.57, 0.41, 0.33, 0.31, 0.31, 0.29, 0.28, 0.26,
                         0.25, 0.26, 0.28, 0.30, 0.22),
        method="magnitude",
    ),
    "bert_squad": SparsityProfile(
        name="bert_squad",
        weight_density=0.33,
        input_density=1.0,
        layer_densities=(0.50, 0.45, 0.42, 0.40, 0.38, 0.36, 0.33, 0.30,
                         0.27, 0.22, 0.12, 0.04),
        method="movement",
    ),
    "bert_mnli": SparsityProfile(
        name="bert_mnli",
        weight_density=0.13,
        input_density=1.0,
        layer_densities=(0.22, 0.20, 0.18, 0.16, 0.15, 0.13, 0.12, 0.10,
                         0.08, 0.06, 0.03, 0.01),
        method="movement",
    ),
    # LSTM density evaluated against ESE (Fig. 8)
    "ese_lstm": SparsityProfile(
        name="ese_lstm", weight_density=0.10, input_density=1.0,
        method="magnitude",
    ),
}


def prune_tree(
    params,
    density: float | Callable[[str], float],
    method: str = "magnitude",
    min_size: int = 4096,
    path_filter: Callable[[str], bool] | None = None,
):
    """Prune every 2-D+ weight in a pytree to the target density.

    ``density`` may be a callable path → density for layerwise profiles.
    Embeddings/norms/biases are skipped via ``min_size`` and dimensionality.
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    out = []
    for path, leaf in flat:
        name = (jax.tree_util.keystr(path).replace("'", "")
                .replace("]", "").replace("[", "."))
        eligible = (
            hasattr(leaf, "ndim")
            and leaf.ndim >= 2
            and leaf.size >= min_size
            and (path_filter is None or path_filter(name))
        )
        if not eligible:
            out.append(leaf)
            continue
        d = density(name) if callable(density) else density
        mat = leaf.reshape(-1, leaf.shape[-1])
        if method == "magnitude":
            pruned = magnitude_prune(mat, d)
        elif method == "block":
            pruned = block_prune(mat, d)
        elif method == "nm":
            m = 8
            n = max(int(round(d * m)), 1)
            pad = (-mat.shape[0]) % m
            matp = jnp.pad(mat, ((0, pad), (0, 0)))
            pruned = nm_prune(matp, n=n, m=m, axis=0)[: mat.shape[0]]
        else:
            raise ValueError(f"unknown pruning method {method!r}")
        out.append(pruned.reshape(leaf.shape).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
