"""Sparse-on-Dense as a composable module: config, packing, apply.

This is the user-facing surface of the paper's technique.  A
:class:`SoDConfig` describes *how* a family of weight matrices is stored and
consumed; :func:`pack_param` prunes + packs a dense weight accordingly;
:func:`apply` is the single matmul entry point every model layer calls —
dense arrays bypass decompression (paper Fig. 2c), packed operands go through
the fused Pallas kernel or the jnp scatter oracle depending on ``impl``.

Because the packed containers are pytrees with exact-zero padding gradients,
a model whose params hold ``TiledCSC`` leaves trains with a fixed sparsity
mask out of the box, and its Adam moments shrink by the same compression
ratio — the paper's "effective on-chip capacity" argument applied to
optimizer state.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import formats, pruning
from repro.core.formats import BlockCSR, TiledCSC

__all__ = ["SoDConfig", "pack_param", "apply", "weight_bytes", "DENSE"]


@dataclasses.dataclass(frozen=True)
class SoDConfig:
    """Storage/compute mode for a family of weight matrices."""

    mode: str = "dense"            # dense | tiled_csc | block_csr
    density: float = 1.0           # pruning target (1.0 = keep as-is)
    prune_method: str = "magnitude"  # magnitude | block | nm
    tile: tuple[int, int] = (128, 128)
    br: int = 8                    # BlockCSR sub-block rows
    impl: str = "auto"             # auto | jnp | pallas
    min_dim: int = 128             # matrices smaller than this stay dense

    def __post_init__(self):
        if self.mode not in ("dense", "tiled_csc", "block_csr"):
            raise ValueError(f"unknown SoD mode {self.mode!r}")

    @property
    def enabled(self) -> bool:
        return self.mode != "dense"


DENSE = SoDConfig()


def pack_param(w: jax.Array, cfg: SoDConfig, prune: bool = True):
    """Prune (optional) and pack one dense 2-D weight per the config.

    Returns the dense array unchanged when the config is dense or the matrix
    is too small to tile profitably.
    """
    if not cfg.enabled or w.ndim != 2 or min(w.shape) < cfg.min_dim:
        return w
    if prune and cfg.density < 1.0:
        if cfg.prune_method == "magnitude":
            w = pruning.magnitude_prune(w, cfg.density)
        elif cfg.prune_method == "block":
            w = pruning.block_prune(w, cfg.density, block=(cfg.br, cfg.tile[1]))
        elif cfg.prune_method == "nm":
            m = 8
            n = max(int(round(cfg.density * m)), 1)
            pad = (-w.shape[0]) % m
            w = pruning.nm_prune(
                jnp.pad(w, ((0, pad), (0, 0))), n=n, m=m, axis=0
            )[: w.shape[0]]
        else:
            raise ValueError(f"unknown prune method {cfg.prune_method!r}")
    if cfg.mode == "tiled_csc":
        return formats.pack_tiled_csc(w, tile=cfg.tile)
    return formats.pack_block_csr(w, tile=cfg.tile, br=cfg.br)


def apply(x: jax.Array, w, cfg: SoDConfig | None = None, **kw) -> jax.Array:
    """``x @ W`` through the Sparse-on-Dense datapath.

    Packed operands dispatch through the kernel registry
    (:mod:`repro.kernels.registry`): ``impl="auto"`` resolves to the
    autotuner's persisted winner for this (format, shape, density, backend)
    or the cost-model-prior default on a cold cache — the differentiable jnp
    oracle on CPU, the fused Pallas kernel on TPU/interpret.  ``impl`` may
    force ``jnp`` or ``pallas`` explicitly.
    """
    from repro.kernels import ops  # local import: kernels depend on core

    impl = kw.pop("impl", cfg.impl if cfg else "auto")
    if isinstance(w, (TiledCSC, BlockCSR)):
        if w.lead:
            # Stacked layouts (lax.scan layer stacks / experts) keep the
            # fused-by-XLA scatter+dot path; the kernels are per-matrix.
            return jnp.dot(
                x, w.to_dense(), preferred_element_type=jnp.float32
            ).astype(kw.pop("out_dtype", x.dtype))
        return ops.sod_matmul(x, w, impl=impl, **kw)
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(
        kw.pop("out_dtype", x.dtype)
    )


def expected_cap(bk: int, density: float) -> int:
    """Static per-column slot budget for Bernoulli(density) sparsity.

    mean + 4σ of Binomial(bk, density), sublane-aligned — the deterministic
    cap the dry-run uses so abstract shapes don't depend on weight values.
    """
    import math

    mean = bk * density
    sigma = math.sqrt(max(bk * density * (1 - density), 1e-9))
    cap = min(bk, int(math.ceil(mean + 4 * sigma)))
    return max((cap + 7) // 8 * 8, 8)


_SOD_PATHS = re.compile(
    r"(wq|wk|wv|wo|w_gate|w_up|w_down|head|w_z|w_x|out_proj)$"
)


def _packable(name: str, leaf) -> bool:
    return (
        hasattr(leaf, "ndim") and leaf.ndim >= 2
        and _SOD_PATHS.search(name) is not None
    )


def sodify_params(params, cfg: SoDConfig, prune: bool = True):
    """Pack every eligible 2-D projection weight in a param pytree."""
    if not cfg.enabled:
        return params
    flat, treedef = _flatten_named(params)
    out = []
    for name, leaf in flat:
        if _packable(name, leaf) and min(leaf.shape[-2:]) >= cfg.min_dim:
            if leaf.ndim == 2:
                out.append(pack_param(leaf, cfg, prune=prune))
            else:
                lead = leaf.shape[:-2]
                flat_w = leaf.reshape((-1,) + leaf.shape[-2:])
                if prune and cfg.density < 1.0:
                    flat_w = jnp.stack([
                        pruning.magnitude_prune(flat_w[i], cfg.density)
                        if cfg.prune_method == "magnitude" else
                        pruning.block_prune(flat_w[i], cfg.density,
                                            block=(cfg.br, cfg.tile[1]))
                        for i in range(flat_w.shape[0])
                    ])
                w = flat_w.reshape(lead + leaf.shape[-2:])
                if cfg.mode == "tiled_csc":
                    out.append(formats.pack_tiled_csc(w, tile=cfg.tile))
                else:
                    out.append(formats.pack_block_csr(w, tile=cfg.tile,
                                                      br=cfg.br))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def sodify_abstract(params_sds, cfg: SoDConfig):
    """ShapeDtypeStruct version for the dry-run: deterministic cap."""
    if not cfg.enabled:
        return params_sds
    flat, treedef = _flatten_named(params_sds)
    bk, bn = cfg.tile
    out = []
    for name, leaf in flat:
        if not (_packable(name, leaf) and min(leaf.shape[-2:]) >= cfg.min_dim):
            out.append(leaf)
            continue
        lead = tuple(leaf.shape[:-2])
        k, n = leaf.shape[-2:]
        kt, nt = -(-k // bk), -(-n // bn)
        if cfg.mode == "tiled_csc":
            cap = expected_cap(bk, cfg.density)
            idx = jnp.int8 if bk <= 128 else jnp.int32
            out.append(TiledCSC(
                vals=jax.ShapeDtypeStruct(lead + (kt, nt, cap, bn),
                                          leaf.dtype),
                rows=jax.ShapeDtypeStruct(lead + (kt, nt, cap, bn), idx),
                shape=(k, n), tile=cfg.tile))
        else:
            nb = bk // cfg.br
            bcap = max(min(int(nb * cfg.density * 1.5 + 2), nb), 1)
            out.append(BlockCSR(
                block_vals=jax.ShapeDtypeStruct(
                    lead + (kt, nt, bcap, cfg.br, bn), leaf.dtype),
                block_ids=jax.ShapeDtypeStruct(lead + (kt, nt, bcap),
                                               jnp.int32),
                tile_nnz=jax.ShapeDtypeStruct(lead + (kt, nt), jnp.int32),
                shape=(k, n), tile=cfg.tile, br=cfg.br))
    return jax.tree_util.tree_unflatten(treedef, out)


def _flatten_named(tree):
    is_packed = lambda l: isinstance(l, (TiledCSC, BlockCSR))
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_packed)
    named = [
        (jax.tree_util.keystr(p).replace("'", "").replace("]", "")
         .replace("[", "."), l)
        for p, l in flat
    ]
    return named, treedef


def weight_bytes(w, value_bits: int = 16, index_bits: int = 8) -> int:
    """Bytes this operand occupies in memory (compressed when packed)."""
    if isinstance(w, TiledCSC):
        return w.nbytes_compressed(value_bits, index_bits)
    if isinstance(w, BlockCSR):
        return w.nbytes_compressed(value_bits)
    if hasattr(w, "size"):
        return int(w.size) * value_bits // 8
    return 0


def tree_weight_bytes(params: Any) -> dict[str, int]:
    """Compressed vs dense byte totals over a parameter pytree."""
    compressed = 0
    dense = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda l: isinstance(l, (TiledCSC, BlockCSR))
    ):
        if isinstance(leaf, (TiledCSC, BlockCSR)):
            compressed += leaf.nbytes_compressed()
            dense += leaf.nbytes_dense()
        elif hasattr(leaf, "size"):
            b = int(leaf.size) * 2
            compressed += b
            dense += b
    return {"compressed": compressed, "dense": dense,
            "ratio": compressed / max(dense, 1)}
