"""Sparse-on-Dense as a composable module: config, packing, apply.

This is the user-facing surface of the paper's technique.  A
:class:`SoDConfig` describes *how* a family of weight matrices is stored and
consumed; :func:`pack_param` prunes + packs a dense weight accordingly;
:func:`apply` is the single matmul entry point every model layer calls —
dense arrays bypass decompression (paper Fig. 2c), packed operands go through
the fused Pallas kernel or the jnp scatter oracle depending on ``impl``.

Because the packed containers are pytrees with exact-zero padding gradients,
a model whose params hold ``TiledCSC`` leaves trains with a fixed sparsity
mask out of the box, and its Adam moments shrink by the same compression
ratio — the paper's "effective on-chip capacity" argument applied to
optimizer state.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import formats, pruning
from repro.core import plan as plan_mod
from repro.core.formats import BlockCSR, TiledCSC
from repro.core.plan import ModelPlan, PackPlan

__all__ = ["SoDConfig", "pack_param", "prune_weight", "apply",
           "weight_bytes", "DENSE"]


@dataclasses.dataclass(frozen=True)
class SoDConfig:
    """Storage/compute mode for a family of weight matrices."""

    mode: str = "dense"            # dense | tiled_csc | block_csr
    density: float = 1.0           # pruning target (1.0 = keep as-is)
    prune_method: str = "magnitude"  # magnitude | block | nm
    tile: tuple[int, int] = (128, 128)
    br: int = 8                    # BlockCSR sub-block rows
    impl: str = "auto"             # auto | jnp | pallas
    min_dim: int = 128             # matrices smaller than this stay dense
    qmode: str = "none"            # none | int8 | fp8 | codebook

    def __post_init__(self):
        if self.mode not in ("dense", "tiled_csc", "block_csr"):
            raise ValueError(f"unknown SoD mode {self.mode!r}")
        if self.qmode not in plan_mod.QMODES:
            raise ValueError(f"unknown SoD qmode {self.qmode!r}")

    @property
    def enabled(self) -> bool:
        """True when a Sparse-on-Dense mode is configured."""
        return self.mode != "dense"


DENSE = SoDConfig()


def prune_weight(w: jax.Array, density: float, method: str = "magnitude",
                 tile: tuple[int, int] = (128, 128), br: int = 8) -> jax.Array:
    """Prune one 2-D weight to ``density`` with the named method.

    The single pruning entry point shared by :func:`pack_param`, the
    stacked-leaf path in :func:`sodify_params`, and the planner — so every
    path supports all three methods and unknown methods raise instead of
    silently falling through.
    """
    if density >= 1.0:
        return w
    if method == "magnitude":
        return pruning.magnitude_prune(w, density)
    if method == "block":
        return pruning.block_prune(w, density, block=(br, tile[1]))
    if method == "nm":
        m = 8
        n = max(int(round(density * m)), 1)
        pad = (-w.shape[0]) % m
        return pruning.nm_prune(
            jnp.pad(w, ((0, pad), (0, 0))), n=n, m=m, axis=0
        )[: w.shape[0]]
    raise ValueError(f"unknown prune method {method!r}")


def pack_param(w: jax.Array, cfg: SoDConfig, prune: bool = True):
    """Prune (optional) and pack one dense 2-D weight per the config.

    Returns the dense array unchanged when the config is dense or the matrix
    is too small to tile profitably.
    """
    if not cfg.enabled or w.ndim != 2 or min(w.shape) < cfg.min_dim:
        return w
    if prune and cfg.density < 1.0:
        w = prune_weight(w, cfg.density, cfg.prune_method, cfg.tile, cfg.br)
    if cfg.mode == "tiled_csc":
        return formats.pack_tiled_csc(w, tile=cfg.tile, qmode=cfg.qmode)
    return formats.pack_block_csr(w, tile=cfg.tile, br=cfg.br,
                                  qmode=cfg.qmode)


def _layout_key(w) -> tuple:
    """Layout signature of a packed operand — matches
    :meth:`repro.core.plan.PackPlan.layout_key`."""
    if isinstance(w, TiledCSC):
        return ("tiled_csc", tuple(int(s) for s in w.shape),
                tuple(int(t) for t in w.tile), int(w.cap), 0, w.qmode)
    return ("block_csr", tuple(int(s) for s in w.shape),
            tuple(int(t) for t in w.tile), int(w.bcap), int(w.br), w.qmode)


def _plan_spmd(entry: PackPlan):
    """Runtime :class:`repro.runtime.spmd.SpmdPlan` from a plan entry's
    serialized spmd fields — only when a matching mesh is active."""
    from repro.runtime import spmd as spmd_mod  # deferred: runtime over core

    mesh = spmd_mod.active_mesh()
    if mesh is None or spmd_mod.in_spmd_body():
        return None
    mp = plan_mod.active_plan()
    if mp is not None and mp.mesh and mp.mesh != spmd_mod.mesh_key(mesh):
        return None  # plan was built for a different mesh
    sp = spmd_mod.SpmdPlan.from_dict(entry.spmd)
    if not set(sp.axes()) <= set(mesh.axis_names):
        return None
    return sp


def apply(x: jax.Array, w, cfg: SoDConfig | None = None,
          plan: PackPlan | None = None, **kw) -> jax.Array:
    """``x @ W`` through the Sparse-on-Dense datapath.

    Packed operands dispatch through the kernel registry
    (:mod:`repro.kernels.registry`): ``impl="auto"`` resolves to the
    autotuner's persisted winner for this (format, shape, density, backend)
    or the cost-model-prior default on a cold cache — the differentiable jnp
    oracle on CPU, the fused Pallas kernel on TPU/interpret.  ``impl`` may
    force ``jnp`` or ``pallas`` explicitly.

    ``plan`` is the layer's :class:`~repro.core.plan.PackPlan` (model blocks
    thread it through); when omitted and a :class:`~repro.core.plan.ModelPlan`
    is active (:func:`repro.core.plan.use_plan`), the operand's layout
    signature resolves it.  The plan supplies the impl hint, tuned dispatch
    parameters, and the per-layer SPMD partition plan — explicit kwargs
    always win.
    """
    from repro.kernels import ops  # local import: kernels depend on core

    if isinstance(w, (TiledCSC, BlockCSR)):
        if w.lead:
            # Stacked layouts (lax.scan layer stacks / experts) keep the
            # fused-by-XLA scatter+dot path; the kernels are per-matrix.
            return jnp.dot(
                x, w.to_dense(), preferred_element_type=jnp.float32
            ).astype(kw.pop("out_dtype", x.dtype))
        if plan is None:
            plan = plan_mod.lookup_active(_layout_key(w))
        if plan is not None:
            # an explicit impl= from the caller (e.g. debugging a kernel at
            # its defaults) disables the plan's impl hint AND its params
            user_forced = "impl" in kw
            if not user_forced and plan.impl != "auto":
                kw["impl"] = plan.impl
            if (plan.dispatch_params and not user_forced
                    and "fallback_params" not in kw):
                # hint seeds cold-cache dispatch only; a measured tuning-
                # cache entry for the actual (layout, M) always wins
                kw["fallback_params"] = plan.dispatch_params
            if plan.spmd and kw.get("spmd", "auto") == "auto":
                sp = _plan_spmd(plan)
                if sp is not None:
                    kw["spmd"] = sp
        impl = kw.pop("impl", cfg.impl if cfg else "auto")
        return ops.sod_matmul(x, w, impl=impl, **kw)
    kw.pop("impl", None)
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(
        kw.pop("out_dtype", x.dtype)
    )


def expected_cap(bk: int, density: float) -> int:
    """Static per-column slot budget for Bernoulli(density) sparsity.

    mean + 4σ of Binomial(bk, density), sublane-aligned — the deterministic
    cap the dry-run uses so abstract shapes don't depend on weight values.
    (The math lives in :mod:`repro.core.plan` next to the other shared
    sizing functions; this re-export keeps the historical name.)
    """
    return plan_mod.expected_cap(bk, density)


_SOD_PATHS = re.compile(
    r"(wq|wk|wv|wo|w_gate|w_up|w_down|head|w_z|w_x|out_proj)$"
)


def _packable(name: str, leaf) -> bool:
    return (
        hasattr(leaf, "ndim") and leaf.ndim >= 2
        and _SOD_PATHS.search(name) is not None
    )


def _prune_leaf(leaf, density: float, method: str, tile: tuple[int, int],
                br: int):
    """Prune one (possibly stacked) leaf — the single per-slice prune loop
    shared by :func:`sodify_params`, :func:`_pack_planned` and the
    planner's observed-capacity pass."""
    if leaf.ndim == 2:
        return prune_weight(leaf, density, method, tile, br)
    lead = leaf.shape[:-2]
    flat_w = leaf.reshape((-1,) + leaf.shape[-2:])
    flat_w = jnp.stack([
        prune_weight(flat_w[i], density, method, tile, br)
        for i in range(flat_w.shape[0])
    ])
    return flat_w.reshape(lead + leaf.shape[-2:])


def _check_plan_truncation(name: str, w, packed) -> None:
    """Warn when a plan's fixed capacity dropped non-zeros.

    A plan built from abstract shapes budgets capacities statistically
    (mean + 4σ); weights whose survivors cluster by column can need more.
    Packing still succeeds (ESE-style load capping, largest-|value| kept)
    but the replay is then lossy — that must never be silent.
    """
    import warnings

    total = int(jnp.count_nonzero(w))
    if isinstance(packed, TiledCSC):
        stored = int(jnp.sum(packed.rows >= 0))
    else:
        # invalid blocks are zeroed; valid blocks store raw values
        stored = int(jnp.count_nonzero(packed.block_vals))
    if stored < total:
        warnings.warn(
            f"pack plan capacity truncated {total - stored} of {total} "
            f"non-zeros on {name!r} (cap budget below the data's "
            f"requirement); re-plan against concrete weights or raise the "
            f"entry's cap/bcap", stacklevel=2)


def _pack_planned(name: str, leaf, entry: PackPlan, prune: bool):
    """Prune + pack one leaf per its :class:`~repro.core.plan.PackPlan`.

    The plan's explicit ``cap``/``bcap`` (not the data) size the containers,
    so a plan built against abstract shapes replays on concrete weights with
    byte-identical layouts — and hence identical tuning-cache keys.  A
    ``mode="dense"`` entry stores the layer dense but still prunes it — the
    plan chooses the storage format, not whether the layer is sparse.
    """
    if getattr(leaf, "ndim", 0) < 2:
        return leaf
    w = leaf
    if prune and entry.density < 1.0:
        w = _prune_leaf(w, entry.density, entry.prune_method, entry.tile,
                        entry.br)
    if entry.mode == "dense":
        return w
    if entry.mode == "tiled_csc":
        packed = formats.pack_tiled_csc(w, tile=entry.tile, cap=entry.cap)
    else:
        packed = formats.pack_block_csr(w, tile=entry.tile, br=entry.br,
                                        bcap=entry.bcap)
    # truncation is judged on the unquantized pack: quantization may round
    # small survivors to code 0, which is lossy-by-design, not capacity loss
    _check_plan_truncation(name, w, packed)
    if entry.qmode != "none":
        packed = formats.quantize_packed(packed, entry.qmode)
    return packed


def sodify_params(params, cfg: SoDConfig, prune: bool = True,
                  plan: ModelPlan | None = None):
    """Pack every eligible 2-D projection weight in a param pytree.

    With a :class:`~repro.core.plan.ModelPlan` (see
    :mod:`repro.runtime.planner`) each leaf follows its own per-layer entry
    — format, tile, explicit capacity — and unplanned leaves stay dense
    (strict replay: the packed tree is exactly what the plan says, nothing
    more).  Without a plan, behaviour is the historical global-config pack
    with data-dependent (lossless) capacities.
    """
    if plan is None and not cfg.enabled:
        return params
    flat, treedef = _flatten_named(params)
    out = []
    for name, leaf in flat:
        if plan is not None:
            entry = plan.get(name)
            out.append(leaf if entry is None
                       else _pack_planned(name, leaf, entry, prune))
            continue
        if _packable(name, leaf) and min(leaf.shape[-2:]) >= cfg.min_dim:
            if leaf.ndim == 2:
                out.append(pack_param(leaf, cfg, prune=prune))
            else:
                w = leaf
                if prune and cfg.density < 1.0:
                    w = _prune_leaf(w, cfg.density, cfg.prune_method,
                                    cfg.tile, cfg.br)
                if cfg.mode == "tiled_csc":
                    out.append(formats.pack_tiled_csc(w, tile=cfg.tile,
                                                      qmode=cfg.qmode))
                else:
                    out.append(formats.pack_block_csr(w, tile=cfg.tile,
                                                      br=cfg.br,
                                                      qmode=cfg.qmode))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def _abstract_qside(lead, kt, nt, dtype, qmode):
    """(value dtype, scale SDS, codebook SDS) for an abstract quantized pack.

    Mirrors the concrete side-band shapes :func:`repro.core.formats.
    quantize_packed` produces: per-tile f32 scale for int8/fp8, a per-lead
    shared-value table for codebook mode.
    """
    if qmode == "none":
        return dtype, None, None
    if qmode == "codebook":
        book = jax.ShapeDtypeStruct(
            lead + (formats.CODEBOOK_SIZE,), jnp.float32)
        return jnp.int8, None, book
    scale = jax.ShapeDtypeStruct(lead + (kt, nt), jnp.float32)
    if qmode == "fp8":
        fp8 = formats.fp8_dtype()
        if fp8 is None:
            raise ValueError(
                "qmode='fp8' needs a jax build with float8_e4m3fn")
        return fp8, scale, None
    return jnp.int8, scale, None


def _abstract_tiled(lead, k, n, dtype, tile, cap,
                    qmode: str = "none") -> TiledCSC:
    bk, bn = tile
    kt, nt = -(-k // bk), -(-n // bn)
    idx = jnp.int8 if bk <= 128 else jnp.int32
    vdt, scale, codebook = _abstract_qside(lead, kt, nt, dtype, qmode)
    return TiledCSC(
        vals=jax.ShapeDtypeStruct(lead + (kt, nt, cap, bn), vdt),
        rows=jax.ShapeDtypeStruct(lead + (kt, nt, cap, bn), idx),
        shape=(k, n), tile=tuple(tile),
        scale=scale, codebook=codebook, qmode=qmode)


def _abstract_block(lead, k, n, dtype, tile, br, bcap,
                    qmode: str = "none") -> BlockCSR:
    bk, bn = tile
    kt, nt = -(-k // bk), -(-n // bn)
    vdt, scale, codebook = _abstract_qside(lead, kt, nt, dtype, qmode)
    return BlockCSR(
        block_vals=jax.ShapeDtypeStruct(lead + (kt, nt, bcap, br, bn), vdt),
        block_ids=jax.ShapeDtypeStruct(lead + (kt, nt, bcap), jnp.int32),
        tile_nnz=jax.ShapeDtypeStruct(lead + (kt, nt), jnp.int32),
        shape=(k, n), tile=tuple(tile), br=br,
        scale=scale, codebook=codebook, qmode=qmode)


def sodify_abstract(params_sds, cfg: SoDConfig,
                    plan: ModelPlan | None = None):
    """ShapeDtypeStruct version for the dry-run: deterministic capacities.

    With a plan, each entry's explicit ``cap``/``bcap`` is used — the exact
    shapes :func:`sodify_params` produces under the same plan.  Without one,
    capacities come from the shared sizing functions in
    :mod:`repro.core.plan` (:func:`~repro.core.plan.tiled_cap` /
    :func:`~repro.core.plan.block_bcap`), the same budgets the planner
    assigns when it has no weight values to observe.
    """
    if plan is None and not cfg.enabled:
        return params_sds
    flat, treedef = _flatten_named(params_sds)
    bk, bn = cfg.tile
    out = []
    for name, leaf in flat:
        if plan is not None:
            entry = plan.get(name)
            if entry is None or entry.mode == "dense":
                out.append(leaf)
                continue
            lead = tuple(leaf.shape[:-2])
            k, n = leaf.shape[-2:]
            if entry.mode == "tiled_csc":
                cap = entry.cap if entry.cap is not None else \
                    plan_mod.tiled_cap(entry.tile[0], entry.density)
                out.append(_abstract_tiled(lead, k, n, leaf.dtype,
                                           entry.tile, cap,
                                           qmode=entry.qmode))
            else:
                bcap = entry.bcap if entry.bcap is not None else \
                    plan_mod.block_bcap(
                        entry.tile[0] // entry.br, entry.density,
                        entry.prune_method, entry.br * entry.tile[1])
                out.append(_abstract_block(lead, k, n, leaf.dtype,
                                           entry.tile, entry.br, bcap,
                                           qmode=entry.qmode))
            continue
        if not (_packable(name, leaf) and min(leaf.shape[-2:]) >= cfg.min_dim):
            out.append(leaf)
            continue
        lead = tuple(leaf.shape[:-2])
        k, n = leaf.shape[-2:]
        if cfg.mode == "tiled_csc":
            cap = plan_mod.tiled_cap(bk, cfg.density)
            out.append(_abstract_tiled(lead, k, n, leaf.dtype, cfg.tile, cap,
                                       qmode=cfg.qmode))
        else:
            bcap = plan_mod.block_bcap(bk // cfg.br, cfg.density,
                                       cfg.prune_method, cfg.br * bn)
            out.append(_abstract_block(lead, k, n, leaf.dtype, cfg.tile,
                                       cfg.br, bcap, qmode=cfg.qmode))
    return jax.tree_util.tree_unflatten(treedef, out)


def _flatten_named(tree):
    is_packed = lambda l: isinstance(l, (TiledCSC, BlockCSR))
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=is_packed)
    named = [
        (jax.tree_util.keystr(p).replace("'", "").replace("]", "")
         .replace("[", "."), l)
        for p, l in flat
    ]
    return named, treedef


def weight_bytes(w, value_bits: int | None = None,
                 index_bits: int = 8) -> int:
    """Bytes this operand occupies in memory (compressed when packed).

    ``value_bits=None`` (default) counts packed values at the container's
    own quantized width (plus scale/codebook side bands); an explicit
    ``value_bits`` overrides.  Dense arrays are sized at 16-bit by default.
    """
    if isinstance(w, TiledCSC):
        return w.nbytes_compressed(value_bits, index_bits)
    if isinstance(w, BlockCSR):
        return w.nbytes_compressed(value_bits)
    if hasattr(w, "size"):
        return int(w.size) * (16 if value_bits is None else value_bits) // 8
    return 0


def tree_weight_bytes(params: Any) -> dict[str, int]:
    """Compressed vs dense byte totals over a parameter pytree."""
    compressed = 0
    dense = 0
    for leaf in jax.tree_util.tree_leaves(
        params, is_leaf=lambda l: isinstance(l, (TiledCSC, BlockCSR))
    ):
        if isinstance(leaf, (TiledCSC, BlockCSR)):
            compressed += leaf.nbytes_compressed()
            dense += leaf.nbytes_dense()
        elif hasattr(leaf, "size"):
            b = int(leaf.size) * 2
            compressed += b
            dense += b
    return {"compressed": compressed, "dense": dense,
            "ratio": compressed / max(dense, 1)}
