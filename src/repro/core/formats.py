"""Sparse data formats for Sparse-on-Dense.

The paper stores non-zero data in the global buffer in CSC form (16-bit
values, 8-bit row indices, column pointers) and re-densifies tiles on the fly
in a decompression unit placed between the buffer and the dense PE array.

XLA/Pallas need static shapes, so the executable TPU formats are *padded*
variants with a static per-column (or per-tile) capacity:

  * :class:`TiledCSC`  — element-granular, paper-faithful.  The matrix is cut
    into (bk, bn) tiles; each tile column stores up to ``cap`` non-zeros as
    (value, in-tile row index).  Lossless when ``cap`` >= the max column
    non-zero count over all tiles (the default).
  * :class:`BlockCSR`  — TPU-native adaptation.  (br, bc) = (8, 128)
    VREG-shaped sub-blocks; decompression is whole-register gather and
    all-zero MXU macro-tiles can be skipped.
  * :class:`Bitmap`    — SIGMA-style bitmap + packed values (used for
    footprint comparisons and as a third executable format).
  * :func:`pack_csc` / :func:`unpack_csc` — classic pointer CSC (numpy),
    used by the cost model for exact footprint accounting.

All executable formats are registered as JAX pytrees, are differentiable
through ``to_dense`` (scatter-add ⇒ gather gradient onto the fixed mask —
this is what makes fixed-mask sparse *training* work for free), and carry
byte-accounting helpers that honour the paper's 16-bit value / 8-bit index
assumption as well as the TPU bf16/int8 layout.

Quantized value storage (``qmode``)
-----------------------------------

On top of sparsity, the packed value buffers can be stored quantized
(EIE-style weight sharing taken to the SoD formats).  Both executable
formats carry a ``qmode`` axis:

  * ``"none"``     — values stay in the pack dtype (fp32/bf16); default.
  * ``"int8"``     — symmetric int8 with one fp scale per (bk, bn) tile.
  * ``"fp8"``      — float8_e4m3 values with one fp scale per tile.
  * ``"codebook"`` — EIE-style weight sharing: a per-matrix table of
    ``CODEBOOK_SIZE`` shared fp values (entry 0 reserved for 0.0) and a
    narrow per-slot index into it.

Quantization happens at pack time (:func:`quantize_packed`, called by the
packers); dequantization is fused into the Pallas decompress loops and
into ``to_dense`` so every consumer — oracle, VJP, SPMD gather — sees the
dequantized weight.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TiledCSC",
    "BlockCSR",
    "Bitmap",
    "pack_tiled_csc",
    "pack_block_csr",
    "pack_bitmap",
    "pack_csc",
    "unpack_csc",
    "density",
    "padded_shape",
    "observed_tiled_cap",
    "observed_block_cap",
    "quantize_packed",
    "qvalue_bits",
    "fp8_dtype",
    "QMODES",
    "CODEBOOK_SIZE",
]

# -- quantized value storage -------------------------------------------------
# The accounting constants are shared with the (jax-free) plan layer so the
# planner's compressed_bytes can never drift from the packed containers'.
from repro.core.plan import (  # noqa: E402
    CODEBOOK_SIZE, QMODES, QVALUE_BITS, SCALE_BITS)


def fp8_dtype():
    """The fp8 value dtype (``float8_e4m3fn``), or ``None`` when this jax
    build has no fp8 support — callers gate the ``"fp8"`` qmode on it."""
    return getattr(jnp, "float8_e4m3fn", None)


def qvalue_bits(qmode: str, ncodes: int = CODEBOOK_SIZE) -> int:
    """Paper-accounting bits per stored value slot under ``qmode``.

    ``"none"`` keeps the paper's 16-bit value assumption; int8/fp8 store one
    byte; codebook stores only the index into the shared table
    (``ceil(log2(ncodes))``, 4 bits at the default table size).
    """
    if qmode == "codebook":
        return max(int(np.ceil(np.log2(max(ncodes, 2)))), 1)
    if qmode in (None, "none"):
        qmode = "none"
    if qmode not in QVALUE_BITS:
        raise ValueError(
            f"unknown qmode {qmode!r} (expected one of {QMODES})")
    return QVALUE_BITS[qmode]


def _check_qmode(qmode: str) -> str:
    qmode = qmode or "none"
    if qmode not in QMODES:
        raise ValueError(f"unknown qmode {qmode!r} (expected one of {QMODES})")
    if qmode == "fp8" and fp8_dtype() is None:
        raise ValueError("qmode='fp8' needs jnp.float8_e4m3fn, which this "
                         "jax build does not provide")
    return qmode


def density(x) -> float:
    """Fraction of non-zero elements."""
    x = np.asarray(x)
    if x.size == 0:
        return 0.0
    return float(np.count_nonzero(x)) / float(x.size)


def padded_shape(shape: tuple[int, int], tile: tuple[int, int]) -> tuple[int, int]:
    """Round ``shape`` up to whole multiples of ``tile``."""
    bk, bn = tile
    k, n = shape
    return ((k + bk - 1) // bk * bk, (n + bn - 1) // bn * bn)


def _pad_to_tiles(w: jax.Array, tile: tuple[int, int]) -> jax.Array:
    k, n = w.shape
    kp, np_ = padded_shape((k, n), tile)
    if (kp, np_) != (k, n):
        w = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    return w


def observed_tiled_cap(w, tile: tuple[int, int]) -> int:
    """Max per-tile-column non-zero count over a (possibly stacked) matrix —
    the data-dependent capacity :func:`pack_tiled_csc` uses (unaligned).

    The single source of truth for this number: the packer's stacked branch
    and the planner's observed-cap pass both call it, so planned capacities
    can never drift from what a lossless global pack would choose.
    """
    w = jnp.asarray(w)
    if not w.size:
        return 0
    bk, bn = tile
    flat = w.reshape((-1,) + w.shape[-2:])
    wp = jax.vmap(lambda m: _pad_to_tiles(m, tile))(flat)
    kp, np_ = wp.shape[-2:]
    t = wp.reshape(flat.shape[0], kp // bk, bk, np_ // bn, bn)
    return int(jnp.max(jnp.sum(t != 0, axis=2)))


def observed_block_cap(w, tile: tuple[int, int], br: int) -> int:
    """Max non-zero (br, bn) sub-block count per macro tile over a (possibly
    stacked) matrix — the data-dependent bcap :func:`pack_block_csr` uses."""
    w = jnp.asarray(w)
    if not w.size:
        return 0
    bk, bn = tile
    flat = w.reshape((-1,) + w.shape[-2:])
    wp = jax.vmap(lambda m: _pad_to_tiles(m, tile))(flat)
    kp, np_ = wp.shape[-2:]
    blk = wp.reshape(flat.shape[0], kp // bk, bk // br, br, np_ // bn, bn)
    nz = jnp.any(blk != 0, axis=(3, 5))
    return int(jnp.max(jnp.sum(nz, axis=2)))


# ---------------------------------------------------------------------------
# Quantization helpers shared by both executable formats
# ---------------------------------------------------------------------------
def _fit_codebook(x: np.ndarray, ncodes: int) -> np.ndarray:
    """EIE-style shared-value table via 1-D Lloyd k-means (deterministic).

    Entry 0 is reserved for exactly 0.0 so padding slots (and pruned
    positions inside stored blocks) round-trip to zero; the remaining
    ``ncodes - 1`` centroids are quantile-initialised over the non-zero
    values and refined for a few Lloyd iterations.
    """
    book = np.zeros((ncodes,), np.float32)
    nz = np.asarray(x, np.float32).ravel()
    nz = nz[nz != 0]
    if nz.size == 0:
        return book
    k = ncodes - 1
    cent = np.quantile(nz, np.linspace(0.0, 1.0, k))
    # collapsed quantiles (few distinct values) would alias centroids;
    # nudge them apart so argmin assignment stays well defined
    cent = cent + np.arange(k) * 1e-12
    for _ in range(8):
        assign = np.argmin(np.abs(nz[:, None] - cent[None, :]), axis=1)
        for i in range(k):
            sel = assign == i
            if sel.any():
                cent[i] = nz[sel].mean()
    book[1:] = np.sort(cent)
    return book


def _dequant_values(vals, scale, codebook, qmode: str, nval_dims: int):
    """Dequantize a packed value buffer back to float32.

    ``vals`` is ``(*lead, Kt, Nt, *value_dims)`` with ``nval_dims`` trailing
    value dims (2 for TiledCSC's ``(cap, bn)``, 3 for BlockCSR's
    ``(bcap, br, bn)``); ``scale`` is ``(*lead, Kt, Nt)``; ``codebook`` is
    ``(*lead, ncodes)``.  Differentiable in ``scale`` / ``codebook``, which
    is what routes training gradients into the quantization parameters.
    """
    if qmode in (None, "none"):
        return vals
    if qmode in ("int8", "fp8"):
        s = scale.reshape(scale.shape + (1,) * nval_dims)
        return vals.astype(jnp.float32) * s
    if qmode == "codebook":
        lead_ndim = vals.ndim - 2 - nval_dims
        idx = vals.astype(jnp.int32).reshape(vals.shape[:lead_ndim] + (-1,))
        out = jnp.take_along_axis(codebook.astype(jnp.float32), idx, axis=-1)
        return out.reshape(vals.shape)
    raise ValueError(f"unknown qmode {qmode!r}")


def _quantize_values(vals, qmode: str, nval_dims: int, ncodes: int):
    """Quantize a packed fp value buffer; returns ``(qvals, scale, codebook)``.

    Shapes as in :func:`_dequant_values`.  Padding slots hold value 0 and
    map to quantized 0 (int8/fp8) or codebook entry 0 in every mode, so the
    sentinel-row/-id masking downstream keeps working unchanged.
    """
    qmode = _check_qmode(qmode)
    if qmode == "none":
        return vals, None, None
    tile_axes = tuple(range(vals.ndim - nval_dims, vals.ndim))
    absmax = jnp.max(jnp.abs(vals.astype(jnp.float32)), axis=tile_axes)
    if qmode == "int8":
        scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
        s = scale.reshape(scale.shape + (1,) * nval_dims)
        q = jnp.clip(jnp.round(vals.astype(jnp.float32) / s), -127, 127)
        return q.astype(jnp.int8), scale.astype(jnp.float32), None
    if qmode == "fp8":
        scale = jnp.where(absmax > 0, absmax / 448.0, 1.0)
        s = scale.reshape(scale.shape + (1,) * nval_dims)
        q = (vals.astype(jnp.float32) / s).astype(fp8_dtype())
        return q, scale.astype(jnp.float32), None
    # codebook: fit one shared-value table per lead slice (host-side numpy —
    # packing is an eager, concrete-weights operation)
    if isinstance(vals, jax.core.Tracer):
        raise ValueError("qmode='codebook' needs concrete weights at pack "
                         "time (the shared-value table is fit with numpy)")
    lead = vals.shape[:vals.ndim - 2 - nval_dims]
    v_np = np.asarray(vals, np.float32).reshape((-1,) + vals.shape[len(lead):])
    books = np.stack([_fit_codebook(v_np[i], ncodes)
                      for i in range(v_np.shape[0])])
    idx = np.empty(v_np.shape, np.int8)
    for i in range(v_np.shape[0]):
        idx[i] = np.argmin(
            np.abs(v_np[i][..., None] - books[i]), axis=-1).astype(np.int8)
    codebook = jnp.asarray(books.reshape(lead + (ncodes,)), jnp.float32)
    return jnp.asarray(idx.reshape(vals.shape)), None, codebook


def quantize_packed(packed, qmode: str, ncodes: int = CODEBOOK_SIZE):
    """Quantize the value buffer of a packed operand (TiledCSC/BlockCSR).

    Returns a new container with ``qmode`` set and ``vals``/``block_vals``
    replaced by the quantized representation plus the ``scale`` /
    ``codebook`` side bands.  ``qmode='none'`` (or quantizing an already
    quantized operand with the same mode) is the identity.
    """
    qmode = _check_qmode(qmode)
    if qmode == getattr(packed, "qmode", "none"):
        return packed
    if getattr(packed, "qmode", "none") != "none":
        raise ValueError(f"operand is already quantized ({packed.qmode}); "
                         "re-pack from dense to change qmode")
    if isinstance(packed, TiledCSC):
        q, scale, codebook = _quantize_values(packed.vals, qmode, 2, ncodes)
        return dataclasses.replace(packed, vals=q, scale=scale,
                                   codebook=codebook, qmode=qmode)
    if isinstance(packed, BlockCSR):
        q, scale, codebook = _quantize_values(
            packed.block_vals, qmode, 3, ncodes)
        return dataclasses.replace(packed, block_vals=q, scale=scale,
                                   codebook=codebook, qmode=qmode)
    raise TypeError(f"cannot quantize {type(packed).__name__}")


# ---------------------------------------------------------------------------
# TiledCSC — element-granular, paper-faithful static-shape CSC
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class TiledCSC:
    """Per-(bk, bn)-tile padded CSC.

    ``vals[kt, nt, s, j]`` is the s-th non-zero of column ``j`` of tile
    ``(kt, nt)``; ``rows[kt, nt, s, j]`` its in-tile row index.  Padding slots
    carry ``val == 0`` and sentinel ``row == -1``: compare-accumulate never
    matches them and scatter-add drops them (``mode='drop'``), which also
    guarantees *exactly zero* gradient flow into padding slots — fixed-mask
    sparse training stays on the mask.

    Under a quantized ``qmode``, ``vals`` holds the quantized representation
    (int8 / fp8 codes, or int8 codebook indices) and ``scale`` / ``codebook``
    carry the dequantization side band; padding slots quantize to 0 (or
    codebook entry 0 == 0.0), so the sentinel logic is qmode-oblivious.
    """

    vals: jax.Array   # (*lead, Kt, Nt, cap, bn) — lead = layer-stack/expert dims
    rows: jax.Array   # same shape, int8 (bk <= 128) or int32
    shape: tuple[int, int]          # logical (K, N) before tile padding
    tile: tuple[int, int]
    scale: Any = None      # (*lead, Kt, Nt) f32, int8/fp8 modes only
    codebook: Any = None   # (*lead, ncodes) f32, codebook mode only
    qmode: str = "none"

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        """Flatten into (array children, static aux) for jax pytrees."""
        return (self.vals, self.rows, self.scale, self.codebook), (
            self.shape, self.tile, self.qmode)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild from :meth:`tree_flatten` output."""
        vals, rows, scale, codebook = children
        shape, tile, qmode = aux
        return cls(vals=vals, rows=rows, shape=shape, tile=tile,
                   scale=scale, codebook=codebook, qmode=qmode)

    # -- views --------------------------------------------------------------
    @property
    def cap(self) -> int:
        """Padded slot count per tile column (trailing value dim)."""
        return self.vals.shape[-2]

    @property
    def grid(self) -> tuple[int, int]:
        """``(Kt, Nt)`` tile-grid extents."""
        return self.vals.shape[-4], self.vals.shape[-3]

    @property
    def lead(self) -> tuple[int, ...]:
        """Leading stack dims (layer groups / experts), ahead of the grid."""
        return tuple(self.vals.shape[:-4])

    @property
    def dtype(self):
        """Stored value dtype: fp, int8 codes, or fp8."""
        return self.vals.dtype

    def nbytes_compressed(self, value_bits: int | None = None,
                          index_bits: int = 8) -> int:
        """Footprint under the paper's encoding (value + index per slot).

        ``value_bits=None`` uses the ``qmode``'s width (16 unquantized, 8
        for int8/fp8, index width for codebook) plus the side-band cost:
        one 16-bit scale per tile, or 16 bits per codebook entry.
        """
        side = 0
        if value_bits is None:
            ncodes = (self.codebook.shape[-1] if self.codebook is not None
                      else CODEBOOK_SIZE)
            value_bits = qvalue_bits(self.qmode, ncodes)
            if self.scale is not None:
                side += int(np.prod(self.scale.shape)) * SCALE_BITS // 8
            if self.codebook is not None:
                side += int(np.prod(self.codebook.shape)) * SCALE_BITS // 8
        slots = int(np.prod(self.vals.shape))
        return slots * (value_bits + index_bits) // 8 + side

    def nbytes_dense(self, value_bits: int = 16) -> int:
        """Dense-equivalent bytes at ``value_bits`` (lead dims included)."""
        # nbytes_compressed counts the stacked (layer-group / expert) lead
        # dims via vals.shape; the dense equivalent must too, or stacked
        # leaves report a compression ratio off by prod(lead)
        kp, np_ = padded_shape(self.shape, self.tile)
        return int(np.prod(self.lead, dtype=np.int64)) * kp * np_ \
            * value_bits // 8

    def compression_ratio(self) -> float:
        """``nbytes_compressed / nbytes_dense`` — below 1 when packing pays."""
        return self.nbytes_compressed() / max(self.nbytes_dense(), 1)

    def dequantize(self) -> "TiledCSC":
        """The equivalent unquantized (``qmode='none'``) operand, values
        dequantized to float32.  Identity when already unquantized."""
        if self.qmode == "none":
            return self
        vals = _dequant_values(self.vals, self.scale, self.codebook,
                               self.qmode, 2)
        return TiledCSC(vals=vals, rows=self.rows, shape=self.shape,
                        tile=self.tile)

    def to_dense(self) -> jax.Array:
        """Differentiable scatter-add decompression (the jnp 'oracle').

        Leading (layer-stack / expert) dims are vmapped; returns
        ``(*lead, K, N)``.  Quantized operands dequantize first (float32
        output), which keeps gradients flowing into ``scale``/``codebook``.
        """
        if self.qmode != "none":
            return self.dequantize().to_dense()
        if self.lead:
            flat = TiledCSC(
                vals=self.vals.reshape((-1,) + self.vals.shape[-4:]),
                rows=self.rows.reshape((-1,) + self.rows.shape[-4:]),
                shape=self.shape, tile=self.tile)
            dense = jax.vmap(
                lambda v, r: TiledCSC(v, r, self.shape, self.tile).to_dense()
            )(flat.vals, flat.rows)
            return dense.reshape(self.lead + dense.shape[-2:])
        kt_n, nt_n = self.grid
        bk, bn = self.tile
        kt = jnp.arange(kt_n)[:, None, None, None]
        nt = jnp.arange(nt_n)[None, :, None, None]
        jn = jnp.arange(bn)[None, None, None, :]
        rows = self.rows.astype(jnp.int32)
        # Mask padding explicitly: keeps decompression exact even if padding
        # values are polluted and gives exactly-zero cotangents at padding.
        vals = jnp.where(rows >= 0, self.vals, 0)
        dense = jnp.zeros((kt_n, nt_n, bk, bn), self.vals.dtype)
        dense = dense.at[
            jnp.broadcast_to(kt, rows.shape),
            jnp.broadcast_to(nt, rows.shape),
            rows,
            jnp.broadcast_to(jn, rows.shape),
        ].add(vals, mode="drop")
        dense = dense.transpose(0, 2, 1, 3).reshape(kt_n * bk, nt_n * bn)
        return dense[: self.shape[0], : self.shape[1]]


def pack_tiled_csc(
    w: jax.Array,
    tile: tuple[int, int] = (128, 128),
    cap: int | None = None,
    index_dtype=None,
    qmode: str = "none",
    ncodes: int = CODEBOOK_SIZE,
) -> TiledCSC:
    """Pack a dense matrix into :class:`TiledCSC`.

    ``cap=None`` chooses the exact max column non-zero count over all tiles
    (lossless).  A smaller ``cap`` keeps the ``cap`` largest-magnitude entries
    per tile column (lossy, ESE-style load-capping).  ``qmode`` quantizes the
    value buffer after packing (:func:`quantize_packed`).

    Leading dims (layer stacks / experts) are packed with a *shared* cap so
    the result slices homogeneously under ``lax.scan``.
    """
    w = jnp.asarray(w)
    if w.ndim > 2:
        lead = w.shape[:-2]
        flat = w.reshape((-1,) + w.shape[-2:])
        if cap is None:
            cap = max((observed_tiled_cap(w, tile) + 7) // 8 * 8, 8)
        packed = [pack_tiled_csc(flat[i], tile, cap, index_dtype)
                  for i in range(flat.shape[0])]
        vals = jnp.stack([p.vals for p in packed]).reshape(
            lead + packed[0].vals.shape)
        rows = jnp.stack([p.rows for p in packed]).reshape(
            lead + packed[0].rows.shape)
        return quantize_packed(
            TiledCSC(vals=vals, rows=rows, shape=tuple(w.shape[-2:]),
                     tile=tile),
            qmode, ncodes)
    if w.ndim != 2:
        raise ValueError(f"expected >=2-D matrix, got {w.shape}")
    bk, bn = tile
    shape = tuple(w.shape)
    w = _pad_to_tiles(w, tile)
    kp, np_ = w.shape
    kt_n, nt_n = kp // bk, np_ // bn
    # (Kt, Nt, bk, bn)
    tiles = w.reshape(kt_n, bk, nt_n, bn).transpose(0, 2, 1, 3)

    nz = tiles != 0
    if cap is None:
        cap = int(jnp.max(jnp.sum(nz, axis=2))) if w.size else 0
        cap = max(cap, 1)
        cap = (cap + 7) // 8 * 8  # sublane-align slot dim for the TPU kernel
    # Order rows of each tile column: non-zeros first (stable ⇒ ascending row),
    # then pick the top `cap` slots.  For the lossy path order by |value|.
    exact = cap >= bk
    key_nz = (~nz).astype(jnp.int32)
    order = jnp.argsort(key_nz, axis=2, stable=True)  # (Kt, Nt, bk, bn)
    gathered = jnp.take_along_axis(tiles, order, axis=2)
    gathered_nz = jnp.take_along_axis(nz, order, axis=2)
    if not exact:
        # keep largest-|value| entries when truncating
        mag_order = jnp.argsort(
            jnp.where(gathered_nz, -jnp.abs(gathered.astype(jnp.float32)), jnp.inf),
            axis=2,
            stable=True,
        )
        keep = mag_order[:, :, :cap, :]
        vals = jnp.take_along_axis(gathered, keep, axis=2)
        # restore ascending-row order within the kept set
        row_ids = jnp.take_along_axis(order, keep, axis=2)
        asc = jnp.argsort(row_ids, axis=2, stable=True)
        rows = jnp.take_along_axis(row_ids, asc, axis=2)
        vals = jnp.take_along_axis(vals, asc, axis=2)
        valid = jnp.take_along_axis(jnp.take_along_axis(gathered_nz, keep, axis=2), asc, axis=2)
    else:
        cap_eff = min(cap, bk)
        vals = gathered[:, :, :cap_eff, :]
        rows = order[:, :, :cap_eff, :]
        valid = gathered_nz[:, :, :cap_eff, :]
        if cap > bk:  # degenerate: more slots than rows
            pad = cap - bk
            vals = jnp.pad(vals, ((0, 0), (0, 0), (0, pad), (0, 0)))
            rows = jnp.pad(rows, ((0, 0), (0, 0), (0, pad), (0, 0)))
            valid = jnp.pad(valid, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vals = jnp.where(valid, vals, 0).astype(w.dtype)
    rows = jnp.where(valid, rows, -1)
    if index_dtype is None:
        index_dtype = jnp.int8 if bk <= 128 else jnp.int32
    rows = rows.astype(index_dtype)
    return quantize_packed(
        TiledCSC(vals=vals, rows=rows, shape=shape, tile=(bk, bn)),
        qmode, ncodes)


# ---------------------------------------------------------------------------
# BlockCSR — (8, 128) VREG blocks, macro-tile skip list
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class BlockCSR:
    """Block-compressed rows of MXU macro-tiles.

    The matrix is cut into (bk, bn) macro tiles; each macro tile is further
    cut along K into (br, bn) VREG-shaped sub-blocks (br = 8 by default).
    Per macro tile we store up to ``bcap`` non-zero sub-blocks and their
    in-tile block indices (padding id = -1, dropped on scatter).  ``tile_nnz``
    counts non-zero sub-blocks per macro tile; a macro tile with 0 can be
    skipped entirely by the matmul kernel (compute win).

    ``qmode``/``scale``/``codebook`` quantize the ``block_vals`` buffer the
    same way :class:`TiledCSC` quantizes ``vals`` (scale per macro tile).
    """

    block_vals: jax.Array  # (Kt, Nt, bcap, br, bn)
    block_ids: jax.Array   # (Kt, Nt, bcap) int32, in-tile sub-block index
    tile_nnz: jax.Array    # (Kt, Nt) int32
    shape: tuple[int, int]
    tile: tuple[int, int]  # (bk, bn) macro tile
    br: int                # sub-block rows
    scale: Any = None      # (*lead, Kt, Nt) f32, int8/fp8 modes only
    codebook: Any = None   # (*lead, ncodes) f32, codebook mode only
    qmode: str = "none"

    def tree_flatten(self):
        """Flatten into (array children, static aux) for jax pytrees."""
        return (self.block_vals, self.block_ids, self.tile_nnz,
                self.scale, self.codebook), (
            self.shape,
            self.tile,
            self.br,
            self.qmode,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild from :meth:`tree_flatten` output."""
        block_vals, block_ids, tile_nnz, scale, codebook = children
        shape, tile, br, qmode = aux
        return cls(block_vals, block_ids, tile_nnz, shape, tile, br,
                   scale=scale, codebook=codebook, qmode=qmode)

    @property
    def bcap(self) -> int:
        """Stored sub-blocks per tile (trailing block dim)."""
        return self.block_vals.shape[-3]

    @property
    def grid(self) -> tuple[int, int]:
        """``(Kt, Nt)`` tile-grid extents."""
        return self.block_vals.shape[-5], self.block_vals.shape[-4]

    @property
    def lead(self) -> tuple[int, ...]:
        """Leading stack dims (layer groups / experts), ahead of the grid."""
        return tuple(self.block_vals.shape[:-5])

    @property
    def dtype(self):
        """Stored value dtype: fp, int8 codes, or fp8."""
        return self.block_vals.dtype

    def nbytes_compressed(self, value_bits: int | None = None,
                          index_bits: int = 16) -> int:
        """Footprint: stored sub-block values + block ids (+ quant side
        band under a quantized ``qmode``, as in :class:`TiledCSC`)."""
        side = 0
        if value_bits is None:
            ncodes = (self.codebook.shape[-1] if self.codebook is not None
                      else CODEBOOK_SIZE)
            value_bits = qvalue_bits(self.qmode, ncodes)
            if self.scale is not None:
                side += int(np.prod(self.scale.shape)) * SCALE_BITS // 8
            if self.codebook is not None:
                side += int(np.prod(self.codebook.shape)) * SCALE_BITS // 8
        v = int(np.prod(self.block_vals.shape)) * value_bits // 8
        i = int(np.prod(self.block_ids.shape)) * index_bits // 8
        return v + i + side

    def nbytes_dense(self, value_bits: int = 16) -> int:
        """Dense-equivalent bytes at ``value_bits`` (lead dims included)."""
        # see TiledCSC.nbytes_dense: the lead dims count on both sides
        kp, np_ = padded_shape(self.shape, self.tile)
        return int(np.prod(self.lead, dtype=np.int64)) * kp * np_ \
            * value_bits // 8

    def dequantize(self) -> "BlockCSR":
        """The equivalent unquantized operand (cf. ``TiledCSC.dequantize``)."""
        if self.qmode == "none":
            return self
        bvals = _dequant_values(self.block_vals, self.scale, self.codebook,
                                self.qmode, 3)
        return BlockCSR(block_vals=bvals, block_ids=self.block_ids,
                        tile_nnz=self.tile_nnz, shape=self.shape,
                        tile=self.tile, br=self.br)

    def to_dense(self) -> jax.Array:
        """Differentiable scatter-add decompression to ``(*lead, K, N)``."""
        if self.qmode != "none":
            return self.dequantize().to_dense()
        if self.lead:
            bv = self.block_vals.reshape((-1,) + self.block_vals.shape[-5:])
            bi = self.block_ids.reshape((-1,) + self.block_ids.shape[-3:])
            tn = self.tile_nnz.reshape((-1,) + self.tile_nnz.shape[-2:])
            dense = jax.vmap(
                lambda v, i, n: BlockCSR(v, i, n, self.shape, self.tile,
                                         self.br).to_dense()
            )(bv, bi, tn)
            return dense.reshape(self.lead + dense.shape[-2:])
        kt_n, nt_n = self.grid
        bk, bn = self.tile
        br = self.br
        nb = bk // br
        bcap = self.bcap
        kt = jnp.arange(kt_n)[:, None, None]
        nt = jnp.arange(nt_n)[None, :, None]
        ids = self.block_ids
        bvals = jnp.where((ids >= 0)[:, :, :, None, None], self.block_vals, 0)
        dense = jnp.zeros((kt_n, nt_n, nb, br, bn), self.block_vals.dtype)
        dense = dense.at[
            jnp.broadcast_to(kt, ids.shape),
            jnp.broadcast_to(nt, ids.shape),
            ids,
        ].add(bvals, mode="drop")
        dense = dense.reshape(kt_n, nt_n, bk, bn).transpose(0, 2, 1, 3)
        dense = dense.reshape(kt_n * bk, nt_n * bn)
        return dense[: self.shape[0], : self.shape[1]]


def pack_block_csr(
    w: jax.Array,
    tile: tuple[int, int] = (128, 128),
    br: int = 8,
    bcap: int | None = None,
    qmode: str = "none",
    ncodes: int = CODEBOOK_SIZE,
) -> BlockCSR:
    """Pack a dense matrix into :class:`BlockCSR` (lossless for bcap=None).

    ``qmode`` quantizes ``block_vals`` after packing (:func:`quantize_packed`).
    """
    bk, bn = tile
    if bk % br:
        raise ValueError(f"tile rows {bk} not divisible by block rows {br}")
    w = jnp.asarray(w)
    if w.ndim > 2:
        lead = w.shape[:-2]
        flat = w.reshape((-1,) + w.shape[-2:])
        if bcap is None:
            bcap = max(observed_block_cap(w, tile, br), 1)
        packed = [pack_block_csr(flat[i], tile, br, bcap)
                  for i in range(flat.shape[0])]
        return quantize_packed(BlockCSR(
            block_vals=jnp.stack([p.block_vals for p in packed]).reshape(
                lead + packed[0].block_vals.shape),
            block_ids=jnp.stack([p.block_ids for p in packed]).reshape(
                lead + packed[0].block_ids.shape),
            tile_nnz=jnp.stack([p.tile_nnz for p in packed]).reshape(
                lead + packed[0].tile_nnz.shape),
            shape=tuple(w.shape[-2:]), tile=tile, br=br), qmode, ncodes)
    shape = tuple(w.shape)
    w = _pad_to_tiles(w, tile)
    kp, np_ = w.shape
    kt_n, nt_n = kp // bk, np_ // bn
    nb = bk // br
    blocks = w.reshape(kt_n, nb, br, nt_n, bn).transpose(0, 3, 1, 2, 4)
    # (Kt, Nt, nb, br, bn)
    nz = jnp.any(blocks != 0, axis=(3, 4))  # (Kt, Nt, nb)
    tile_nnz = jnp.sum(nz, axis=2).astype(jnp.int32)
    if bcap is None:
        bcap = max(int(jnp.max(tile_nnz)) if w.size else 0, 1)
    else:
        # an explicit (plan-provided) bcap may truncate; tile_nnz must
        # count the *stored* sub-blocks, not the pre-truncation ones
        tile_nnz = jnp.minimum(tile_nnz, bcap)
    # Keep the largest-L2 sub-blocks when bcap truncates (ESE-style load
    # capping, mirroring pack_tiled_csc's lossy path), then restore
    # ascending block-index order within the kept set — so the lossless
    # case (bcap ≥ every tile's count) lays out exactly as a plain
    # valid-first index-ordered pack.
    norms = jnp.sum(blocks.astype(jnp.float32) ** 2, axis=(3, 4))
    sel = jnp.argsort(jnp.where(nz, -norms, jnp.inf), axis=2,
                      stable=True)[:, :, :bcap]              # (Kt, Nt, bcap)
    sel_valid = jnp.take_along_axis(nz, sel, axis=2)
    asc = jnp.argsort(jnp.where(sel_valid, sel, nb), axis=2, stable=True)
    order = jnp.take_along_axis(sel, asc, axis=2)
    valid = jnp.take_along_axis(sel_valid, asc, axis=2)
    block_vals = jnp.take_along_axis(
        blocks, order[:, :, :, None, None], axis=2
    )
    block_vals = jnp.where(valid[:, :, :, None, None], block_vals, 0).astype(w.dtype)
    block_ids = jnp.where(valid, order, -1).astype(jnp.int32)
    return quantize_packed(BlockCSR(
        block_vals=block_vals,
        block_ids=block_ids,
        tile_nnz=tile_nnz,
        shape=shape,
        tile=(bk, bn),
        br=br,
    ), qmode, ncodes)


# ---------------------------------------------------------------------------
# Bitmap — SIGMA-style
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Bitmap:
    """Bitmap + row-major packed non-zero values (padded to ``cap``)."""

    mask: jax.Array   # (K, N) bool
    vals: jax.Array   # (cap,) packed row-major non-zeros
    shape: tuple[int, int]

    def tree_flatten(self):
        """Flatten into (array children, static aux) for jax pytrees."""
        return (self.mask, self.vals), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild from :meth:`tree_flatten` output."""
        mask, vals = children
        return cls(mask, vals, aux[0])

    def nbytes_compressed(self, value_bits: int = 16) -> int:
        """Bitmap bytes (1 bit/element) plus the stored value list."""
        bits = int(np.prod(self.mask.shape))  # 1 bit/element bitmap
        return bits // 8 + self.vals.shape[0] * value_bits // 8

    def nbytes_dense(self, value_bits: int = 16) -> int:
        """Dense-equivalent bytes at ``value_bits``."""
        return int(np.prod(self.shape)) * value_bits // 8

    def to_dense(self) -> jax.Array:
        """Reconstruct the dense matrix (bitmap-guided scatter)."""
        flat_mask = self.mask.reshape(-1)
        pos = jnp.cumsum(flat_mask) - 1
        gathered = self.vals[jnp.clip(pos, 0, self.vals.shape[0] - 1)]
        out = jnp.where(flat_mask, gathered, 0)
        return out.reshape(self.shape).astype(self.vals.dtype)


def pack_bitmap(w: jax.Array, cap: int | None = None) -> Bitmap:
    """Pack into :class:`Bitmap`: 1-bit mask + row-major value list."""
    w = jnp.asarray(w)
    mask = w != 0
    flat = w.reshape(-1)
    flat_mask = mask.reshape(-1)
    if cap is None:
        cap = max(int(jnp.sum(flat_mask)), 1)
    order = jnp.argsort(~flat_mask, stable=True)[:cap]
    vals = jnp.where(flat_mask[order], flat[order], 0)
    return Bitmap(mask=mask, vals=vals, shape=tuple(w.shape))


# ---------------------------------------------------------------------------
# Classic pointer CSC (numpy) — exact footprint accounting for the cost model
# ---------------------------------------------------------------------------
def pack_csc(w: np.ndarray) -> dict[str, np.ndarray]:
    """Classic CSC: values, row indices, column pointers (numpy, exact)."""
    w = np.asarray(w)
    k, n = w.shape
    cols = []
    rows = []
    vals = []
    ptr = [0]
    for j in range(n):
        nz = np.nonzero(w[:, j])[0]
        rows.append(nz)
        vals.append(w[nz, j])
        ptr.append(ptr[-1] + len(nz))
    return {
        "values": np.concatenate(vals) if vals else np.zeros((0,), w.dtype),
        "row_indices": np.concatenate(rows).astype(np.int32)
        if rows
        else np.zeros((0,), np.int32),
        "col_pointers": np.asarray(ptr, np.int64),
        "shape": np.asarray([k, n]),
    }


def unpack_csc(csc: dict[str, np.ndarray]) -> np.ndarray:
    """Reconstruct the dense matrix from a :func:`pack_csc` dict."""
    k, n = (int(x) for x in csc["shape"])
    out = np.zeros((k, n), csc["values"].dtype)
    ptr = csc["col_pointers"]
    for j in range(n):
        lo, hi = int(ptr[j]), int(ptr[j + 1])
        out[csc["row_indices"][lo:hi], j] = csc["values"][lo:hi]
    return out


def csc_nbytes(csc: dict[str, np.ndarray], value_bits: int = 16,
               index_bits: int = 8, pointer_bits: int = 32) -> int:
    """Byte footprint of a pointer-CSC dict at the given bit widths."""
    nnz = csc["values"].shape[0]
    ncols = csc["col_pointers"].shape[0]
    return (nnz * (value_bits + index_bits) + ncols * pointer_bits) // 8
