"""Analytical accelerator models reproducing the paper's evaluation.

Implements the paper's design (Sparse-on-Dense) and **every baseline it
compares against** — dense TPU-style [11], ESE [8], SCNN [9], SNAP [10],
SIGMA [12] — as calibrated 28nm analytical models at the paper's common
configuration (4K MACs, 2 MB global SRAM, 500 MHz, 16-bit data, 8-bit
indices).  Each model produces cycles, area and system energy (DRAM + SRAM +
PE array) for a (M, K, N) matmul at weight density ``dw`` / input density
``di``; the derived metrics are the paper's:

  * effective throughput / area  [TOPS/mm²]  — logical dense ops / time / mm²
  * energy efficiency            [TOPS/W]    — logical dense ops / energy

Mechanisms modelled per accelerator follow Section II/IV of the paper:

  dense    — computes all MKN MACs; dense operands in memory.
  SoD      — computes all MKN MACs; *compressed* operands in memory
             (1.5·density: 16-bit value + 8-bit index); decompression unit
             ≈ 2% of PE-array area; larger effective tiles → more reuse.
  ESE      — skips zero weights (time ∝ dw) with high utilization, paid for
             with FIFOs + index matching + oversized per-PE buffers (area
             multiple) and per-op index-compare energy.
  SCNN     — Cartesian product, two-sided skip (time ∝ dw·di best case) but
             throughput bound by the scatter network whose congestion grows
             with density; area multiple 3.75× from the paper's breakdown.
  SNAP     — two-sided inner-product with comparator array; good utilization,
             moderate area multiple, comparator energy per op.
  SIGMA    — bitmap format: the matching frontend must scan *all* K·N
             positions (including zeros) at a fixed AND-gate throughput —
             the control-flow bound the paper describes; big reduction-tree
             area.

Calibration constants are explicit (``*_CAL`` dataclasses) and were chosen
so the model reproduces the paper's headline numbers (Table II, Figs 6–11);
``benchmarks/`` prints model-vs-paper side by side and the tests assert the
claim windows.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.topology import PAPER_28NM, PaperTech

# ---------------------------------------------------------------------------
# common configuration (paper Section IV-A/B)
# ---------------------------------------------------------------------------
N_MACS = 4096
SRAM_BYTES = 2 * 1024 * 1024
FREQ = 500e6
VALUE_BITS = 16
INDEX_BITS = 8


@dataclasses.dataclass(frozen=True)
class Workload:
    """One matmul: (M × K) · (K × N), densities in (0, 1]."""

    m: int
    k: int
    n: int
    dw: float = 1.0      # weight density
    di: float = 1.0      # input density
    name: str = ""

    @property
    def dense_macs(self) -> float:
        return float(self.m) * self.k * self.n


@dataclasses.dataclass(frozen=True)
class Report:
    name: str
    cycles: float
    area_logic_mm2: float
    area_sram_mm2: float
    energy_pj: float
    effective_ops: float           # logical dense MACs × 2

    @property
    def time_s(self) -> float:
        return self.cycles / FREQ

    @property
    def eff_tops(self) -> float:
        return self.effective_ops / self.time_s / 1e12

    def tops_per_mm2(self, include_sram: bool = False) -> float:
        a = self.area_logic_mm2 + (self.area_sram_mm2 if include_sram else 0)
        return self.eff_tops / a

    @property
    def tops_per_watt(self) -> float:
        watts = self.energy_pj * 1e-12 / self.time_s
        return self.eff_tops / watts


# ---------------------------------------------------------------------------
# shared memory-traffic model (output-stationary tiling, full-K slabs)
# ---------------------------------------------------------------------------
def _dram_traffic_bits(w: Workload, bits_in: float, bits_w: float,
                       sram_bytes: float) -> float:
    """Weights stream once per M-tile sweep; inputs once per N-tile sweep.

    Tile (T × T) outputs with full-K operand slabs resident:
        SRAM ≥ T·K·bits_in/8 + K·T·bits_w/8 + T·T·4
    Compressed operands (smaller bits_*) ⇒ larger T ⇒ fewer refetches —
    the paper's on-chip-reuse argument (Section III-B1).
    """
    k = w.k
    # solve 4 T² + (K(bits_in+bits_w)/8) T − C = 0 for the square tile T
    b = k * (bits_in + bits_w) / 8
    t = (-b + math.sqrt(b * b + 16 * sram_bytes)) / 8
    t = max(min(t, max(w.m, w.n)), 1.0)
    inputs = w.m * k * bits_in * max(w.n / t, 1.0)
    weights = k * w.n * bits_w * max(w.m / t, 1.0)
    outputs = 2 * w.m * w.n * VALUE_BITS
    return inputs + weights + outputs


def _sram_traffic_bits(w: Workload, bits_in: float, bits_w: float) -> float:
    """Each operand crosses the SRAM→array boundary ~once per tile pass;
    model as 2× its DRAM-resident footprint + output accumulation."""
    return 2 * (w.m * w.k * bits_in + w.k * w.n * bits_w) \
        + 2 * w.m * w.n * VALUE_BITS


def _mem_energy(w: Workload, bits_in: float, bits_w: float,
                tech: PaperTech, sram_bytes: float) -> float:
    dram = _dram_traffic_bits(w, bits_in, bits_w, sram_bytes)
    sram = _sram_traffic_bits(w, bits_in, bits_w)
    return dram * tech.e_dram_per_bit + sram * tech.e_sram_per_bit


def _sram_area(tech: PaperTech, sram_bytes: float = SRAM_BYTES) -> float:
    return sram_bytes / 1024 * tech.a_sram_per_kb


def _dims_util(w: Workload, side: int = 64) -> float:
    """Systolic-array edge underutilization for small matrices."""
    um = min(w.m / side, 1.0) if w.m < side else 1.0
    un = min(w.n / side, 1.0) if w.n < side else 1.0
    return max(um * un, 1e-3)


# ---------------------------------------------------------------------------
# 1) dense TPU-style baseline [11]
# ---------------------------------------------------------------------------
def dense_baseline(w: Workload, tech: PaperTech = PAPER_28NM,
                   sram_bytes: float = SRAM_BYTES) -> Report:
    util = _dims_util(w)
    cycles = w.dense_macs / (N_MACS * util)
    energy = w.dense_macs * tech.e_mac_16b \
        + _mem_energy(w, VALUE_BITS, VALUE_BITS, tech, sram_bytes)
    return Report(
        name="dense",
        cycles=cycles,
        area_logic_mm2=N_MACS * tech.a_dense_pe,
        area_sram_mm2=_sram_area(tech, sram_bytes),
        energy_pj=energy,
        effective_ops=2 * w.dense_macs,
    )


# ---------------------------------------------------------------------------
# 2) Sparse-on-Dense (this paper)
# ---------------------------------------------------------------------------
DECOMP_AREA_FRACTION = 0.02        # Fig. 5: ≈2% of the 4K PE array
DECOMP_ENERGY_PER_NZ = 0.08       # pJ per decompressed non-zero (subtr+mux)


def sparse_on_dense(w: Workload, tech: PaperTech = PAPER_28NM,
                    sram_bytes: float = SRAM_BYTES) -> Report:
    util = _dims_util(w)
    cycles = w.dense_macs / (N_MACS * util)        # dense compute, dense time
    bits_w = VALUE_BITS if w.dw >= 1.0 else w.dw * (VALUE_BITS + INDEX_BITS)
    bits_i = VALUE_BITS if w.di >= 1.0 else w.di * (VALUE_BITS + INDEX_BITS)
    nz = w.dw * w.k * w.n + w.di * w.m * w.k
    energy = w.dense_macs * tech.e_mac_16b \
        + nz * DECOMP_ENERGY_PER_NZ \
        + _mem_energy(w, bits_i, bits_w, tech, sram_bytes)
    pe_area = N_MACS * tech.a_dense_pe
    return Report(
        name="sparse_on_dense",
        cycles=cycles,
        area_logic_mm2=pe_area * (1 + DECOMP_AREA_FRACTION),
        area_sram_mm2=_sram_area(tech, sram_bytes),
        energy_pj=energy,
        effective_ops=2 * w.dense_macs,
    )


# ---------------------------------------------------------------------------
# 3) ESE [8] — sparse weight × dense input
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ESECal:
    area_mult: float = 5.0        # FIFOs + index match + per-PE buffers
    fifo_depth: float = 6.0       # index compares per useful MAC
    util_hi: float = 0.92         # multiplier utilization (Fig. 7)
    util_lo: float = 0.80         # at extreme sparsity (load imbalance)
    sram_mult: float = 2.0        # oversized psum/weight buffers traffic


ESE_CAL = ESECal()


def ese(w: Workload, tech: PaperTech = PAPER_28NM,
        sram_bytes: float = SRAM_BYTES, cal: ESECal = ESE_CAL) -> Report:
    # utilization: high, degrading slightly at extreme sparsity (imbalance)
    util = cal.util_lo + (cal.util_hi - cal.util_lo) * min(w.dw / 0.3, 1.0)
    useful = w.dense_macs * w.dw
    cycles = useful / (N_MACS * util * _dims_util(w))
    bits_w = w.dw * (VALUE_BITS + INDEX_BITS)
    energy = useful * (tech.e_mac_16b
                       + cal.fifo_depth * tech.e_index_match
                       + VALUE_BITS * tech.e_fifo_per_bit) \
        + _mem_energy(w, VALUE_BITS, bits_w, tech, sram_bytes) * cal.sram_mult
    return Report(
        name="ese",
        cycles=cycles,
        area_logic_mm2=N_MACS * tech.a_dense_pe * cal.area_mult,
        area_sram_mm2=_sram_area(tech, sram_bytes),
        energy_pj=energy,
        effective_ops=2 * w.dense_macs,
    )


# ---------------------------------------------------------------------------
# 4) SCNN [9] — Cartesian product, two-sided
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SCNNCal:
    """Saturating sustained throughput: the scatter backend sustains
    ``u_max`` of peak only once product density swamps its fixed per-tile
    drain cost ``k0`` — at low density the coordinate-compute/drain pipeline
    dominates (cycles floor ∝ MKN·k0), matching the paper's observation that
    the gap *grows* with density yet SCNN never recovers dense efficiency."""

    area_mult: float = 4.75       # scatter network + FIFO = 3.75× mult array
    u_max: float = 0.362
    k0: float = 0.201
    stride_util: float = 0.18 / 0.79   # stride-4 L1 relative util (IV-D)
    sram_mult: float = 1.0        # oversized psum buffers (> dense output)
    psum_energy: float = 0.2      # scatter-add writes per product (rel.)
    ctrl_pj_per_cycle: float = 2000.0  # crossbar/coordinate control power


SCNN_CAL = SCNNCal()


def scnn(w: Workload, tech: PaperTech = PAPER_28NM,
         sram_bytes: float = SRAM_BYTES, cal: SCNNCal = SCNN_CAL,
         stride: int = 1, kernel_size: int = 1) -> Report:
    d_prod = w.dw * w.di
    u_eff = cal.u_max * d_prod / (d_prod + cal.k0)
    if stride > 1:
        u_eff *= cal.stride_util
    products = w.dense_macs * d_prod
    cycles = products / (N_MACS * max(u_eff, 1e-4) * _dims_util(w))
    bits_w = w.dw * (VALUE_BITS + INDEX_BITS)
    bits_i = w.di * (VALUE_BITS + INDEX_BITS) if w.di < 1.0 else VALUE_BITS
    # psum scatter writes dominate backend energy; kernel_size>1 means SoD
    # reuses psums in-register while SCNN re-scatters (Section IV-D)
    psum_writes = products * (cal.psum_energy + 0.3 * max(kernel_size - 1, 0))
    energy = products * tech.e_mac_16b \
        + psum_writes * VALUE_BITS * tech.e_sram_per_bit * 4 \
        + cycles * cal.ctrl_pj_per_cycle \
        + _mem_energy(w, bits_i, bits_w, tech, sram_bytes) * cal.sram_mult
    return Report(
        name="scnn",
        cycles=cycles,
        area_logic_mm2=N_MACS * tech.a_dense_pe * cal.area_mult,
        area_sram_mm2=_sram_area(tech, sram_bytes),
        energy_pj=energy,
        effective_ops=2 * w.dense_macs,
    )


# ---------------------------------------------------------------------------
# 5) SNAP [10] — two-sided inner product, comparator array
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SNAPCal:
    """Same saturating form as SCNN (comparator frontend has a fixed
    match-discovery cost) but a lighter floor; SNAP's edge at very low
    density shows up in *energy* (comparator work ∝ useful MACs only),
    matching Fig. 10/14 where SNAP wins energy in the sparsest layers."""

    area_mult: float = 4.3        # comparator array + FIFOs + buffers
    u_max: float = 0.476
    k0: float = 0.207
    compares_per_mac: float = 3.0
    sram_mult: float = 1.15
    ctrl_pj_per_cycle: float = 2500.0  # comparator-array + FIFO control


SNAP_CAL = SNAPCal()


def snap(w: Workload, tech: PaperTech = PAPER_28NM,
         sram_bytes: float = SRAM_BYTES, cal: SNAPCal = SNAP_CAL) -> Report:
    d_prod = w.dw * w.di
    u_eff = cal.u_max * d_prod / (d_prod + cal.k0)
    useful = w.dense_macs * d_prod
    cycles = useful / (N_MACS * max(u_eff, 1e-4) * _dims_util(w))
    bits_w = w.dw * (VALUE_BITS + INDEX_BITS)
    bits_i = w.di * (VALUE_BITS + INDEX_BITS) if w.di < 1.0 else VALUE_BITS
    energy = useful * (tech.e_mac_16b
                       + cal.compares_per_mac * tech.e_index_match) \
        + cycles * cal.ctrl_pj_per_cycle \
        + _mem_energy(w, bits_i, bits_w, tech, sram_bytes) * cal.sram_mult
    return Report(
        name="snap",
        cycles=cycles,
        area_logic_mm2=N_MACS * tech.a_dense_pe * cal.area_mult,
        area_sram_mm2=_sram_area(tech, sram_bytes),
        energy_pj=energy,
        effective_ops=2 * w.dense_macs,
    )


# ---------------------------------------------------------------------------
# 6) SIGMA [12] — bitmap + flexible interconnect
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SIGMACal:
    area_mult: float = 6.0        # Benes distribution + reduction tree bufs
    and_gates: int = 16384        # matching frontend (Section IV-A)
    match_eff: float = 0.55       # routing-control inefficiency
    sram_mult: float = 1.3
    reduce_energy: float = 3.0    # reduction-tree buffer writes per MAC
    ctrl_pj_per_cycle: float = 17000.0  # Benes routing + reduction control


SIGMA_CAL = SIGMACal()


def sigma(w: Workload, tech: PaperTech = PAPER_28NM,
          sram_bytes: float = SRAM_BYTES, cal: SIGMACal = SIGMA_CAL) -> Report:
    useful = w.dense_macs * w.dw * w.di
    compute_cycles = useful / N_MACS
    # bitmap scan must touch every K×N position per M-row-block, throttled
    # by control inefficiency (Section II-B); the matching frontend and the
    # routed compute serialize through the distribution network
    positions = w.dense_macs     # all positions incl. zeros
    match_cycles = positions / (cal.and_gates * cal.match_eff)
    cycles = match_cycles + compute_cycles
    # bitmap format: 1 bit per position + values for non-zeros
    bits_w = w.dw * VALUE_BITS + 1.0
    bits_i = (w.di * VALUE_BITS + 1.0) if w.di < 1.0 else VALUE_BITS
    energy = useful * tech.e_mac_16b \
        + positions * tech.e_index_match * 0.5 \
        + useful * cal.reduce_energy * VALUE_BITS * tech.e_sram_per_bit \
        + cycles * cal.ctrl_pj_per_cycle \
        + _mem_energy(w, bits_i, bits_w, tech, sram_bytes) * cal.sram_mult
    return Report(
        name="sigma",
        cycles=cycles,
        area_logic_mm2=N_MACS * tech.a_dense_pe * cal.area_mult,
        area_sram_mm2=_sram_area(tech, sram_bytes),
        energy_pj=energy,
        effective_ops=2 * w.dense_macs,
    )


ACCELERATORS = {
    "dense": dense_baseline,
    "sparse_on_dense": sparse_on_dense,
    "ese": ese,
    "scnn": scnn,
    "snap": snap,
    "sigma": sigma,
}


# ---------------------------------------------------------------------------
# area / power breakdown (paper Fig. 5)
# ---------------------------------------------------------------------------
def sod_breakdown(tech: PaperTech = PAPER_28NM) -> dict:
    pe = N_MACS * tech.a_dense_pe
    dec = pe * DECOMP_AREA_FRACTION
    sram = _sram_area(tech)
    total = pe + dec + sram
    return {
        "pe_array_mm2": pe,
        "decompression_mm2": dec,
        "sram_mm2": sram,
        "total_mm2": total,
        "decomp_over_pe": dec / pe,
        "decomp_over_total": dec / total,
    }
