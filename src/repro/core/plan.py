"""Layer-wise Sparse-on-Dense packing plans.

A :class:`PackPlan` is the per-layer answer to "how should this weight be
stored and dispatched": storage format, tile geometry, slot capacity
(``cap`` / ``bcap``), pruning settings, a dispatch hint (impl + tuned
parameters from the tuning cache), and an optional SPMD partition plan
mirroring the leaf's resident sharding.  A :class:`ModelPlan` maps every
packable parameter path of a model to its :class:`PackPlan` and round-trips
through JSON, so a plan built once (e.g. by the dry-run against abstract
shapes) replays byte-identically in train/serve.

This module is deliberately dependency-free (no jax): the sizing math and
the (de)serialization live here; the jax-heavy plan *builder* lives in
:mod:`repro.runtime.planner`, and :mod:`repro.core.sod` consumes plans when
packing (``sodify_params`` / ``sodify_abstract``) and dispatching
(``sod.apply`` reads the active plan installed with :func:`use_plan`).

Sizing is the one place abstract and concrete packing must agree
(tuning-cache keys and dry-run shapes are derived from it), so both go
through the shared functions below: :func:`tiled_cap` / :func:`block_bcap`
return the deterministic budget when no data is available and reproduce the
packer's data-dependent capacity when an observed count is supplied.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import json
import math
import pathlib
from typing import Any

__all__ = [
    "PLAN_VERSION",
    "QMODES",
    "QVALUE_BITS",
    "PackPlan",
    "ModelPlan",
    "expected_cap",
    "tiled_cap",
    "block_bcap",
    "use_plan",
    "active_plan",
    "active_entry",
    "active_subplans",
    "lookup_active",
]

PLAN_VERSION = 1

VALUE_BITS = 16
TILED_INDEX_BITS = 8
BLOCK_INDEX_BITS = 16

#: Quantized value-storage modes (the ``qmode`` plan axis; mirrored by the
#: executable formats in :mod:`repro.core.formats`).
QMODES = ("none", "int8", "fp8", "codebook")
#: Paper-accounting bits per stored value slot under each qmode — codebook
#: slots store only the index into the shared table.
QVALUE_BITS = {"none": 16, "int8": 8, "fp8": 8, "codebook": 4}
#: Bits for one per-tile scale or one codebook entry (side band).
SCALE_BITS = 16
#: Entries in the codebook's shared-value table (entry 0 reserved for 0.0).
CODEBOOK_SIZE = 16


def _align_slots(cap: int, align: int = 8) -> int:
    return max((int(cap) + align - 1) // align * align, align)


def expected_cap(bk: int, density: float) -> int:
    """Static per-column slot budget for Bernoulli(density) sparsity.

    mean + 4σ of Binomial(bk, density), sublane-aligned — the deterministic
    cap used when no weight values are available (dry-run / abstract
    packing), so shapes never depend on data.
    """
    density = min(max(float(density), 0.0), 1.0)
    mean = bk * density
    sigma = math.sqrt(max(bk * density * (1 - density), 1e-9))
    cap = min(bk, int(math.ceil(mean + 4 * sigma)))
    return _align_slots(cap)


def tiled_cap(bk: int, density: float, observed: int | None = None) -> int:
    """TiledCSC slot capacity: observed max column non-zero count when the
    planner saw concrete weights (matches ``pack_tiled_csc``'s lossless
    data-dependent cap exactly), else the deterministic budget."""
    if observed is not None:
        return _align_slots(max(int(observed), 1))
    return expected_cap(bk, density)


def block_bcap(nb: int, density: float, prune_method: str = "magnitude",
               block_elems: int = 1024, observed: int | None = None) -> int:
    """BlockCSR per-macro-tile sub-block capacity (shared sizing function).

    ``observed`` (concrete weights) reproduces ``pack_block_csr``'s
    data-dependent cap.  Otherwise the budget is mean + 4σ of
    Binomial(nb, p) where p is the sub-block survival probability: the
    target density itself under block pruning, and
    ``1 - (1-density)^block_elems`` (≈1 for any realistic density) under
    element-granular pruning — a whole (br, bn) sub-block only dies when
    every one of its ``block_elems`` entries is zero.
    """
    if observed is not None:
        return max(int(observed), 1)
    d = min(max(float(density), 0.0), 1.0)
    if prune_method == "block":
        p = d
    else:
        p = 1.0 - (1.0 - d) ** block_elems
    mean = nb * p
    sigma = math.sqrt(max(nb * p * (1 - p), 1e-9))
    return max(min(int(math.ceil(mean + 4 * sigma)), nb), 1)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class PackPlan:
    """How one parameter leaf is pruned, packed, and dispatched."""

    mode: str                        # dense | tiled_csc | block_csr
    shape: tuple[int, int]           # logical (K, N) of the matrix dims
    lead: tuple[int, ...] = ()       # leading layer-stack / expert dims
    density: float = 1.0
    prune_method: str = "magnitude"
    tile: tuple[int, int] = (128, 128)
    br: int = 8
    cap: int | None = None           # TiledCSC slot capacity
    bcap: int | None = None          # BlockCSR sub-block capacity
    dtype: str = "bfloat16"
    qmode: str = "none"              # value quantization: none|int8|fp8|codebook
    impl: str = "auto"               # dispatch hint: auto | jnp | pallas
    dispatch_params: dict = dataclasses.field(default_factory=dict)
    spmd: dict | None = None         # SpmdPlan fields (runtime.spmd), or None
    note: str = ""                   # informational (chosen impl / reason)

    def __post_init__(self):
        if self.mode not in ("dense", "tiled_csc", "block_csr"):
            raise ValueError(f"unknown plan mode {self.mode!r}")
        if self.qmode not in QMODES:
            raise ValueError(f"unknown plan qmode {self.qmode!r} "
                             f"(expected one of {QMODES})")

    # -- derived layout facts ------------------------------------------------
    @property
    def grid(self) -> tuple[int, int]:
        """Tile-grid shape ``(k_tiles, n_tiles)`` over the (K, N) weight."""
        bk, bn = self.tile
        k, n = self.shape
        return _ceil_div(k, bk), _ceil_div(n, bn)

    def layout_key(self) -> tuple:
        """Identity of the packed layout this plan produces — what dispatch
        can observe from the operand alone (no parameter path)."""
        slot = self.cap if self.mode == "tiled_csc" else self.bcap
        return (self.mode, tuple(self.shape), tuple(self.tile),
                int(slot or 0), self.br if self.mode == "block_csr" else 0,
                self.qmode)

    def _lead_n(self) -> int:
        n = 1
        for d in self.lead:
            n *= int(d)
        return n

    def _qside_bytes(self, kt: int, nt: int) -> int:
        """Per-lead-slice side-band bytes of the qmode (scales / codebook)."""
        if self.qmode in ("int8", "fp8"):
            return kt * nt * SCALE_BITS // 8
        if self.qmode == "codebook":
            return CODEBOOK_SIZE * SCALE_BITS // 8
        return 0

    def compressed_bytes(self) -> int:
        """Footprint of the packed (or dense) leaf under this plan — same
        accounting as the formats' ``nbytes_compressed`` (value slots at the
        qmode's width plus the quantization side band)."""
        k, n = self.shape
        if self.mode == "dense":
            return self._lead_n() * k * n * VALUE_BITS // 8
        kt, nt = self.grid
        bk, bn = self.tile
        vbits = QVALUE_BITS[self.qmode]
        side = self._qside_bytes(kt, nt)
        if self.mode == "tiled_csc":
            cap = self.cap if self.cap is not None else tiled_cap(
                bk, self.density)
            slots = kt * nt * cap * bn
            return self._lead_n() * (
                slots * (vbits + TILED_INDEX_BITS) // 8 + side)
        bcap = self.bcap if self.bcap is not None else block_bcap(
            bk // self.br, self.density, self.prune_method, self.br * bn)
        vals = kt * nt * bcap * self.br * bn * vbits // 8
        ids = kt * nt * bcap * BLOCK_INDEX_BITS // 8
        return self._lead_n() * (vals + ids + side)

    def dense_bytes(self) -> int:
        """Footprint the same leaf would take stored dense — the baseline
        ``compressed_bytes`` is measured against."""
        k, n = self.shape
        return self._lead_n() * k * n * VALUE_BITS // 8

    def describe(self) -> str:
        """One-line human-readable summary (mode, tile, caps, impl/spmd
        hints) for plan dumps and ``ModelPlan.summary``."""
        if self.mode == "dense":
            s = "dense"
        elif self.mode == "tiled_csc":
            s = (f"tiled_csc t={self.tile[0]}x{self.tile[1]} cap={self.cap}")
        else:
            s = (f"block_csr t={self.tile[0]}x{self.tile[1]} br={self.br} "
                 f"bcap={self.bcap}")
        if self.lead:
            s += f" lead={tuple(self.lead)}"
        if self.qmode != "none":
            s += f" q={self.qmode}"
        if self.impl != "auto":
            s += f" impl={self.impl}"
        if self.dispatch_params:
            s += f" params={self.dispatch_params}"
        if self.spmd:
            parts = []
            if self.spmd.get("batch_axes"):
                parts.append("dp=" + "+".join(self.spmd["batch_axes"]))
            for f in ("col_axis", "row_axis", "gather_axis"):
                if self.spmd.get(f):
                    parts.append(f"{f.split('_')[0]}={self.spmd[f]}")
            s += f" spmd={';'.join(parts) or 'replicated'}"
        if self.note:
            s += f" ({self.note})"
        return s

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> dict:
        """JSON-safe dict, dropping empty fields (keeps plan files small
        and diffable); inverse of :meth:`from_json`."""
        d = dataclasses.asdict(self)
        if d.get("qmode") == "none":
            del d["qmode"]  # default; keeps pre-qmode plan files diff-clean
        return {k: v for k, v in d.items() if v not in (None, {}, "", ())
                or k in ("mode", "shape", "cap", "bcap")}

    @classmethod
    def from_json(cls, d: dict) -> "PackPlan":
        """Rebuild a plan from :meth:`to_json` output, normalizing JSON
        lists back to tuples and ignoring unknown fields."""
        kw = dict(d)
        kw["shape"] = tuple(int(s) for s in kw["shape"])
        kw["lead"] = tuple(int(s) for s in kw.get("lead", ()))
        kw["tile"] = tuple(int(s) for s in kw.get("tile", (128, 128)))
        if kw.get("spmd"):
            # normalize to lists so a loaded plan compares equal to the
            # built one (json has no tuples)
            sp = dict(kw["spmd"])
            sp["batch_axes"] = list(sp.get("batch_axes", ()))
            kw["spmd"] = sp
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in kw.items() if k in known})


class ModelPlan:
    """Per-parameter-path :class:`PackPlan` for one model.

    ``mesh`` is the :func:`repro.runtime.spmd.mesh_key` signature the SPMD
    sub-plans were derived for (empty when meshless); dispatch only applies
    a plan's ``spmd`` hint when the active mesh matches.
    """

    def __init__(self, entries: dict[str, PackPlan], mesh: str = "",
                 meta: dict[str, Any] | None = None):
        self.entries: dict[str, PackPlan] = dict(entries)
        self.mesh = mesh
        self.meta: dict[str, Any] = dict(meta or {})
        self._layouts: dict[tuple, PackPlan | None] | None = None

    # -- lookups -------------------------------------------------------------
    def get(self, path: str) -> PackPlan | None:
        """Entry for an exact parameter path, or None."""
        return self.entries.get(path)

    def for_suffix(self, suffix: str) -> PackPlan | None:
        """The unique entry whose path ends with ``suffix`` (dot-separated
        components), or None when absent/ambiguous."""
        parts = suffix.split(".")
        hits = [e for p, e in self.entries.items()
                if p.strip(".").split(".")[-len(parts):] == parts]
        return hits[0] if len(hits) == 1 else None

    def subplans(self, component: str) -> dict[str, PackPlan]:
        """Leaf-name → entry for paths that contain ``component`` as a
        non-final segment (e.g. ``subplans("mlp")`` → the w_gate/w_up/w_down
        entries of the unique mlp subtree).  Ambiguous names are dropped —
        dispatch then falls back to layout matching."""
        grouped: dict[str, list[PackPlan]] = {}
        for p, e in self.entries.items():
            segs = p.strip(".").split(".")
            if component in segs[:-1]:
                grouped.setdefault(segs[-1], []).append(e)
        return {n: es[0] for n, es in grouped.items()
                if all(e == es[0] for e in es)}

    def for_layout(self, key: tuple) -> PackPlan | None:
        """Entry matching a packed operand's layout signature; None when no
        entry (or more than one distinct entry) produces that layout."""
        if self._layouts is None:
            # Stacked (lead-dim) entries participate too: the scan body
            # slices layer stacks to per-matrix operands whose layout is
            # exactly the entry's (layout_key ignores lead).  Distinct
            # entries colliding on one layout resolve to None — dispatch
            # then falls back to ordinary auto resolution.
            table: dict[tuple, PackPlan | None] = {}
            for e in self.entries.values():
                if e.mode == "dense":
                    continue
                k = e.layout_key()
                if k in table and table[k] != e:
                    table[k] = None
                elif k not in table:
                    table[k] = e
            self._layouts = table
        return self._layouts.get(key)

    # -- accounting / reporting ---------------------------------------------
    def compressed_bytes(self) -> int:
        """Total packed weight bytes across every planned leaf."""
        return sum(e.compressed_bytes() for e in self.entries.values())

    def summary(self) -> dict[str, str]:
        """Parameter path → :meth:`PackPlan.describe` line, sorted."""
        return {p: e.describe() for p, e in sorted(self.entries.items())}

    def __len__(self) -> int:
        return len(self.entries)

    # -- (de)serialization ---------------------------------------------------
    def to_json(self) -> dict:
        """Versioned JSON document (``PLAN_VERSION``-stamped) holding every
        entry; inverse of :meth:`from_json`."""
        return {
            "version": PLAN_VERSION,
            "mesh": self.mesh,
            "meta": self.meta,
            "entries": {p: e.to_json() for p, e in self.entries.items()},
        }

    @classmethod
    def from_json(cls, d: dict) -> "ModelPlan":
        """Rebuild from :meth:`to_json` output; rejects other plan-format
        versions rather than guessing at field meanings."""
        if d.get("version") != PLAN_VERSION:
            raise ValueError(
                f"unsupported plan version {d.get('version')!r} "
                f"(want {PLAN_VERSION})")
        entries = {p: PackPlan.from_json(e)
                   for p, e in d.get("entries", {}).items()}
        return cls(entries, mesh=d.get("mesh", ""), meta=d.get("meta"))

    def save(self, path) -> pathlib.Path:
        """Write the plan as indented JSON (parents created); returns the
        path for chaining into log lines."""
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_json(), indent=1, sort_keys=True))
        return path

    @classmethod
    def load(cls, path) -> "ModelPlan":
        """Read a plan saved by :meth:`save`."""
        return cls.from_json(json.loads(pathlib.Path(path).read_text()))


# ---------------------------------------------------------------------------
# active-plan context: how model blocks receive their layer's plan
# ---------------------------------------------------------------------------
_ACTIVE: contextvars.ContextVar[ModelPlan | None] = contextvars.ContextVar(
    "repro_pack_plan", default=None)


def active_plan() -> ModelPlan | None:
    """The :class:`ModelPlan` installed by the innermost
    :func:`use_plan` context, or None outside any."""
    return _ACTIVE.get()


@contextlib.contextmanager
def use_plan(plan: ModelPlan | None):
    """Install ``plan`` for every ``sod.apply`` dispatch traced inside the
    block (the step builders in :mod:`repro.launch.steps` wrap their bodies
    in this, so jit tracing sees the plan).  ``None`` is a no-op."""
    if plan is None:
        yield None
        return
    token = _ACTIVE.set(plan)
    try:
        yield plan
    finally:
        _ACTIVE.reset(token)


def active_entry(suffix: str) -> PackPlan | None:
    """Unique entry of the active plan ending with ``suffix``, else None."""
    mp = active_plan()
    return mp.for_suffix(suffix) if mp is not None else None


def active_subplans(component: str) -> dict[str, PackPlan] | None:
    """``subplans(component)`` of the active plan, or None when no plan is
    active (callers pass the result straight to ``layers.mlp(plans=...)``)."""
    mp = active_plan()
    return mp.subplans(component) if mp is not None else None


def lookup_active(layout_key: tuple) -> PackPlan | None:
    """Layout-signature lookup into the active plan (dispatch fallback when
    the call site doesn't know its parameter path)."""
    mp = active_plan()
    return mp.for_layout(layout_key) if mp is not None else None
