# Sparse-on-Dense core: formats, pruning, the SoD compute module, and the
# paper's analytical cost model.
from repro.core.formats import (  # noqa: F401
    Bitmap,
    BlockCSR,
    TiledCSC,
    density,
    pack_bitmap,
    pack_block_csr,
    pack_csc,
    pack_tiled_csc,
    unpack_csc,
)
from repro.core.pruning import (  # noqa: F401
    PAPER_PROFILES,
    SparsityProfile,
    block_prune,
    magnitude_prune,
    nm_prune,
    prune_tree,
    random_sparse,
)
from repro.core.sod import DENSE, SoDConfig, apply, pack_param  # noqa: F401
from repro.core.topology import (  # noqa: F401
    MULTI_POD,
    PAPER_28NM,
    SINGLE_POD,
    TPU_V5E,
    ChipSpec,
    MeshSpec,
)
