"""Hardware constants for roofline analysis and the paper's cost model.

Two parameter sets coexist:
  * ``TPU_V5E``  — the executable-reproduction target (roofline terms).
  * ``PAPER_28NM`` — the paper's 28nm CMOS evaluation context, used by
    ``core.cost_model`` to reproduce the paper's figures.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    """One accelerator chip, as seen by the roofline model."""

    name: str
    peak_bf16_flops: float   # FLOP/s
    hbm_bandwidth: float     # bytes/s
    ici_link_bandwidth: float  # bytes/s per link
    ici_links: int           # links per chip (2D torus: 4)
    hbm_bytes: int           # capacity
    vmem_bytes: int          # usable VMEM per core
    clock_hz: float

    @property
    def flops_per_byte_balance(self) -> float:
        return self.peak_bf16_flops / self.hbm_bandwidth


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    ici_links=4,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=64 * 1024**2,
    clock_hz=0.94e9,
)

# VPU throughput estimate used by the decompression napkin math in DESIGN.md:
# 8 sublanes x 128 lanes x ~2 ALU ops / cycle.
TPU_V5E_VPU_OPS_PER_CYCLE = 2048.0


@dataclasses.dataclass(frozen=True)
class MeshSpec:
    """Logical mesh used for the roofline collective term."""

    axes: tuple[str, ...]
    shape: tuple[int, ...]
    chip: ChipSpec = TPU_V5E

    @property
    def num_chips(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    def axis_size(self, name: str) -> int:
        return self.shape[self.axes.index(name)]


SINGLE_POD = MeshSpec(axes=("data", "model"), shape=(16, 16))
MULTI_POD = MeshSpec(axes=("pod", "data", "model"), shape=(2, 16, 16))


# ---------------------------------------------------------------------------
# Paper's 28 nm evaluation context (Section IV).  Energy numbers are standard
# 28/45 nm scaling values (Horowitz ISSCC'14 style) that reproduce the
# qualitative and quantitative behaviour reported in the paper: DRAM access
# dominates, SRAM ~1-2 orders below, MAC lowest.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PaperTech:
    name: str = "28nm"
    clock_hz: float = 500e6
    # energy per element-access / op (pJ), 16-bit datapath
    e_dram_per_bit: float = 20.0      # pJ/bit off-chip DRAM
    e_sram_per_bit: float = 0.35      # pJ/bit large global SRAM buffer
    e_mac_16b: float = 1.0            # pJ per 16-bit MAC (mult+add+reg)
    e_index_match: float = 0.25       # pJ per index comparison (sparse PEs)
    e_fifo_per_bit: float = 0.10      # pJ/bit FIFO traversal
    # area, mm^2 (28nm; calibrated so the dense baseline reproduces the
    # paper's Table II absolute TOPS/mm²: 0.956 logic-only, 0.430 +2MB SRAM)
    a_dense_pe: float = 1.046e-3      # one 16-bit MAC PE incl. pipeline regs
    a_sram_per_kb: float = 2.56e-3    # global buffer SRAM
    # value/index bit widths used throughout the paper
    bits_value: int = 16
    bits_index: int = 8


PAPER_28NM = PaperTech()
