"""Packing planner: tuning-cache + cost model → per-layer ModelPlan.

:func:`build_plan` walks a parameter pytree (concrete arrays *or* the
``eval_shape`` ShapeDtypeStructs the dry-run uses), and emits one
:class:`repro.core.plan.PackPlan` per packable leaf:

* **capacity** — from the shared sizing functions in :mod:`repro.core.plan`:
  the exact data-dependent capacity when the weights are concrete (what a
  lossless global-config pack would use), the deterministic mean+4σ budget
  otherwise.  Either way the number is recorded in the plan, so replaying it
  — in train, serve, or a dry-run — produces byte-identical packed layouts
  and therefore identical tuning-cache keys.
* **format** — per layer, the configured packed mode or plain dense,
  whichever stores fewer bytes.  A high-density layer whose padded packed
  footprint exceeds its dense bytes stays dense (the paper's argument that
  format parameters must track per-layer sparsity structure); a per-layer
  plan therefore never exceeds the global-config pack in compressed bytes.
* **dispatch hint** — the persisted tuning cache is consulted at the plan's
  layout/M: a measured winner's parameters ride along in
  ``dispatch_params`` (and seed dispatch even on a machine with a cold
  cache); otherwise the analytical prior's choice is recorded in ``note``.
* **SpmdPlan** — when a mesh (and a :class:`~repro.configs.base.ModelConfig`)
  is given, each non-stacked packed leaf gets the partition plan matching
  its resident sharding from
  :func:`repro.runtime.sharding.packed_matmul_plans`.

:func:`warmup_plan` keys tuning-cache warmup off a plan: every distinct
planned layout is materialized (random weights packed at the plan's exact
capacity) and tuned at the plan's M values — no model parameters needed, so
a plan dumped by the dry-run can pre-warm a serving host's cache before the
checkpoint even loads.
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp

from repro.core import formats, pruning, sod
from repro.core import plan as plan_mod
from repro.core.plan import ModelPlan, PackPlan
from repro.core.sod import SoDConfig
from repro.kernels import registry

__all__ = ["build_plan", "build_draft_plan", "choose_draft_density",
           "warmup_plan", "load_or_build", "DRAFT_DENSITY_LADDER",
           "NOMINAL_QDRIFT"]


def _is_abstract(leaf) -> bool:
    return isinstance(leaf, jax.ShapeDtypeStruct)


def _pruned_leaf(leaf, sod_cfg: SoDConfig, tile, prune: bool):
    """Pruned copy of one (possibly stacked) leaf, via the same
    :func:`repro.core.sod._prune_leaf` loop ``sodify_params`` packs with.

    Note ``--plan auto`` prunes twice by design: once here to *observe*
    capacities, once in ``sodify_params`` when actually packing — the plan
    stays a pure value (JSON-serializable, replayable) instead of carrying
    device arrays.  Pruning is deterministic, so both passes agree.
    """
    leaf = jnp.asarray(leaf)
    if prune and sod_cfg.density < 1.0:
        return sod._prune_leaf(leaf, sod_cfg.density, sod_cfg.prune_method,
                               tile, sod_cfg.br)
    return leaf


def _packed_candidate(leaf, sod_cfg: SoDConfig, tile: tuple[int, int],
                      prune: bool) -> PackPlan:
    shape = tuple(int(s) for s in leaf.shape[-2:])
    lead = tuple(int(s) for s in leaf.shape[:-2])
    bk, bn = tile
    common = dict(shape=shape, lead=lead, density=sod_cfg.density,
                  prune_method=sod_cfg.prune_method, tile=tuple(tile),
                  br=sod_cfg.br, dtype=str(jnp.dtype(leaf.dtype)))
    # observed capacities come from the packers' own counting helpers
    # (formats.observed_*_cap), so planned caps can never drift from what a
    # lossless global-config pack would choose
    observe = not _is_abstract(leaf)
    pruned = _pruned_leaf(leaf, sod_cfg, tile, prune) if observe else None
    if sod_cfg.mode == "tiled_csc":
        cap = plan_mod.tiled_cap(
            bk, sod_cfg.density,
            observed=formats.observed_tiled_cap(pruned, tile)
            if observe else None)
        return PackPlan(mode="tiled_csc", cap=cap, **common)
    bcap = plan_mod.block_bcap(
        bk // sod_cfg.br, sod_cfg.density, sod_cfg.prune_method,
        sod_cfg.br * bn,
        observed=formats.observed_block_cap(pruned, tile, sod_cfg.br)
        if observe else None)
    return PackPlan(mode="block_csr", bcap=bcap, **common)


def _abstract_operand(e: PackPlan, dtype):
    """Packed container of ShapeDtypeStructs with the entry's exact layout
    (enough for :func:`repro.kernels.registry.problem_key`).  Built by the
    same constructors ``sodify_abstract`` uses, so hint/warmup cache keys
    can never drift from the dry-run's abstract shapes."""
    k, n = e.shape
    if e.mode == "tiled_csc":
        return sod._abstract_tiled((), k, n, dtype, e.tile, e.cap,
                                   qmode=e.qmode)
    return sod._abstract_block((), k, n, dtype, e.tile, e.br, e.bcap,
                               qmode=e.qmode)


# Nominal relative-RMS round-trip drift per quantization mode, used when the
# planner only has abstract shapes (no weight values to measure).  Calibrated
# on gaussian magnitude-pruned weights; a measured pass always wins when the
# weights are concrete.
NOMINAL_QDRIFT = {"none": 0.0, "int8": 0.005, "fp8": 0.03, "codebook": 0.1}

# auto-mode search order: ascending stored bits (codebook 4 < int8/fp8 8 <
# none 16); int8 before fp8 because it drifts less at the same width
_QMODE_ORDER = ("codebook", "int8", "fp8", "none")


def _measured_qdrift(pruned2d, e: PackPlan) -> dict[str, float]:
    """Relative-RMS round-trip drift of each candidate qmode on one
    concretely pruned 2-D weight, packed at the entry's exact layout."""
    if e.mode == "tiled_csc":
        packed = formats.pack_tiled_csc(pruned2d, tile=e.tile, cap=e.cap)
    else:
        packed = formats.pack_block_csr(pruned2d, tile=e.tile, br=e.br,
                                        bcap=e.bcap)
    base = packed.to_dense()
    bnorm = float(jnp.linalg.norm(base)) or 1.0
    out = {"none": 0.0}
    for q in _QMODE_ORDER:
        if q == "none" or (q == "fp8" and formats.fp8_dtype() is None):
            continue
        dq = formats.quantize_packed(packed, q).to_dense()
        out[q] = float(jnp.linalg.norm(dq - base)) / bnorm
    return out


def _select_qmode(e: PackPlan, leaf, requested: str, drift_budget: float,
                  sod_cfg: SoDConfig, prune: bool) -> PackPlan:
    """Resolve a plan entry's quantization mode.

    An explicit mode is taken as-is (fp8 raises early when the jax build
    lacks ``float8_e4m3fn``).  ``"auto"`` walks candidate modes from
    smallest stored width up and keeps the first whose round-trip drift
    fits ``drift_budget`` — measured on the actual pruned weights when
    concrete, :data:`NOMINAL_QDRIFT` otherwise.  The chosen drift is
    recorded in the entry's ``note`` so plan JSON explains the choice.
    """
    if requested == "none":
        return e
    if requested != "auto":
        if requested == "fp8" and formats.fp8_dtype() is None:
            raise ValueError(
                "qmode='fp8' needs a jax build with float8_e4m3fn")
        return dataclasses.replace(e, qmode=requested)
    if _is_abstract(leaf):
        drifts = {q: NOMINAL_QDRIFT[q] for q in _QMODE_ORDER
                  if q == "none" or not (q == "fp8"
                                         and formats.fp8_dtype() is None)}
        tag = "nominal"
    else:
        w2 = jnp.asarray(leaf)
        if w2.ndim > 2:
            w2 = w2.reshape((-1,) + w2.shape[-2:])[0]
        if prune and sod_cfg.density < 1.0:
            w2 = sod.prune_weight(w2, sod_cfg.density, e.prune_method,
                                  e.tile, e.br)
        drifts = _measured_qdrift(w2, e)
        tag = "measured"
    for q in _QMODE_ORDER:
        if q in drifts and drifts[q] <= drift_budget:
            if q == "none":
                return e
            return dataclasses.replace(
                e, qmode=q, note=f"qdrift({tag})={drifts[q]:.4f}")
    return e


def _attach_hint(e: PackPlan, dtype, cache, backend, m: int) -> PackPlan:
    """Dispatch hint from the persisted tuning cache (measured winner) or
    the analytical prior at the plan's layout."""
    from repro.kernels import autotune  # deferred: autotune imports registry

    cache = autotune.get_cache() if cache is None else cache
    key = registry.problem_key(_abstract_operand(e, dtype), m=int(m),
                               backend=backend)
    hit = cache.get(key)
    prefix = f"{e.note}; " if e.note else ""
    if hit is not None:
        return dataclasses.replace(
            e, dispatch_params=dict(hit.get("params") or {}),
            note=f"{prefix}tuned:{hit.get('impl', '?')}")
    ranked = autotune.rank_candidates(key)
    if ranked:
        return dataclasses.replace(
            e, note=f"{prefix}prior:{ranked[0][1].name}")
    return e


def _spmd_dict(sp) -> dict:
    return {
        "batch_axes": list(sp.batch_axes),
        "col_axis": sp.col_axis,
        "row_axis": sp.row_axis,
        "gather_axis": sp.gather_axis,
    }


def build_plan(
    params,
    sod_cfg: SoDConfig,
    *,
    cfg=None,
    mesh=None,
    cache=None,
    backend: str | None = None,
    m_values: tuple[int, ...] = (128, 8),
    tiles: tuple[tuple[int, int], ...] | None = None,
    allow_dense: bool = True,
    prune: bool = True,
    qmode: str | None = None,
    drift_budget: float = 0.05,
) -> ModelPlan:
    """Per-layer :class:`~repro.core.plan.ModelPlan` for a param pytree.

    ``params`` may hold concrete arrays (exact observed capacities) or
    ShapeDtypeStructs (deterministic budgets).  ``cfg``/``mesh`` enable the
    SPMD pass; ``tiles`` widens the tile-geometry search beyond
    ``sod_cfg.tile`` (candidates are ranked by compressed bytes).

    ``qmode`` sets the per-layer value quantization: ``None`` inherits
    ``sod_cfg.qmode``, an explicit mode applies everywhere, and ``"auto"``
    picks the smallest mode whose round-trip drift fits ``drift_budget``
    (measured against the pruned weights when concrete, nominal per-mode
    constants otherwise).  The dense-bytes fallback below compares against
    the *quantized* compressed bytes, so the plan's dense-never-worse
    guarantee holds for the bytes the pack will actually store.
    """
    req_qmode = sod_cfg.qmode if qmode is None else qmode
    entries: dict[str, PackPlan] = {}
    if sod_cfg.enabled:
        flat, _ = sod._flatten_named(params)
        for name, leaf in flat:
            if isinstance(leaf, (formats.TiledCSC, formats.BlockCSR)):
                raise ValueError(
                    f"build_plan expects unpacked params; {name} is already "
                    f"a {type(leaf).__name__}")
            if not (sod._packable(name, leaf)
                    and min(leaf.shape[-2:]) >= sod_cfg.min_dim):
                continue
            cands = [_packed_candidate(leaf, sod_cfg, tuple(t), prune)
                     for t in (tiles or (tuple(sod_cfg.tile),))]
            best = min(cands, key=lambda e: e.compressed_bytes())
            best = _select_qmode(best, leaf, req_qmode, drift_budget,
                                 sod_cfg, prune)
            if allow_dense and best.dense_bytes() < best.compressed_bytes():
                # keep the pruning geometry (tile/br) — dense fallback
                # changes the storage format, not the sparsity pattern
                best = PackPlan(
                    mode="dense", shape=best.shape, lead=best.lead,
                    density=sod_cfg.density,
                    prune_method=sod_cfg.prune_method,
                    tile=tuple(sod_cfg.tile), br=sod_cfg.br,
                    dtype=best.dtype, note="packed would exceed dense bytes")
            if best.mode != "dense":
                best = _attach_hint(best, leaf.dtype, cache, backend,
                                    m_values[0] if m_values else 128)
            entries[name] = best

    mesh_sig = ""
    if mesh is not None and cfg is not None and entries:
        from repro.runtime import sharding as shard_mod
        from repro.runtime import spmd as spmd_mod

        shapes = jax.tree_util.tree_map(
            lambda leaf: jax.ShapeDtypeStruct(tuple(leaf.shape),
                                              jnp.dtype(leaf.dtype)),
            params)
        packed_abs = sod.sodify_abstract(shapes, sod_cfg,
                                         plan=ModelPlan(entries))
        for path, sp in shard_mod.packed_matmul_plans(
                packed_abs, cfg, mesh).items():
            e = entries.get(path)
            if e is not None:
                entries[path] = dataclasses.replace(e, spmd=_spmd_dict(sp))
        mesh_sig = spmd_mod.mesh_key(mesh)

    meta = {
        "sod": {"mode": sod_cfg.mode, "density": sod_cfg.density,
                "prune_method": sod_cfg.prune_method,
                "tile": list(sod_cfg.tile), "br": sod_cfg.br,
                "min_dim": sod_cfg.min_dim, "qmode": req_qmode},
        "m_values": [int(m) for m in m_values],
        "backend": backend or registry.current_backend(),
        "arch": getattr(cfg, "name", ""),
    }
    return ModelPlan(entries, mesh=mesh_sig, meta=meta)


# ---------------------------------------------------------------------------
# draft tier (speculative decoding)
# ---------------------------------------------------------------------------
DRAFT_DENSITY_LADDER = (0.05, 0.08, 0.12, 0.2, 0.3, 0.5)


def _draft_sod_cfg(sod_cfg: SoDConfig, density: float,
                   qmode: str | None = None) -> SoDConfig:
    """Draft-tier :class:`~repro.core.sod.SoDConfig`: the target's packing
    geometry (format, tile, prune method) re-pruned to ``density``.  A
    dense target still gets a packed draft — magnitude-pruned
    ``tiled_csc`` — which is the paper's point: the same dense matmul
    path serves the compressed tier too.  ``qmode`` (optional) stores the
    draft tier's values quantized (int8 / fp8 / codebook), shrinking its
    bytes — and the draft step cost — independent of density."""
    if sod_cfg.enabled:
        draft = dataclasses.replace(sod_cfg, density=float(density))
    else:
        draft = SoDConfig(mode="tiled_csc", density=float(density),
                          prune_method="magnitude", min_dim=64)
    if qmode is not None:
        draft = dataclasses.replace(draft, qmode=qmode)
    return draft


def _expected_window_tokens(alpha: float, k: int) -> float:
    """Expected committed tokens per k-draft window under i.i.d. per-token
    acceptance probability ``alpha``: the longest accepted prefix plus the
    bonus target token, E = sum_{i=0..k} alpha^i."""
    return float(sum(alpha ** i for i in range(k + 1)))


def _draft_alpha(density: float) -> float:
    """Heuristic acceptance probability for a draft tier keeping
    ``density`` of the target's weights.  Monotone in density with
    alpha(1) ≈ 1 (an unpruned self-draft always agrees): the sqrt shape
    keeps moderate tiers attractive while harshly discounting extreme
    pruning.  A measured acceptance curve can replace this without
    touching the selection rule."""
    return 0.95 * float(density) ** 0.5


def choose_draft_density(
    params,
    sod_cfg: SoDConfig,
    *,
    spec_k: int = 4,
    candidates: tuple[float, ...] = DRAFT_DENSITY_LADDER,
    cfg=None,
    cache=None,
    m_values: tuple[int, ...] = (128, 8),
    draft_qmode: str | None = None,
) -> tuple[float, dict]:
    """Cost-model choice of the draft tier's sparsity.

    For each candidate density the draft tier is planned abstractly
    (ShapeDtypeStructs — no pruning pass) and costed by the paper's
    decode model: decode is weight-bytes-bound, so a window of k draft
    steps plus one target verify costs ``k·r + 1`` target-step
    equivalents, where ``r`` is the draft/target ratio of planned
    compressed bytes over the packable weight set.  Expected yield is the
    standard speculative-decoding window formula under the documented
    acceptance heuristic :func:`_draft_alpha`; the density maximizing
    yield/cost wins.  Returns ``(density, diagnostics)``.

    ``draft_qmode`` quantizes the draft tier's value storage (int8 / fp8 /
    codebook): the candidate plans are built with that ``qmode``, so ``r``
    is the *quantized* draft bytes over the target bytes — a codebook
    draft at equal density costs ~4x less per step, shifting the optimum
    toward denser (higher-acceptance) tiers.
    """
    shapes = jax.tree_util.tree_map(
        lambda leaf: jax.ShapeDtypeStruct(tuple(leaf.shape),
                                          jnp.dtype(leaf.dtype)), params)

    def _ratio(plan: ModelPlan) -> float:
        dense = sum(e.dense_bytes() for e in plan.entries.values())
        return plan.compressed_bytes() / dense if dense else 1.0

    if sod_cfg.enabled:
        t_ratio = _ratio(build_plan(shapes, sod_cfg, cfg=cfg, cache=cache,
                                    m_values=m_values))
    else:
        t_ratio = 1.0
    diag: dict = {"spec_k": int(spec_k), "target_ratio": round(t_ratio, 4),
                  "candidates": {}}
    if draft_qmode is not None:
        diag["draft_qmode"] = draft_qmode
    best_d, best_score = None, -1.0
    for d in candidates:
        dplan = build_plan(shapes,
                           _draft_sod_cfg(sod_cfg, d, qmode=draft_qmode),
                           cfg=cfg, cache=cache, m_values=m_values)
        r = _ratio(dplan) / max(t_ratio, 1e-9)
        alpha = _draft_alpha(d)
        score = _expected_window_tokens(alpha, spec_k) / (spec_k * r + 1.0)
        diag["candidates"][f"{d:g}"] = {
            "cost_ratio": round(r, 4), "alpha": round(alpha, 4),
            "tokens_per_cost": round(score, 4)}
        if score > best_score:
            best_d, best_score = float(d), score
    diag["chosen"] = best_d
    return best_d, diag


def build_draft_plan(
    params,
    sod_cfg: SoDConfig,
    *,
    draft_density: float | None = None,
    spec_k: int = 4,
    cfg=None,
    mesh=None,
    cache=None,
    backend: str | None = None,
    m_values: tuple[int, ...] = (128, 8),
    draft_qmode: str | None = None,
) -> tuple[SoDConfig, ModelPlan]:
    """Second, aggressive :class:`~repro.core.plan.ModelPlan` over the
    *same* weights — the speculative-decoding draft tier.

    ``params`` must be the raw (unpacked) parameters; pack the draft copy
    with ``sodify_params(params, draft_cfg, plan=draft_plan)`` *before*
    packing the target tier.  ``draft_density=None`` delegates to
    :func:`choose_draft_density`; ``draft_qmode`` quantizes the draft
    tier's value storage and feeds the quantized bytes into that choice.
    Returns ``(draft_cfg, draft_plan)``; the plan's meta records the tier
    and the diagnostics of the density choice.
    """
    diag = None
    if draft_density is None:
        draft_density, diag = choose_draft_density(
            params, sod_cfg, spec_k=spec_k, cfg=cfg, cache=cache,
            m_values=m_values, draft_qmode=draft_qmode)
    draft_cfg = _draft_sod_cfg(sod_cfg, draft_density, qmode=draft_qmode)
    plan = build_plan(params, draft_cfg, cfg=cfg, mesh=mesh, cache=cache,
                      backend=backend, m_values=m_values)
    plan.meta["tier"] = "draft"
    plan.meta["spec_k"] = int(spec_k)
    if diag is not None:
        plan.meta["density_choice"] = diag
    return draft_cfg, plan


def _concrete_operand(e: PackPlan, key):
    """Random concrete operand with the entry's exact packed layout."""
    w = pruning.random_sparse(key, e.shape, min(max(e.density, 0.05), 1.0))
    if e.prune_method == "block" and e.density < 1.0:
        w = pruning.block_prune(w, e.density, block=(e.br, e.tile[1]))
    w = w.astype(jnp.dtype(e.dtype))
    if e.mode == "tiled_csc":
        packed = formats.pack_tiled_csc(w, tile=e.tile, cap=e.cap)
    else:
        packed = formats.pack_block_csr(w, tile=e.tile, br=e.br, bcap=e.bcap)
    if e.qmode != "none":
        packed = formats.quantize_packed(packed, e.qmode)
    return packed


def warmup_plan(
    plan: ModelPlan,
    m_values: tuple[int, ...] | None = None,
    *,
    mesh=None,
    backend: str | None = None,
    cache=None,
    iters: int = 1,
    seed: int = 0,
) -> dict:
    """Tune every distinct planned layout at the plan's M values.

    Layouts are synthesized from the plan alone (random weights packed at
    the planned capacity — kernel runtime depends on the static layout, not
    the values), so warmup needs no model parameters.  With ``mesh``,
    entries carrying an SPMD sub-plan are tuned at their per-local-shard
    shape under the mesh-qualified cache key instead, mirroring
    :func:`repro.runtime.spmd.warmup_params_spmd`.
    """
    from repro.kernels import autotune

    cache = autotune.get_cache() if cache is None else cache
    m_values = tuple(int(m) for m in
                     (m_values or plan.meta.get("m_values") or (128,)))
    stats = {"tuned": 0, "cached": 0, "skipped": 0}
    rng = jax.random.PRNGKey(seed)
    seen: set = set()
    for path, e in sorted(plan.entries.items()):
        if e.mode == "dense":
            stats["skipped"] += 1
            continue
        # Stacked entries tune at their per-matrix slice layout — exactly
        # what the scan body dispatches after lead-dim slicing.
        sig = e.layout_key() + (e.dtype,
                                repr(sorted((e.spmd or {}).items())))
        if sig in seen:
            continue
        seen.add(sig)
        w = _concrete_operand(
            e, jax.random.fold_in(rng, zlib.crc32(path.encode()) % (2**31)))
        mesh_sig = ""
        dp = 1
        if mesh is not None and e.spmd:
            from repro.runtime import spmd as spmd_mod

            sp = spmd_mod.SpmdPlan.from_dict(e.spmd)
            try:
                spmd_mod._validate(sp, mesh, w)
            except ValueError:
                stats["skipped"] += 1
                continue
            w = spmd_mod._local_packed(w, mesh, sp)
            mesh_sig = f"{spmd_mod.mesh_key(mesh)}|{sp.signature()}"
            dp = spmd_mod._axes_size(mesh, sp.batch_axes)
        for m in dict.fromkeys(m_values):
            m_local = max(-(-m // dp), 1)
            pk = registry.problem_key(w, m=m_local, backend=backend,
                                      mesh=mesh_sig)
            if cache.get(pk) is not None:
                stats["cached"] += 1
                continue
            x = jax.random.normal(
                jax.random.fold_in(rng, (zlib.crc32(repr(sig).encode())
                                         ^ m) % (2**31)),
                (m_local, w.shape[0]), jnp.float32)
            if jnp.issubdtype(jnp.dtype(e.dtype), jnp.floating):
                x = x.astype(e.dtype)
            autotune.tune(x, w, backend=backend, mesh=mesh_sig, cache=cache,
                          iters=iters)
            stats["tuned"] += 1
    return stats


def load_or_build(
    plan_arg: str | None,
    params,
    sod_cfg: SoDConfig,
    *,
    cfg=None,
    mesh=None,
    cache=None,
    m_values: tuple[int, ...] = (),
    qmode: str | None = None,
) -> ModelPlan | None:
    """Resolve a launch script's ``--plan`` argument.

    ``None``/empty → no plan (historical global-config packing); ``"auto"``
    → build one with the planner; anything else is a JSON path to replay.
    ``qmode`` forwards the ``--quantize`` flag to :func:`build_plan`
    (``"auto"`` enables the drift-budgeted per-layer choice).
    """
    if not plan_arg:
        return None
    if plan_arg == "auto":
        return build_plan(params, sod_cfg, cfg=cfg, mesh=mesh, cache=cache,
                          m_values=tuple(m_values) or (128, 8), qmode=qmode)
    return ModelPlan.load(plan_arg)
