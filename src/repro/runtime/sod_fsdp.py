"""Sparse-on-Dense at the interconnect boundary (DESIGN.md §2, beyond-paper).

The paper's trade — compressed storage + cheap local re-densify + dense
compute — applied to the two dominant collective planes of large-scale
training:

* **Compressed weight all-gather (SoD-FSDP)** — params live ZeRO-3-style
  sharded across the data axis *in TiledCSC form*; each step all-gathers the
  compressed (vals, rows) payload (≈ 1.5·density of the dense bytes) and
  decompresses once on-chip before the dense matmul.
* **Compressed gradient reduce (top-k + error feedback)** — each data shard
  all-gathers only its top-k gradient coordinates; the dense sum is rebuilt
  locally by scatter-add.  ≈ 6·ratio bytes/element crosses the wire instead
  of 4 (fp32), a >10× collective-byte cut at ratio 0.05.

Both run under ``shard_map`` so the collective is explicit in HLO — the
dry-run's collective-bytes parser sees exactly what would cross the links.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.formats import TiledCSC
from repro.optim.grad import topk_compress, topk_decompress

Params = Any


# ---------------------------------------------------------------------------
# compressed weight all-gather
# ---------------------------------------------------------------------------
def shard_packed(packed: TiledCSC, mesh: Mesh, axis: str = "data") -> TiledCSC:
    """Place a packed weight sharded along its Nt grid dim on ``axis``.

    Quantized packs shard the per-tile scale along the same Nt dim and
    replicate the codebook (a whole-matrix shared-value table)."""
    nd = packed.vals.ndim
    spec = P(*((None,) * (nd - 3) + (axis, None, None)))
    sharding = jax.sharding.NamedSharding(mesh, spec)
    kw = {}
    if packed.scale is not None:
        s_spec = P(*((None,) * (packed.scale.ndim - 1) + (axis,)))
        kw["scale"] = jax.device_put(
            packed.scale, jax.sharding.NamedSharding(mesh, s_spec))
    if packed.codebook is not None:
        kw["codebook"] = jax.device_put(
            packed.codebook,
            jax.sharding.NamedSharding(
                mesh, P(*(None,) * packed.codebook.ndim)))
    return TiledCSC(
        vals=jax.device_put(packed.vals, sharding),
        rows=jax.device_put(packed.rows, sharding),
        shape=packed.shape, tile=packed.tile, qmode=packed.qmode, **kw)


def sod_fsdp_matmul(x: jax.Array, packed: TiledCSC, mesh: Mesh,
                    axis: str = "data", impl: str = "auto") -> jax.Array:
    """``x @ W`` with W stored compressed + sharded on the data axis.

    Inside shard_map each chip all-gathers the *compressed* shard list
    (collective bytes ≈ 1.5·density·dense), decompresses locally, and runs
    its dense matmul.  x is replicated across ``axis`` (the usual FSDP
    situation: activations sharded on batch, weights gathered per layer).

    The gather-then-matmul is the ``gather_axis`` plan of
    :mod:`repro.runtime.spmd`, so the local decompress+matmul dispatches
    through the kernel registry with a mesh-qualified problem key: tuned
    Pallas kernels on TPU (shard_map makes them mesh-legal), the
    differentiable jnp oracle elsewhere.  Stacked (lead-dim) layouts keep
    the explicit per-layout gather below.
    """
    nd = packed.vals.ndim
    if nd == 4:
        from repro.runtime import spmd

        return spmd.sod_matmul_spmd(
            x, packed, mesh=mesh, plan=spmd.SpmdPlan(gather_axis=axis),
            impl=impl, out_dtype=x.dtype)

    w_spec = P(*((None,) * (nd - 3) + (axis, None, None)))
    scale, codebook = packed.scale, packed.codebook
    s_spec = (P(*((None,) * (scale.ndim - 1) + (axis,)))
              if scale is not None else P())
    cb_spec = (P(*(None,) * codebook.ndim)
               if codebook is not None else P())

    def body(x_l, vals_l, rows_l, scale_l, cb_l):
        vals = jax.lax.all_gather(vals_l, axis, axis=nd - 3, tiled=True)
        rows = jax.lax.all_gather(rows_l, axis, axis=nd - 3, tiled=True)
        s = (jax.lax.all_gather(scale_l, axis, axis=scale_l.ndim - 1,
                                tiled=True)
             if scale is not None else None)
        w = TiledCSC(vals, rows, packed.shape, packed.tile,
                     scale=s, codebook=cb_l if codebook is not None else None,
                     qmode=packed.qmode)
        # stacked layouts re-densify and run the XLA-fused scatter+dot —
        # the same lead-dim treatment as sod.apply (kernels are per-matrix)
        return jnp.einsum(
            "mk,...kn->...mn", x_l, w.to_dense()).astype(x_l.dtype)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), w_spec, w_spec, s_spec, cb_spec),
        out_specs=P(),
        check_rep=False)
    # dummy zero stand-ins keep the body signature static when a side band
    # is absent (shard_map positional inputs can't be None)
    return fn(x, packed.vals, packed.rows,
              packed.scale if scale is not None else jnp.zeros(()),
              packed.codebook if codebook is not None else jnp.zeros(()))


# ---------------------------------------------------------------------------
# compressed gradient all-reduce
# ---------------------------------------------------------------------------
def compressed_grad_allreduce(grad: jax.Array, mesh: Mesh, ratio: float,
                              axis: str = "data",
                              error: jax.Array | None = None):
    """Mean of per-shard grads moving only top-k coordinates + indices.

    Returns (dense mean grad, new error-feedback residual).  The residual
    keeps dropped coordinates for the next step (DGC-style), so the
    compression is unbiased over time.
    """
    if error is None:
        error = jnp.zeros_like(grad, jnp.float32)
    n_shards = mesh.shape[axis]

    def body(g_l, e_l):
        g_fb = g_l.astype(jnp.float32) + e_l
        vals, idx, resid = topk_compress(g_fb, ratio)
        all_vals = jax.lax.all_gather(vals, axis)      # (S, k)
        all_idx = jax.lax.all_gather(idx, axis)        # (S, k)
        dense = topk_decompress(
            all_vals.reshape(-1), all_idx.reshape(-1), g_l.shape)
        return dense / n_shards, resid

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis)),
        check_rep=False)
    # grads enter sharded on the data axis along dim 0 (per-shard grads)
    return fn(grad, error)


def collective_savings(density: float, ratio: float | None = None,
                       qmode: str = "none") -> dict:
    """Napkin numbers used in EXPERIMENTS.md §Perf.

    ``qmode`` narrows the gathered value bytes: int8/fp8 packs cross the
    wire at (1B value + 1B index)/2B dense = 1.0·density; 4-bit codebook
    indices at 0.75·density (scale/codebook side bands are per-tile /
    per-matrix and vanish in the napkin)."""
    from repro.core.plan import QVALUE_BITS

    vbytes = QVALUE_BITS.get(qmode, 16) / 8.0
    w = (vbytes + 1.0) / 2.0 * density  # (value + 1B index) / 2B dense
    out = {"weight_allgather_fraction": w}
    if ratio is not None:
        out["grad_reduce_fraction"] = 1.5 * ratio  # (4+2)B / 4B per kept elt
    return out
