"""Partition rules: params / optimizer state / batches → PartitionSpec trees.

Rules are path-pattern based and **format-aware**: a packed Sparse-on-Dense
leaf (TiledCSC / BlockCSR) inherits the dense weight's (K, N) specs on its
tile-grid dims (Kt, Nt) — compressed storage shards exactly like the dense
matrix it stands for.

ZeRO-1: optimizer moments and fp32 masters are *additionally* sharded over
the data axes along the first dimension that divides evenly — the standard
optimizer-state partitioning required to fit the 27–34B archs in 16 GB/chip.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.formats import BlockCSR, TiledCSC
from repro.launch.mesh import dp_axes

Params = Any


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= mesh.shape[a]
        return n
    return mesh.shape[name]


# ---------------------------------------------------------------------------
# dense-weight rules.  Returns the spec for the *matrix* dims (K, N); any
# leading dims (layer-stack groups) are unsharded.
# ---------------------------------------------------------------------------
def _matrix_spec(path: str, shape: tuple[int, ...], cfg: ModelConfig,
                 tp: int) -> tuple:
    kv_dim = cfg.n_kv_heads * cfg.head_dim
    kv_ok = cfg.n_kv_heads % tp == 0

    def col_shard(dim):  # shard output/N dim on model axis when divisible
        return "model" if dim % tp == 0 else None

    if "embed" in path and len(shape) >= 2:
        # (V, D) vocab-sharded; audio (C, V, D)
        return ("model", None) if len(shape) == 2 else (None, "model", None)
    if "patch_proj" in path:
        return (None, None)
    if "head" in path:
        return (None, col_shard(shape[-1]))
    if re.search(r"w[qkv]\b|wq|wk|wv", path):
        is_kv = shape[-1] == kv_dim and kv_dim != cfg.n_heads * cfg.head_dim
        if ("wk" in path or "wv" in path) and not kv_ok:
            return (None, None)          # replicate KV when heads < TP
        if ("wk" in path or "wv" in path):
            return (None, "model")
        return (None, col_shard(shape[-1]))
    if "wo" in path:
        return (col_shard(shape[-2]), None)
    if "w_down" in path or "out_proj" in path or "w_out" in path:
        return (col_shard(shape[-2]), None)
    if re.search(r"w_gate|w_up|in_proj|w_z|w_x\b", path):
        return (None, col_shard(shape[-1]))
    if "router" in path or "w_dt" in path or "w_b" in path or "w_c" in path:
        return (None, None)
    if "w_if" in path or "w_gates" in path:
        return (None, None)
    return tuple(None for _ in shape[-2:]) if len(shape) >= 2 else (None,)


def _leaf_spec(path: str, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    tp = mesh.shape["model"]
    if isinstance(leaf, (TiledCSC, BlockCSR)):
        raise TypeError("packed leaves are handled by their sub-arrays")
    shape = getattr(leaf, "shape", ())
    nd = len(shape)
    if nd <= 1:
        return P()
    # quantization codebook: a whole-matrix shared-value table with no grid
    # dims — always replicated
    if path.endswith(".codebook"):
        return P(*(None,) * nd)
    # packed sub-arrays: the (Kt, Nt) tile-grid dims shard like the dense
    # matrix's (K, N); divisibility checked against the grid dims below.
    # The per-tile quantization scale is exactly a (Kt, Nt) grid (tail 0).
    packed_tail = {"vals": 2, "rows": 2, "block_vals": 3, "block_ids": 1,
                   "tile_nnz": 0, "scale": 0}
    m = re.search(r"\.(vals|rows|block_vals|block_ids|tile_nnz|scale)$", path)
    if m:
        tail = packed_tail[m.group(1)]
        grid = shape[nd - tail - 2: nd - tail]
        base = _matrix_spec(path, grid, cfg, tp)
        spec = (tuple(None for _ in range(nd - tail - 2)) + base
                + (None,) * tail)
        fixed = [
            None if (ax is not None and dim % _axis_size(mesh, ax) != 0)
            else ax
            for dim, ax in zip(shape, spec)
        ]
        return P(*fixed)

    # MoE stacked experts: (..., E, d_in, d_out) — EP on the expert dim
    if re.search(r"moe.*(w_gate|w_up|w_down)", path) and nd >= 3:
        ep = "model" if shape[-3] % tp == 0 else None
        return P(*(tuple(None for _ in range(nd - 3)) + (ep, None, None)))
    if "moe" in path and "router" in path:
        return P(*(None,) * nd)

    mat = _matrix_spec(path, shape, cfg, tp)
    lead = tuple(None for _ in range(nd - len(mat)))
    spec = lead + mat
    # drop shardings that don't divide
    fixed = []
    for dim, ax in zip(shape, spec):
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            ax = None
        fixed.append(ax)
    return P(*fixed)


_PACKED_SUBS = {
    TiledCSC: ("vals", "rows"),
    BlockCSR: ("block_vals", "block_ids", "tile_nnz"),
}


def _packed_specs(name: str, leaf, cfg: ModelConfig, mesh: Mesh):
    """Container-of-PartitionSpecs for one packed leaf.

    Flattening a registered pytree node yields index-keyed paths
    (``[<flat index 0>]``), never ``.vals`` — so the sub-arrays are named
    explicitly here or the format-aware grid-dim rules in
    :func:`_leaf_spec` would silently fall through to the dense rules and
    shard a within-tile dim.
    """
    subs = _PACKED_SUBS[type(leaf)] + tuple(
        s for s in ("scale", "codebook") if getattr(leaf, s) is not None)
    fields = {s: _leaf_spec(f"{name}.{s}", getattr(leaf, s), cfg, mesh)
              for s in subs}
    if isinstance(leaf, TiledCSC):
        return TiledCSC(shape=leaf.shape, tile=leaf.tile, qmode=leaf.qmode,
                        **fields)
    return BlockCSR(shape=leaf.shape, tile=leaf.tile, br=leaf.br,
                    qmode=leaf.qmode, **fields)


def param_specs(params: Params, cfg: ModelConfig, mesh: Mesh) -> Params:
    """PartitionSpec pytree matching ``params`` (packed leaves expanded)."""
    is_packed = lambda l: isinstance(l, (TiledCSC, BlockCSR))
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=is_packed)
    specs = []
    for path, leaf in flat:
        name = jax.tree_util.keystr(path).replace("'", "").replace("]", "")
        name = name.replace("[", ".")
        if is_packed(leaf):
            specs.append(_packed_specs(name, leaf, cfg, mesh))
        else:
            specs.append(_leaf_spec(name, leaf, cfg, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# ZeRO-1 optimizer-state specs
# ---------------------------------------------------------------------------
def _zero_spec(spec: P, shape: tuple[int, ...], mesh: Mesh,
               dp: tuple[str, ...]) -> P:
    if not shape:
        return P()
    dp_size = _axis_size(mesh, dp if len(dp) > 1 else dp[0])
    cur = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = list(cur)
    for i, (dim, ax) in enumerate(zip(shape, cur)):
        if ax is None and dim % dp_size == 0:
            out[i] = dp if len(dp) > 1 else dp[0]
            return P(*out)
    return P(*cur)


def opt_state_specs(opt_state: Params, p_specs: Params, mesh: Mesh,
                    zero1: bool = True) -> Params:
    """Moments/master mirror the param spec + ZeRO-1 data-axis sharding.

    m/v/master trees share the param treedef (``AdamW.init`` uses tree_map),
    so specs zip leaf-for-leaf; scalar placeholders for int leaves get P().
    """
    dp = dp_axes(mesh)
    flat_p = jax.tree_util.tree_leaves(
        p_specs, is_leaf=lambda x: isinstance(x, P))

    def mom_specs(tree):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        assert len(leaves) == len(flat_p), (len(leaves), len(flat_p))
        out = []
        for leaf, ps in zip(leaves, flat_p):
            shape = getattr(leaf, "shape", ())
            if not shape:
                out.append(P())
                continue
            spec = ps if len(tuple(ps)) <= len(shape) else P()
            out.append(_zero_spec(spec, shape, mesh, dp) if zero1 else spec)
        return jax.tree_util.tree_unflatten(treedef, out)

    return {
        "step": P(),
        "m": mom_specs(opt_state["m"]),
        "v": mom_specs(opt_state["v"]),
        "master": mom_specs(opt_state["master"]),
    }


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------
def batch_specs(batch: Params, mesh: Mesh) -> Params:
    dp = dp_axes(mesh)
    dp_ax = dp if len(dp) > 1 else dp[0]

    def one(leaf):
        shape = getattr(leaf, "shape", ())
        if not shape:
            return P()
        if shape[0] % _axis_size(mesh, dp_ax) == 0:
            return P(dp_ax, *(None,) * (len(shape) - 1))
        return P(*(None,) * len(shape))

    return jax.tree_util.tree_map(one, batch)


def cache_specs(cache: Params, cfg: ModelConfig, mesh: Mesh,
                batch_size: int, seq_len: int | None = None,
                seq_shard: bool = True) -> Params:
    """KV caches: batch on data axes; cache *sequence* dim on ``model``.

    Sequence-sharding the KV cache keeps the attention contraction local per
    chip — softmax over the sharded context needs only tiny max/sum stat
    collectives instead of an all-gather of the whole cache (a 17 GB/chip/
    step gather in the baseline llama decode cell — EXPERIMENTS.md §Perf A1).
    """
    dp = dp_axes(mesh)
    dp_ax = dp if len(dp) > 1 else dp[0]
    dp_size = _axis_size(mesh, dp_ax)
    tp = mesh.shape["model"]

    def one(path, leaf):
        name = jax.tree_util.keystr(path)
        shape = getattr(leaf, "shape", ())
        nd = len(shape)
        if nd == 0:
            return P()
        spec = [None] * nd
        batch_dim = None
        for i, d in enumerate(shape):
            if d == batch_size and d % dp_size == 0:
                spec[i] = dp_ax
                batch_dim = i
                break
        is_kv = name.endswith("['k']") or name.endswith("['v']") \
            or ".k" in name or ".v" in name
        if seq_shard and is_kv and seq_len and nd >= 4:
            for i in range(nd - 1, -1, -1):
                if i != batch_dim and shape[i] == seq_len \
                        and shape[i] % tp == 0:
                    spec[i] = "model"
                    break
        if batch_dim is None and all(s is None for s in spec):
            # batch unshardable (e.g. B=1): shard kv heads / feature dim
            for i in range(nd - 1, 0, -1):
                if spec[i] is None and shape[i] % tp == 0 and shape[i] >= tp \
                        and ("ssm" in name or "mlstm" in name):
                    spec[i] = "model"
                    break
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def to_shardings(spec_tree: Params, mesh: Mesh) -> Params:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# SPMD matmul plans for packed leaves
# ---------------------------------------------------------------------------
def packed_matmul_plans(params: Params, cfg: ModelConfig, mesh: Mesh) -> dict:
    """``{param path: SpmdPlan}`` for every packed (TiledCSC/BlockCSR) leaf.

    The plan mirrors the leaf's *resident* sharding from
    :func:`param_specs` — a Kt grid dim sharded on ``model`` becomes row
    parallelism, a sharded Nt dim column parallelism — so wrapping the
    matmul in :func:`repro.runtime.spmd.sod_matmul_spmd` under this plan
    adds no weight resharding at the shard_map boundary.  Consumed by the
    dry-run's dispatch report and by per-layer plan plumbing.
    """
    from repro.runtime import spmd

    is_packed = lambda l: isinstance(l, (TiledCSC, BlockCSR))
    flat, _ = jax.tree_util.tree_flatten_with_path(params, is_leaf=is_packed)
    plans: dict[str, object] = {}
    for path, leaf in flat:
        if not is_packed(leaf) or leaf.lead:
            continue
        name = jax.tree_util.keystr(path).replace("'", "").replace("]", "")
        name = name.replace("[", ".")
        vals = leaf.vals if isinstance(leaf, TiledCSC) else leaf.block_vals
        vals_spec = _leaf_spec(
            name + (".vals" if isinstance(leaf, TiledCSC) else ".block_vals"),
            vals, cfg, mesh)
        plans[name] = spmd.plan_from_spec(vals_spec, mesh)
    return plans
