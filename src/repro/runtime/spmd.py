"""SPMD execution layer: shard_map wrappers that make every kernel-registry
impl mesh-legal inside pjit-sharded model steps.

``pallas_call`` has no GSPMD partitioning rule, so tracing a Pallas kernel
directly under a sharded ``pjit`` step either fails or forces the whole
operand to one device — which is why cold-cache TPU dispatch historically
fell back to the XLA scatter+dot oracle (``registry.choose``).  This module
closes that gap the way the paper's datapath wants it closed: keep the
*compressed* operand at the memory/interconnect boundary, re-densify
per-chip, and keep the dense MXU kernel saturated.  Concretely, a matmul is
wrapped in ``shard_map`` under a partition *plan*:

* **data-parallel M-sharding** (``batch_axes``) — the flattened batch rows
  split across the data axes; every chip runs the full kernel on its row
  block.  No collectives in forward; the weight cotangent is psummed by the
  shard_map transpose.
* **column tensor parallelism** (``col_axis``) — the packed operand's Nt
  tile-grid dim stays sharded (exactly how ``runtime.sharding`` lays packed
  projections out on the ``model`` axis); each chip computes its N-slice.
* **row tensor parallelism** (``row_axis``) — Kt sharded, ``x`` split along
  K, partial products psummed.
* **compressed all-gather / SoD-FSDP** (``gather_axis``) — the operand
  lives sharded on Nt, each chip all-gathers the *compressed* (vals, rows)
  payload (≈1.5·density of the dense bytes) and decompresses locally before
  the dense matmul — the :mod:`repro.runtime.sod_fsdp` pattern, now
  available to every registry impl.

Inside the body the per-device problem is plain single-device code, so
dispatch goes through the ordinary registry/autotune resolver — with the
mesh signature in the :class:`~repro.kernels.registry.ProblemKey`, so tuned
tiles are per-*local-shard* (m/dp, k, n/tp), never confused with the global
shape, and ``registry.choose`` knows the Pallas impls are legal here.

Gradients: the kernels' custom VJPs (:mod:`repro.kernels.vjp`) run inside
the body; ``shard_map``'s transpose inserts the psums the plan implies
(weight grads over ``batch_axes``, activation grads over ``col_axis``) and
carries the integer leaves' ``float0`` cotangents through, so padding slots
keep their exactly-zero gradients under every plan.
"""
from __future__ import annotations

import contextvars
import dataclasses
import zlib

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # moved out of experimental in newer jax
    from jax.experimental.shard_map import shard_map
except ImportError:  # pragma: no cover - version dependent
    from jax import shard_map

from repro.core.formats import BlockCSR, TiledCSC
from repro.kernels import registry

__all__ = [
    "SpmdPlan",
    "active_mesh",
    "in_spmd_body",
    "mesh_key",
    "auto_plan",
    "plan_from_spec",
    "packed_specs",
    "sod_matmul_spmd",
    "warmup_params_spmd",
]

_IN_BODY = contextvars.ContextVar("repro_spmd_in_body", default=False)


def in_spmd_body() -> bool:
    """True while tracing inside one of this module's shard_map bodies —
    the guard :func:`repro.kernels.ops.sod_matmul` uses to avoid wrapping a
    shard_map inside a shard_map."""
    return _IN_BODY.get()


def active_mesh() -> Mesh | None:
    """The mesh of an enclosing ``with mesh:`` block, or None."""
    try:
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if not mesh.empty:
            return mesh
    except Exception:  # pragma: no cover - jax-version dependent internals
        pass
    try:  # newer jax: jax.sharding.use_mesh / get_abstract_mesh
        from jax.sharding import get_abstract_mesh

        mesh = get_abstract_mesh()
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    return None


def mesh_key(mesh: Mesh) -> str:
    """Stable signature of a mesh's (axis, size) layout: ``data=4,model=2``."""
    return ",".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)


@dataclasses.dataclass(frozen=True)
class SpmdPlan:
    """How one packed matmul is partitioned over the mesh.

    ``col_axis``, ``row_axis`` and ``gather_axis`` are mutually exclusive
    weight shardings (Nt-local, Kt-local, Nt-gathered); ``batch_axes`` may
    combine with any of them.
    """

    batch_axes: tuple[str, ...] = ()
    col_axis: str | None = None
    row_axis: str | None = None
    gather_axis: str | None = None

    def __post_init__(self):
        w_axes = [a for a in (self.col_axis, self.row_axis, self.gather_axis)
                  if a is not None]
        if len(w_axes) > 1:
            raise ValueError(f"plan shards the weight twice: {self}")
        if set(w_axes) & set(self.batch_axes):
            raise ValueError(f"axis both batch and weight sharded: {self}")

    def signature(self) -> str:
        parts = []
        if self.batch_axes:
            parts.append("dp=" + "+".join(self.batch_axes))
        if self.col_axis:
            parts.append(f"col={self.col_axis}")
        if self.row_axis:
            parts.append(f"row={self.row_axis}")
        if self.gather_axis:
            parts.append(f"gather={self.gather_axis}")
        return ";".join(parts) or "replicated"

    def axes(self) -> tuple[str, ...]:
        return self.batch_axes + tuple(
            a for a in (self.col_axis, self.row_axis, self.gather_axis)
            if a is not None)

    @classmethod
    def from_dict(cls, d: dict) -> "SpmdPlan":
        """Rebuild from the serialized form a :class:`repro.core.plan.PackPlan`
        carries (the one place plan-dict → SpmdPlan conversion lives)."""
        return cls(
            batch_axes=tuple(d.get("batch_axes", ())),
            col_axis=d.get("col_axis"),
            row_axis=d.get("row_axis"),
            gather_axis=d.get("gather_axis"))


def _axes_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _grid(w) -> tuple[int, int]:
    return tuple(int(g) for g in w.grid)


def auto_plan(mesh: Mesh, w, m: int | None = None) -> SpmdPlan | None:
    """Default plan for a packed matmul on ``mesh``, or None when wrapping
    isn't applicable (single device, stacked/lead layouts).

    Batch rows shard over the data axes; the Nt grid dim additionally
    shards over ``model`` when it divides — matching how
    :mod:`repro.runtime.sharding` lays packed projection weights out, so
    the shard_map in_specs coincide with the parameters' resident sharding
    and GSPMD inserts no weight resharding at the boundary.
    """
    if not isinstance(w, (TiledCSC, BlockCSR)) or w.lead:
        return None
    if _axes_size(mesh, mesh.axis_names) <= 1:
        return None
    batch = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    if not batch:
        batch = tuple(a for a in mesh.axis_names if a != "model")
    col = None
    if "model" in mesh.axis_names:
        _, nt = _grid(w)
        if mesh.shape["model"] > 1 and nt % mesh.shape["model"] == 0:
            col = "model"
    if not batch and col is None:
        return None
    return SpmdPlan(batch_axes=batch, col_axis=col)


def plan_from_spec(vals_spec: P, mesh: Mesh, grid_dims: tuple[int, int] = (0, 1)
                   ) -> SpmdPlan:
    """Plan matching a packed leaf's resident PartitionSpec (the output of
    :func:`repro.runtime.sharding.param_specs` for its ``vals`` array):
    a sharded Kt grid dim becomes row parallelism, a sharded Nt dim column
    parallelism, and batch rows ride the data axes either way."""
    spec = tuple(vals_spec)
    kt_dim, nt_dim = grid_dims
    kt_ax = spec[kt_dim] if kt_dim < len(spec) else None
    nt_ax = spec[nt_dim] if nt_dim < len(spec) else None
    if isinstance(kt_ax, tuple):
        kt_ax = kt_ax[0] if kt_ax else None
    if isinstance(nt_ax, tuple):
        nt_ax = nt_ax[0] if nt_ax else None
    batch = tuple(a for a in mesh.axis_names
                  if a in ("pod", "data") and a not in (kt_ax, nt_ax))
    return SpmdPlan(batch_axes=batch, col_axis=nt_ax, row_axis=kt_ax)


# ---------------------------------------------------------------------------
# spec trees / local containers
# ---------------------------------------------------------------------------
def _qside_specs(w, kt_ax, nt_ax) -> dict:
    """Spec entries for the quantization side bands: the per-tile scale
    shards with the (Kt, Nt) grid; the codebook (a whole-matrix shared-value
    table) is always replicated."""
    specs = {}
    if w.scale is not None:
        specs["scale"] = P(kt_ax, nt_ax)
    if w.codebook is not None:
        specs["codebook"] = P(*(None,) * w.codebook.ndim)
    return specs


def packed_specs(w, kt_ax: str | None = None, nt_ax: str | None = None):
    """Same-container-type pytree of PartitionSpecs for the packed leaves,
    sharding the (Kt, Nt) tile-grid dims on the given axes."""
    if isinstance(w, TiledCSC):
        s = P(kt_ax, nt_ax, None, None)
        return TiledCSC(vals=s, rows=s, shape=w.shape, tile=w.tile,
                        qmode=w.qmode, **_qside_specs(w, kt_ax, nt_ax))
    if isinstance(w, BlockCSR):
        return BlockCSR(
            block_vals=P(kt_ax, nt_ax, None, None, None),
            block_ids=P(kt_ax, nt_ax, None),
            tile_nnz=P(kt_ax, nt_ax),
            shape=w.shape, tile=w.tile, br=w.br,
            qmode=w.qmode, **_qside_specs(w, kt_ax, nt_ax))
    raise TypeError(f"not a packed operand: {type(w)}")


def _with_shape(w, shape: tuple[int, int]):
    """Container with the same leaves but a different logical shape — used
    to restate a shard's leaves as a standalone local problem."""
    return dataclasses.replace(w, shape=shape)


def _gather_packed(w, axis: str):
    """All-gather the compressed leaves along their Nt grid dim — the
    SoD-FSDP collective: ≈1.5·density of the dense bytes cross the links.
    Quantized packs gather the narrow codes plus the per-tile scale (the
    wire cost drops with the value width); the codebook is replicated and
    needs no collective."""
    gat = lambda a: jax.lax.all_gather(a, axis, axis=1, tiled=True)
    kw = {} if w.scale is None else {"scale": gat(w.scale)}
    if isinstance(w, TiledCSC):
        return dataclasses.replace(w, vals=gat(w.vals), rows=gat(w.rows),
                                   **kw)
    return dataclasses.replace(
        w, block_vals=gat(w.block_vals), block_ids=gat(w.block_ids),
        tile_nnz=gat(w.tile_nnz), **kw)


def _validate(plan: SpmdPlan, mesh: Mesh, w) -> None:
    names = set(mesh.axis_names)
    for a in plan.axes():
        if a not in names:
            raise ValueError(f"plan axis {a!r} not in mesh {mesh.axis_names}")
    kt, nt = _grid(w)
    if plan.col_axis and nt % mesh.shape[plan.col_axis]:
        raise ValueError(
            f"Nt={nt} not divisible by {plan.col_axis}={mesh.shape[plan.col_axis]}")
    if plan.gather_axis and nt % mesh.shape[plan.gather_axis]:
        raise ValueError(
            f"Nt={nt} not divisible by {plan.gather_axis}="
            f"{mesh.shape[plan.gather_axis]}")
    if plan.row_axis and kt % mesh.shape[plan.row_axis]:
        raise ValueError(
            f"Kt={kt} not divisible by {plan.row_axis}={mesh.shape[plan.row_axis]}")


# ---------------------------------------------------------------------------
# the wrapper
# ---------------------------------------------------------------------------
def sod_matmul_spmd(
    x: jax.Array,
    w,
    *,
    mesh: Mesh | None = None,
    plan: SpmdPlan | None = None,
    impl: str = "auto",
    bm: int | None = None,
    out_dtype=None,
    backend: str | None = None,
    params: dict | None = None,
    fallback_params: dict | None = None,
) -> jax.Array:
    """``x @ W`` with the registry impl running inside ``shard_map``.

    ``x``: (..., K); returns (..., N).  Rows (and K under row parallelism)
    are zero-padded to divide the mesh and sliced back after — padding is
    differentiable, so grads keep their logical shapes.
    """
    mesh = mesh or active_mesh()
    if mesh is None:
        raise ValueError("sod_matmul_spmd needs a mesh (arg or `with mesh:`)")
    if plan is None:
        plan = auto_plan(mesh, w)
        if plan is None:
            raise ValueError(f"no auto plan for {type(w).__name__} on "
                             f"{mesh_key(mesh)}")
    _validate(plan, mesh, w)
    out_dtype = out_dtype or x.dtype
    backend = backend or registry.current_backend()

    k_logical, n_logical = (int(s) for s in w.shape)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    kt, nt = _grid(w)
    bk, bn = (int(t) for t in w.tile)

    dp = _axes_size(mesh, plan.batch_axes)
    m_pad = (-m) % dp
    row_shards = mesh.shape[plan.row_axis] if plan.row_axis else 1
    k_pad = kt * bk - k_logical if row_shards > 1 else 0
    if m_pad or k_pad:
        x2 = jnp.pad(x2, ((0, m_pad), (0, k_pad)))
    m_local = (m + m_pad) // dp

    col_shards = mesh.shape[plan.col_axis] if plan.col_axis else 1
    # local logical shape of the per-shard problem: full tile slabs when a
    # grid dim is sharded (the wrapper slices the global padding tail off
    # the reassembled output), the true logical size otherwise
    k_local = (kt // row_shards) * bk if row_shards > 1 else k_logical
    n_local = (nt // col_shards) * bn if col_shards > 1 else n_logical

    mesh_sig = f"{mesh_key(mesh)}|{plan.signature()}"
    batch_spec = plan.batch_axes if plan.batch_axes else None
    x_spec = P(batch_spec, plan.row_axis)
    y_spec = P(batch_spec, plan.col_axis)
    w_specs = packed_specs(w, kt_ax=plan.row_axis,
                           nt_ax=plan.col_axis or plan.gather_axis)

    def body(x_l, w_l):
        from repro.kernels import ops  # deferred: runtime layers over kernels

        token = _IN_BODY.set(True)
        try:
            if plan.gather_axis:
                w_l = _gather_packed(w_l, plan.gather_axis)
            w_loc = _with_shape(w_l, (k_local, n_local))
            key = registry.problem_key(w_loc, m=m_local, backend=backend,
                                       mesh=mesh_sig)
            chosen, run_params = ops.resolve(
                key, impl, params=params, bm=bm,
                fallback_params=fallback_params)
            y = chosen.run(x_l, w_loc, out_dtype=out_dtype, backend=backend,
                           **run_params)
            if plan.row_axis:
                y = jax.lax.psum(y, plan.row_axis)
            return y
        finally:
            _IN_BODY.reset(token)

    fn = shard_map(body, mesh=mesh, in_specs=(x_spec, w_specs),
                   out_specs=y_spec, check_rep=False)
    y = fn(x2, w)
    y = y[:m, :n_logical]
    return y.reshape(*lead, n_logical)


# ---------------------------------------------------------------------------
# per-shard autotuning (what launch --autotune does under a mesh)
# ---------------------------------------------------------------------------
def _local_packed(w, mesh: Mesh, plan: SpmdPlan):
    """A concrete one-shard slice of ``w`` under ``plan`` — the local
    problem the shard_map body sees, suitable for single-device tuning."""
    kt, nt = _grid(w)
    row = mesh.shape[plan.row_axis] if plan.row_axis else 1
    col = mesh.shape[plan.col_axis] if plan.col_axis else 1
    if row == 1 and col == 1:
        return w
    bk, bn = (int(t) for t in w.tile)
    kt_l, nt_l = kt // row, nt // col
    k_l = kt_l * bk if row > 1 else int(w.shape[0])
    n_l = nt_l * bn if col > 1 else int(w.shape[1])
    kw = {} if w.scale is None else {"scale": w.scale[:kt_l, :nt_l]}
    if isinstance(w, TiledCSC):
        return dataclasses.replace(
            w, vals=w.vals[:kt_l, :nt_l], rows=w.rows[:kt_l, :nt_l],
            shape=(k_l, n_l), **kw)
    return dataclasses.replace(
        w, block_vals=w.block_vals[:kt_l, :nt_l],
        block_ids=w.block_ids[:kt_l, :nt_l],
        tile_nnz=w.tile_nnz[:kt_l, :nt_l],
        shape=(k_l, n_l), **kw)


def warmup_params_spmd(
    params,
    m_values,
    mesh: Mesh,
    *,
    plan: SpmdPlan | None = None,
    backend: str | None = None,
    cache=None,
    iters: int = 1,
    seed: int = 0,
) -> dict:
    """Tune every distinct packed layout at its per-local-shard shape.

    Mirrors :func:`repro.kernels.autotune.warmup_params` but slices each
    layout down to one shard of ``plan`` (default: the auto plan) and keys
    the entries with the mesh signature, so a subsequent mesh run's
    shard_map bodies hit the cache exactly.  ``m_values`` are *global* row
    counts (batch·seq); the local m is derived per plan.
    """
    from repro.kernels import autotune

    cache = autotune.get_cache() if cache is None else cache
    leaves = jax.tree_util.tree_leaves(
        params, is_leaf=lambda l: isinstance(l, (TiledCSC, BlockCSR)))
    stats = {"tuned": 0, "cached": 0, "skipped": 0}
    rng = jax.random.PRNGKey(seed)
    seen: set = set()
    for leaf in leaves:
        if not isinstance(leaf, (TiledCSC, BlockCSR)) or leaf.lead:
            continue
        p = plan or auto_plan(mesh, leaf)
        if p is None:
            stats["skipped"] += 1
            continue
        try:
            _validate(p, mesh, leaf)
        except ValueError:
            stats["skipped"] += 1
            continue
        local = _local_packed(leaf, mesh, p)
        sig = (type(leaf).__name__, local.shape, str(local.dtype),
               tuple(local.tile), p.signature())
        if sig in seen:
            continue
        seen.add(sig)
        mesh_sig = f"{mesh_key(mesh)}|{p.signature()}"
        dp = _axes_size(mesh, p.batch_axes)
        for m in dict.fromkeys(int(v) for v in m_values):
            m_local = max(-(-m // dp), 1)
            pk = registry.problem_key(local, m=m_local, backend=backend,
                                      mesh=mesh_sig)
            if cache.get(pk) is not None:
                stats["cached"] += 1
                continue
            sig_digest = zlib.crc32(repr(sig).encode())
            x = jax.random.normal(
                jax.random.fold_in(rng, (sig_digest ^ m) % (2**31)),
                (m_local, local.shape[0]), jnp.float32)
            autotune.tune(x, local, backend=backend, mesh=mesh_sig,
                          cache=cache, iters=iters)
            stats["tuned"] += 1
    return stats
