"""Fault tolerance: failure detection, elastic re-mesh, straggler policy.

Posture for 1000+ nodes (all mechanisms unit-tested at small scale):

* **Checkpoint/restart** — the train loop snapshots asynchronously every
  ``ckpt_every`` steps (checkpoint/Checkpointer); any step-time exception is
  caught, the job rolls back to the last COMMITTED step and replays.  Data is
  a pure function of step (data/pipeline.py), so replay is bit-deterministic.
* **Elastic re-mesh** — on permanent device loss the surviving device list is
  re-factored into the largest (data', model) mesh with the same model axis
  (TP degree is a property of the checkpointed layout; the data axis is
  elastic).  Restore re-shards via ``Checkpointer.restore(shardings=...)``.
* **Straggler mitigation** — synchronous SPMD steps can't drop a slow chip,
  so mitigation operates at the step boundary: a wall-clock watchdog flags
  steps slower than ``straggler_factor ×`` the trailing-median; after
  ``max_strays`` consecutive flags the runner treats the step as failed
  (checkpoint-restart path, which in a real deployment re-schedules the slow
  host).  Deterministic data means the skipped host count never desyncs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

import jax
from jax.sharding import Mesh

Params = Any


@dataclasses.dataclass
class FaultConfig:
    ckpt_every: int = 50
    max_restarts: int = 3
    straggler_factor: float = 3.0
    max_strays: int = 3


def surviving_mesh(all_devices, failed_ids: set[int], model_axis: int,
                   axes=("data", "model")) -> Mesh:
    """Largest (data', model) mesh buildable from survivors.

    The model axis is preserved (parameter layout); the data axis shrinks to
    the largest multiple of ``model_axis`` the survivors allow.
    """
    alive = [d for d in all_devices if d.id not in failed_ids]
    data_axis = len(alive) // model_axis
    if data_axis < 1:
        raise RuntimeError(
            f"{len(alive)} survivors cannot host model axis {model_axis}")
    n = data_axis * model_axis
    return Mesh(np.asarray(alive[:n]).reshape(data_axis, model_axis), axes)


class StragglerWatchdog:
    """Flags steps whose wall time exceeds factor × trailing median."""

    def __init__(self, factor: float = 3.0, window: int = 20,
                 warmup: int = 3):
        self.factor = factor
        self.window = window
        self.warmup = warmup
        self.times: list[float] = []
        self.strays = 0

    def observe(self, seconds: float) -> bool:
        is_straggler = False
        if len(self.times) >= self.warmup:
            med = float(np.median(self.times[-self.window:]))
            is_straggler = seconds > self.factor * med
        self.times.append(seconds)
        self.strays = self.strays + 1 if is_straggler else 0
        return is_straggler


@dataclasses.dataclass
class StepResult:
    step: int
    metrics: dict
    seconds: float
    restarted: bool = False


class ResilientRunner:
    """Wraps a step function with checkpoint-restart + straggler policy."""

    def __init__(self, step_fn: Callable, checkpointer, fault: FaultConfig,
                 state_of: Callable[[], Params],
                 load_state: Callable[[Params], None]):
        self.step_fn = step_fn
        self.ckpt = checkpointer
        self.fault = fault
        self.state_of = state_of
        self.load_state = load_state
        self.watchdog = StragglerWatchdog(fault.straggler_factor)
        self.restarts = 0

    def run_step(self, step: int, *args) -> StepResult:
        t0 = time.perf_counter()
        try:
            metrics = self.step_fn(step, *args)
        except Exception:
            if self.restarts >= self.fault.max_restarts:
                raise
            self.restarts += 1
            last = self.ckpt.latest_step()
            if last is None:
                raise
            self.load_state(self.ckpt.restore(last, self.state_of()))
            metrics = self.step_fn(step, *args)   # deterministic replay
            return StepResult(step, metrics, time.perf_counter() - t0, True)
        dt = time.perf_counter() - t0
        straggling = self.watchdog.observe(dt)
        if straggling and self.watchdog.strays >= self.fault.max_strays:
            # persistent straggler → force a checkpoint so a re-schedule
            # loses nothing (the reschedule itself is the scheduler's job)
            self.ckpt.save(step, self.state_of(), blocking=False)
            self.watchdog.strays = 0
        if step % self.fault.ckpt_every == 0:
            self.ckpt.save(step, self.state_of(), blocking=False)
        return StepResult(step, metrics, dt)
