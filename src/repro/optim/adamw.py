"""AdamW with float32 master weights, built for ZeRO-1 sharding and packed
(Sparse-on-Dense) parameter pytrees.

Packed containers contribute *compressed-sized* moments (the paper's
effective-capacity argument applied to optimizer state) and their integer
index leaves (``rows`` / ``block_ids`` / ``tile_nnz``) pass through
untouched: ``jax.grad(..., allow_int=True)`` hands us ``float0`` gradients
for them, which we detect and skip.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


def _is_float(leaf) -> bool:
    return hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating)


def _is_float0_grad(g) -> bool:
    return hasattr(g, "dtype") and g.dtype == jax.dtypes.float0


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


@dataclasses.dataclass(frozen=True)
class AdamW:
    cfg: AdamWConfig
    schedule: Callable | None = None    # step -> lr multiplier source

    def init(self, params: Params) -> Params:
        def moments(p):
            if _is_float(p):
                return jnp.zeros(p.shape, jnp.float32)
            return jnp.zeros((), jnp.float32)    # placeholder for int leaves

        def master(p):
            if _is_float(p):
                return p.astype(jnp.float32)
            return jnp.zeros((), jnp.float32)

        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree_util.tree_map(moments, params),
            "v": jax.tree_util.tree_map(moments, params),
            "master": jax.tree_util.tree_map(master, params),
        }

    def update(self, params: Params, grads: Params, state: Params):
        cfg = self.cfg
        step = state["step"] + 1
        lr = self.schedule(step) if self.schedule else cfg.lr

        # ---- global-norm clip over float grads -----------------------------
        leaves = [
            g for g in jax.tree_util.tree_leaves(grads)
            if _is_float(g) and not _is_float0_grad(g)
        ]
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in leaves) + 1e-20)
        scale = jnp.minimum(1.0, cfg.grad_clip / gnorm)

        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v, w):
            if not _is_float(p) or _is_float0_grad(g):
                return p, m, v, w
            g = g.astype(jnp.float32) * scale
            m_new = cfg.b1 * m + (1 - cfg.b1) * g
            v_new = cfg.b2 * v + (1 - cfg.b2) * g * g
            upd_ = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
            w_new = w - lr * (upd_ + cfg.weight_decay * w)
            return w_new.astype(p.dtype), m_new, v_new, w_new

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        flat_w = treedef.flatten_up_to(state["master"])
        out = [upd(p, g, m, v, w)
               for p, g, m, v, w in zip(flat_p, flat_g, flat_m, flat_v, flat_w)]
        new_params = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_state = {
            "step": step,
            "m": jax.tree_util.tree_unflatten(treedef, [o[1] for o in out]),
            "v": jax.tree_util.tree_unflatten(treedef, [o[2] for o in out]),
            "master": jax.tree_util.tree_unflatten(treedef, [o[3] for o in out]),
        }
        return new_params, new_state, {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
