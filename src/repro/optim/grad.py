"""Distributed gradient tricks: accumulation + top-k compression.

``topk_compress``/``topk_decompress`` implement deep-gradient-compression
style sparsification with error feedback: only the top ``ratio`` fraction of
gradient magnitudes crosses the interconnect (values + int32 indices ≈
6·ratio bytes per fp32 gradient element vs 4 bytes dense).  This is the
paper's compressed-memory-boundary trade applied to the *gradient* plane
(DESIGN.md §2); ``runtime/sod_fsdp.py`` wires it into a shard_map collective.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = Any


def topk_compress(g: jax.Array, ratio: float):
    """Keep the k = ratio·n largest-|g|.  Returns (values, indices, error)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(int(flat.shape[0] * ratio), 1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    kept = flat[idx]
    error = flat.at[idx].set(0.0).reshape(g.shape)
    return kept, idx.astype(jnp.int32), error


def topk_decompress(vals: jax.Array, idx: jax.Array, shape, dtype=jnp.float32):
    n = 1
    for s in shape:
        n *= s
    out = jnp.zeros((n,), jnp.float32).at[idx].add(vals)
    return out.reshape(shape).astype(dtype)


def compress_tree(grads: Params, ratio: float, errors: Params | None = None):
    """Tree-wide compression with error feedback state."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if errors is None:
        err_leaves = [jnp.zeros_like(l, jnp.float32) if _f(l) else None
                      for l in leaves]
    else:
        err_leaves = treedef.flatten_up_to(errors)
    comp, new_err = [], []
    for l, e in zip(leaves, err_leaves):
        if not _f(l):
            comp.append(l)
            new_err.append(e)
            continue
        vals, idx, err = topk_compress(
            l.astype(jnp.float32) + (e if e is not None else 0.0), ratio)
        comp.append((vals, idx, l.shape))
        new_err.append(err)
    return (jax.tree_util.tree_unflatten(treedef, comp),
            jax.tree_util.tree_unflatten(treedef, new_err))


def _f(l):
    return hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.floating)


def accumulate(grads: Params, acc: Params | None, count: int):
    """Running mean for gradient accumulation."""
    if acc is None:
        return grads
    return jax.tree_util.tree_map(
        lambda a, g: a + (g - a) / count if _f(g) else g, acc, grads)
