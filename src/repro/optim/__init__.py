from repro.optim.adamw import AdamW, AdamWConfig  # noqa: F401
from repro.optim.schedule import cosine_schedule  # noqa: F401
