"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048
[arXiv:2306.05284; hf]

Audio frontend is a STUB per the assignment: inputs are 4 parallel EnCodec
codebook token streams; embeddings are summed, the head predicts all 4
codebooks (delay-pattern scheduling is a serving-driver concern).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    rope_theta=10_000.0,
    act="gelu",
    frontend="audio",
    n_codebooks=4,
)
