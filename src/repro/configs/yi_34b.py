"""yi-34b [dense] — llama-arch GQA.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[arXiv:2403.04652; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64_000,
    rope_theta=5_000_000.0,
)
