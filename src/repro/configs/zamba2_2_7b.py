"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]

54 mamba2 layers; one weight-shared attention+MLP block is applied every 6
mamba layers (9 applications).  Zamba2's per-application LoRA deltas on the
shared block are omitted (documented simplification, DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab=32_000,
    rope_theta=10_000.0,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    hybrid_attn_every=6,
)
