"""xlstm-125m [ssm] — sLSTM + mLSTM blocks.

12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304
[arXiv:2405.04517; unverified]

Groups of 3 mLSTM + 1 sLSTM (sLSTM at layers 3, 7, 11).  d_ff=0: blocks use
their internal up/down projections, no separate MLP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab=50_304,
    slstm_every=4,
    xlstm_proj_factor=2.0,
)
