"""Config schema: architectures and the assigned input-shape set."""
from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.sod import DENSE, SoDConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention
    rope_theta: float = 10000.0
    sliding_window: int | None = None     # for local layers
    layer_pattern: tuple[str, ...] = ("global",)  # repeating local/global
    attn_softcap: float | None = None
    final_softcap: float | None = None
    attn_scale: float | None = None
    use_post_norms: bool = False          # gemma2 sandwich norms
    embed_scale: bool = False             # gemma x*sqrt(d)
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"
    attn_chunk: int = 512

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_shared_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    ep_axis: int = 16                     # pad experts to a multiple of this
    moe_dispatch_blocks: int = 1          # = dp shards for local dispatch
    moe_a2a_axis: str | None = None       # EP axis for shard_map all-to-all
    #                                       dispatch (None = GSPMD scatter)

    # SSM / hybrid (zamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    hybrid_attn_every: int = 0            # shared attn block period

    # xLSTM
    slstm_every: int = 0                  # one sLSTM per this many layers
    xlstm_proj_factor: float = 2.0

    # modality frontend stubs
    frontend: str | None = None           # vision | audio
    frontend_dim: int = 0
    n_patches: int = 0                    # vision: prefix length
    n_codebooks: int = 0                  # audio

    # numerics & sparsity
    dtype: str = "bfloat16"
    sod: SoDConfig = DENSE
    remat: bool = True
    # scan layer groups (HLO size independent of depth).  The dry-run sets
    # False: XLA's cost_analysis counts while-loop bodies ONCE, so an
    # unrolled lowering is required for exact FLOP/collective accounting.
    scan_layers: bool = True

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables ceil-pad to 128 so the vocab dim shards on
        any power-of-two TP axis (granite's 49155 would otherwise replicate
        the logits matmul — EXPERIMENTS.md §Perf C1).  Logits at padded ids
        are masked to -inf; the logical ``vocab`` is unchanged."""
        return (self.vocab + 127) // 128 * 128

    @property
    def pattern_period(self) -> int:
        return len(self.layer_pattern)

    def window_for(self, slot: int) -> int | None:
        return self.sliding_window if self.layer_pattern[
            slot % self.pattern_period] == "local" else None

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, f, l = self.d_model, self.d_ff, self.n_layers
        qkvo = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm", "audio"):
            n += l * (qkvo + 3 * d * f)
        elif self.family == "moe":
            per = self.n_experts * 3 * d * f
            if self.n_shared_experts:
                per += 3 * d * (self.d_shared_ff or f * self.n_shared_experts)
            n += l * (qkvo + per)
        elif self.family == "hybrid":
            di = self.ssm_expand * d
            mamba = d * (2 * di + 2 * self.ssm_state + di // self.ssm_headdim) \
                + di * d
            n += l * mamba + (qkvo + 3 * d * f)   # one shared attn block
        elif self.family == "ssm":
            di = int(d * self.xlstm_proj_factor)
            n += l * (2 * d * di + 3 * di * di + di * d)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, f, l = self.d_model, self.d_ff, self.n_layers
        qkvo = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        per = self.top_k * 3 * d * f
        if self.n_shared_experts:
            per += 3 * d * (self.d_shared_ff or f * self.n_shared_experts)
        return self.vocab * d * 2 + l * (qkvo + per)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# archs whose attention is quadratic-full → long_500k skipped (DESIGN.md §4)
SUBQUADRATIC_FAMILIES = ("hybrid", "ssm")


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.family in SUBQUADRATIC_FAMILIES
    return True
