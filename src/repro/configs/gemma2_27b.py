"""gemma2-27b [dense] — local+global alternating attention, logit softcaps.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000
[arXiv:2408.00118; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256_000,
    rope_theta=10_000.0,
    sliding_window=4096,
    layer_pattern=("local", "global"),
    attn_softcap=50.0,
    final_softcap=30.0,
    attn_scale=(4608 / 32) ** -0.5,   # query_pre_attn_scalar = d_model/n_heads
    use_post_norms=True,
    embed_scale=True,
    tie_embeddings=True,
    act="gelu",
)
