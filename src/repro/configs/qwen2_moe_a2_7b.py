"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed experts, top-4.

24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936, MoE 60e top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

Experts ceil-pad to the EP axis (60 → 64 on a 16-way model axis); the router
masks the padding (DESIGN.md §5).  The 4 shared experts are fused into one
always-on MLP of hidden 4·1408 = 5632 with a sigmoid gate, as in the HF
implementation.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=151_936,
    rope_theta=1_000_000.0,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_shared_ff=5632,
    tie_embeddings=False,
)
