"""Architecture registry: ``get_config(arch)`` + reduced smoke variants."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    shape_applicable,
)
from repro.core.sod import SoDConfig

_MODULES = {
    "gemma2-27b": "gemma2_27b",
    "llama3.2-1b": "llama3_2_1b",
    "internlm2-1.8b": "internlm2_1_8b",
    "yi-34b": "yi_34b",
    "pixtral-12b": "pixtral_12b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "musicgen-medium": "musicgen_medium",
    "zamba2-2.7b": "zamba2_2_7b",
    "xlstm-125m": "xlstm_125m",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, sod: SoDConfig | None = None) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg: ModelConfig = mod.CONFIG
    if sod is not None:
        cfg = cfg.with_(sod=sod)
    return cfg


def reduced(cfg: ModelConfig, seq_hint: int = 128) -> ModelConfig:
    """Same-family tiny variant for CPU smoke tests.

    Keeps the structural pattern (local/global alternation, MoE top-k,
    hybrid period, sLSTM period) while shrinking every dimension.
    """
    period = cfg.pattern_period
    kw: dict = dict(
        name=cfg.name + "-smoke",
        n_layers=max(2 * period, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=0 if cfg.d_ff == 0 else 256,
        vocab=512,
        attn_chunk=64,
        ssm_chunk=32,
        remat=False,
        sliding_window=min(cfg.sliding_window, 64) if cfg.sliding_window else None,
    )
    if cfg.family == "moe":
        kw.update(n_experts=8, top_k=min(cfg.top_k, 2), ep_axis=4,
                  d_shared_ff=128 if cfg.d_shared_ff else 0)
    if cfg.family == "vlm":
        kw.update(frontend_dim=64, n_patches=16)
    if cfg.family == "hybrid":
        kw.update(n_layers=2 * cfg.hybrid_attn_every, ssm_state=16,
                  ssm_headdim=32, head_dim=32)
    if cfg.family == "ssm":
        kw.update(n_layers=2 * (cfg.slstm_every or 1))
    if cfg.attn_scale is not None:
        kw["attn_scale"] = (kw["d_model"] / kw["n_heads"]) ** -0.5
    return dataclasses.replace(cfg, **kw)
