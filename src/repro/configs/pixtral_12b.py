"""pixtral-12b [vlm] — pixtral-ViT + mistral-nemo backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified]

Per the assignment the vision frontend is a STUB: ``input_specs`` provides
precomputed 1024-d patch embeddings; the backbone projects and prepends them.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131_072,
    rope_theta=1_000_000.0,
    frontend="vision",
    frontend_dim=1024,
    n_patches=1024,
)
