"""granite-moe-1b-a400m [moe] — 32 routed experts, top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512 vocab=49155, MoE 32e top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49_155,
    rope_theta=10_000.0,
    n_experts=32,
    top_k=8,
    tie_embeddings=True,
)
