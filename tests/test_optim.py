"""AdamW vs a numpy reference; schedules; packed-pytree handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats, pruning
from repro.optim import AdamW, AdamWConfig, cosine_schedule

KEY = jax.random.PRNGKey(0)


def _np_adamw(w, g, m, v, step, cfg, lr):
    m = cfg.b1 * m + (1 - cfg.b1) * g
    v = cfg.b2 * v + (1 - cfg.b2) * g * g
    mh = m / (1 - cfg.b1 ** step)
    vh = v / (1 - cfg.b2 ** step)
    w = w - lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * w)
    return w, m, v


def test_adamw_matches_numpy_reference():
    cfg = AdamWConfig(lr=1e-2, grad_clip=1e9, weight_decay=0.1)
    opt = AdamW(cfg)
    w0 = jax.random.normal(KEY, (8, 8), jnp.float32)
    params = {"w": w0}
    state = opt.init(params)
    wn = np.asarray(w0, np.float64)
    m = np.zeros_like(wn)
    v = np.zeros_like(wn)
    for step in range(1, 6):
        g = np.asarray(jax.random.normal(jax.random.fold_in(KEY, step),
                                         (8, 8)), np.float64)
        params, state, _ = opt.update(params, {"w": jnp.asarray(g,
                                                                jnp.float32)},
                                      state)
        wn, m, v = _np_adamw(wn, g, m, v, step, cfg, cfg.lr)
    np.testing.assert_allclose(np.asarray(params["w"]), wn, atol=1e-4)


def test_grad_clip_scales_update():
    cfg = AdamWConfig(lr=1e-2, grad_clip=0.1, weight_decay=0.0)
    opt = AdamW(cfg)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    state = opt.init(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = opt.update(params, g, state)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_packed_params_train_on_mask_only():
    """Fixed-mask sparse training: padding slots and integer rows never move,
    and moments have the compressed footprint."""
    w = pruning.random_sparse(KEY, (256, 128), 0.3)
    packed = formats.pack_tiled_csc(w)
    params = {"w": packed}
    opt = AdamW(AdamWConfig(lr=1e-2, weight_decay=0.0))
    state = opt.init(params)
    assert state["m"]["w"].vals.shape == packed.vals.shape

    def loss(p):
        return jnp.sum(p["w"].to_dense() ** 2)

    grads = jax.grad(loss, allow_int=True)(params)
    p2, state, _ = opt.update(params, grads, state)
    # rows untouched
    np.testing.assert_array_equal(np.asarray(p2["w"].rows),
                                  np.asarray(packed.rows))
    # padding values still exactly zero; real values moved
    pad = np.asarray(packed.rows) < 0
    assert np.all(np.asarray(p2["w"].vals)[pad] == 0)
    real = ~pad & (np.asarray(packed.vals) != 0)
    assert np.any(np.asarray(p2["w"].vals)[real]
                  != np.asarray(packed.vals)[real])


def test_cosine_schedule_shape():
    sched = cosine_schedule(1.0, warmup_steps=10, total_steps=100,
                            min_ratio=0.1)
    assert float(sched(0)) == 0.0
    assert float(sched(10)) == pytest.approx(1.0, abs=1e-6)
    assert float(sched(100)) == pytest.approx(0.1, abs=1e-6)
    assert float(sched(55)) < float(sched(20))


def test_schedule_plugged_into_optimizer():
    opt = AdamW(AdamWConfig(lr=1.0),
                schedule=cosine_schedule(1.0, 2, 10))
    params = {"w": jnp.ones((2,), jnp.float32)}
    state = opt.init(params)
    _, state, metrics = opt.update(params, {"w": jnp.ones((2,))}, state)
    assert float(metrics["lr"]) == pytest.approx(0.5)  # warmup step 1/2
