"""Fallback for environments without ``hypothesis`` installed.

CI installs the real package (see requirements-dev.txt), where the property
tests run for real.  In bare environments this stub keeps the test modules
*collectable* — every ``@given``-decorated test is reported as skipped
instead of the whole module dying with ``ModuleNotFoundError`` at
collection time (which previously masked all the non-property tests in the
same files).

Usage in test modules:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, st
"""
import pytest


def given(*_args, **_kwargs):
    def decorate(fn):
        @pytest.mark.skip(reason="hypothesis not installed")
        def skipped():  # arg-less: the strategies would have supplied args
            pass

        skipped.__name__ = fn.__name__
        skipped.__doc__ = fn.__doc__
        return skipped

    return decorate


def settings(*_args, **_kwargs):
    def decorate(fn):
        return fn

    return decorate


class _AnyStrategy:
    """Stands in for ``strategies.*`` — every attribute is a callable
    returning None; @given never invokes the test so values don't matter."""

    def __getattr__(self, name):
        return lambda *a, **k: None


st = _AnyStrategy()
