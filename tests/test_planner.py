"""Layer-wise packing planner: plan build/replay, pack/abstract parity,
dispatch plumbing, and the prune-method regression fixes."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import plan as plan_mod
from repro.core import pruning, sod
from repro.core.formats import BlockCSR, TiledCSC
from repro.core.plan import ModelPlan, PackPlan
from repro.core.sod import SoDConfig, sodify_abstract, sodify_params
from repro.kernels import autotune, registry
from repro.models.model import build_model
from repro.runtime import planner

KEY = jax.random.PRNGKey(3)


def _shapes_of(tree):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(tuple(l.shape), jnp.dtype(l.dtype)),
        tree)


def _leaf_shapes(tree):
    return [(tuple(l.shape), str(jnp.dtype(l.dtype)))
            for l in jax.tree_util.tree_leaves(tree)]


# ---------------------------------------------------------------------------
# pack / abstract parity (the shared sizing function at work)
# ---------------------------------------------------------------------------
SOD_SAMPLE = [
    ("llama3.2-1b", SoDConfig(mode="tiled_csc", density=0.3, min_dim=64)),
    ("llama3.2-1b", SoDConfig(mode="block_csr", density=0.4,
                              prune_method="block", min_dim=64)),
    ("gemma2-27b", SoDConfig(mode="tiled_csc", density=0.5, min_dim=64)),
    ("musicgen-medium", SoDConfig(mode="block_csr", density=0.25,
                                  prune_method="block", min_dim=64)),
    ("llama3.2-1b", SoDConfig(mode="tiled_csc", density=0.3, min_dim=64,
                              qmode="int8")),
    ("llama3.2-1b", SoDConfig(mode="block_csr", density=0.4,
                              prune_method="block", min_dim=64,
                              qmode="codebook")),
]


@pytest.mark.parametrize("arch,sod_cfg", SOD_SAMPLE,
                         ids=[f"{a}-{c.mode}-q{c.qmode}"
                              for a, c in SOD_SAMPLE])
def test_plan_pack_abstract_parity(arch, sod_cfg):
    """sodify_abstract(shapes, plan) ≡ shapes of sodify_params(params, plan)
    — same treedef, same leaf shapes and dtypes, for both formats."""
    cfg = configs.reduced(configs.get_config(arch)).with_(sod=sod_cfg)
    model = build_model(cfg)
    params = model.init(KEY)
    plan = planner.build_plan(params, sod_cfg, cfg=cfg, m_values=(32,))
    assert len(plan) >= 4
    concrete = sodify_params(params, sod_cfg, plan=plan)
    abstract = sodify_abstract(_shapes_of(params), sod_cfg, plan=plan)
    assert (jax.tree_util.tree_structure(concrete)
            == jax.tree_util.tree_structure(abstract))
    assert _leaf_shapes(concrete) == _leaf_shapes(abstract)


def test_abstract_plan_replays_on_concrete_params():
    """The dry-run direction: a plan built from ShapeDtypeStructs (no weight
    values) replays on concrete weights with identical packed shapes AND
    identical tuning-cache keys."""
    sod_cfg = SoDConfig(mode="tiled_csc", density=0.3, min_dim=64)
    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(sod=sod_cfg)
    model = build_model(cfg)
    params = model.init(KEY)
    plan = planner.build_plan(_shapes_of(params), sod_cfg, cfg=cfg,
                              m_values=(32,))
    concrete = sodify_params(params, sod_cfg, plan=plan)
    abstract = sodify_abstract(_shapes_of(params), sod_cfg, plan=plan)
    assert _leaf_shapes(concrete) == _leaf_shapes(abstract)

    is_packed = lambda l: isinstance(l, (TiledCSC, BlockCSR))
    c_leaves = [l for l in jax.tree_util.tree_leaves(
        concrete, is_leaf=is_packed) if is_packed(l)]
    a_leaves = [l for l in jax.tree_util.tree_leaves(
        abstract, is_leaf=is_packed) if is_packed(l)]
    assert c_leaves and len(c_leaves) == len(a_leaves)
    for c, a in zip(c_leaves, a_leaves):
        if c.lead:
            continue  # stacked layouts dispatch via the dense bypass
        kc = autotune.key_str(registry.problem_key(c, m=32, backend="cpu"))
        ka = autotune.key_str(registry.problem_key(a, m=32, backend="cpu"))
        assert kc == ka


@pytest.mark.parametrize("qmode", ["none", "int8", "codebook"])
def test_plan_json_roundtrip_identical_pack(qmode):
    sod_cfg = SoDConfig(mode="block_csr", density=0.4, prune_method="block",
                        min_dim=64, qmode=qmode)
    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(sod=sod_cfg)
    model = build_model(cfg)
    params = model.init(KEY)
    plan = planner.build_plan(params, sod_cfg, cfg=cfg, m_values=(16,))
    blob = json.dumps(plan.to_json())
    plan2 = ModelPlan.from_json(json.loads(blob))
    assert plan2.entries == plan.entries
    assert _leaf_shapes(sodify_params(params, sod_cfg, plan=plan)) \
        == _leaf_shapes(sodify_params(params, sod_cfg, plan=plan2))


def test_plan_save_load(tmp_path):
    sod_cfg = SoDConfig(mode="tiled_csc", density=0.3, min_dim=64)
    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(sod=sod_cfg)
    params = build_model(cfg).init(KEY)
    plan = planner.build_plan(params, sod_cfg, cfg=cfg, m_values=(16,))
    path = plan.save(tmp_path / "plan.json")
    assert ModelPlan.load(path).entries == plan.entries


# ---------------------------------------------------------------------------
# planner never loses to the global-config pack; wins when packing doesn't pay
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("density", [0.3, 0.85])
def test_plan_bytes_never_exceed_global_pack(density):
    sod_cfg = SoDConfig(mode="tiled_csc", density=density, min_dim=64)
    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(sod=sod_cfg)
    model = build_model(cfg)
    params = model.init(KEY)
    plan = planner.build_plan(params, sod_cfg, cfg=cfg, m_values=(32,))
    planned = sod.tree_weight_bytes(sodify_params(params, sod_cfg, plan=plan))
    global_ = sod.tree_weight_bytes(sodify_params(params, sod_cfg))
    assert planned["compressed"] <= global_["compressed"]
    if density == 0.85:
        # packing at this density exceeds dense bytes; the planner must
        # have left at least one layer dense and strictly win
        assert any(e.mode == "dense" for e in plan.entries.values())
        assert planned["compressed"] < global_["compressed"]


def test_plan_entry_bytes_match_packed_leaves():
    """PackPlan.compressed_bytes agrees with the packed containers' own
    accounting — the planner's comparisons are real bytes."""
    sod_cfg = SoDConfig(mode="tiled_csc", density=0.4, min_dim=64)
    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(sod=sod_cfg)
    params = build_model(cfg).init(KEY)
    plan = planner.build_plan(params, sod_cfg, cfg=cfg, m_values=(32,))
    packed = sodify_params(params, sod_cfg, plan=plan)
    flat, _ = sod._flatten_named(packed)
    checked = 0
    for name, leaf in flat:
        e = plan.get(name)
        if e is None or not isinstance(leaf, (TiledCSC, BlockCSR)):
            continue
        assert e.compressed_bytes() == leaf.nbytes_compressed()
        checked += 1
    assert checked >= 3


# ---------------------------------------------------------------------------
# dispatch plumbing: blocks run under their layer's plan
# ---------------------------------------------------------------------------
def test_apply_honors_plan_impl_hint():
    w = pruning.random_sparse(KEY, (256, 256), 0.3)
    p = sod.pack_param(w, SoDConfig(mode="tiled_csc", density=1.0))
    entry = PackPlan(mode="tiled_csc", shape=(256, 256), cap=p.cap,
                     impl="jnp", dtype=str(p.dtype))
    x = jax.random.normal(KEY, (8, 256), jnp.float32)
    with registry.record_dispatches() as log:
        y = sod.apply(x, p, plan=entry)
    assert log and log[-1]["impl"] == "jnp_oracle"
    assert log[-1]["source"] == "forced"
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               atol=2e-3, rtol=1e-3)


def test_active_plan_layout_lookup_and_params():
    """With a ModelPlan installed, a bare sod.apply resolves the operand's
    entry by layout signature and applies its tuned dispatch params."""
    w = pruning.random_sparse(KEY, (256, 384), 0.3)
    p = sod.pack_param(w, SoDConfig(mode="tiled_csc", density=1.0))
    entry = PackPlan(mode="tiled_csc", shape=(256, 384), cap=p.cap,
                     impl="pallas", dispatch_params={"bm": 64},
                     dtype=str(p.dtype))
    mp = ModelPlan({".blocks.mlp.w_gate": entry})
    x = jax.random.normal(KEY, (16, 256), jnp.float32)
    with plan_mod.use_plan(mp), registry.record_dispatches() as log:
        y = sod.apply(x, p)
    assert log[-1]["impl"] == "pallas_fused"
    assert log[-1]["params"]["bm"] == 64  # the plan's tuned param applied
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               atol=2e-3, rtol=1e-3)
    # outside the context the same call falls back to ordinary dispatch
    with registry.record_dispatches() as log2:
        sod.apply(x, p)
    assert log2[-1]["source"] != "forced"


def test_model_forward_under_plan_matches_no_plan():
    """Installing the plan changes dispatch hints, not numerics."""
    sod_cfg = SoDConfig(mode="tiled_csc", density=0.4, min_dim=64)
    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(sod=sod_cfg)
    model = build_model(cfg)
    params = model.init(KEY)
    plan = planner.build_plan(params, sod_cfg, cfg=cfg, m_values=(32,))
    packed = sodify_params(params, sod_cfg, plan=plan)
    from repro.data.pipeline import SyntheticLMData

    batch = SyntheticLMData(cfg, 2, 32, seed=0).batch(0)
    with plan_mod.use_plan(plan):
        loss_planned, _ = model.loss(packed, batch)
    loss_plain, _ = model.loss(packed, batch)
    assert float(loss_planned) == pytest.approx(float(loss_plain), abs=1e-5)


def test_subplans_and_suffix_lookup():
    sod_cfg = SoDConfig(mode="tiled_csc", density=0.3, min_dim=64)
    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(sod=sod_cfg)
    params = build_model(cfg).init(KEY)
    plan = planner.build_plan(params, sod_cfg, cfg=cfg, m_values=(32,))
    sub = plan.subplans("mlp")
    assert set(sub) >= {"w_gate", "w_up", "w_down"}
    assert plan.for_suffix("attn.wo") is plan.get(".blocks.attn.wo")
    assert plan.for_suffix("definitely.not.there") is None


# ---------------------------------------------------------------------------
# tuning-cache feedback: warmup keyed off the plan; hints read the cache
# ---------------------------------------------------------------------------
def test_warmup_plan_populates_cache_at_plan_keys(tmp_path):
    sod_cfg = SoDConfig(mode="tiled_csc", density=0.3, min_dim=64)
    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(sod=sod_cfg)
    params = build_model(cfg).init(KEY)
    plan = planner.build_plan(params, sod_cfg, cfg=cfg, m_values=(16,))
    cache = autotune.TuningCache(tmp_path / "cache.json")
    stats = planner.warmup_plan(plan, (16,), backend="cpu", cache=cache)
    assert stats["tuned"] >= 1
    # every packed leaf of the planned pack hits the cache at the layout the
    # model dispatches (scan stacks dispatch their per-matrix slice)
    packed = sodify_params(params, sod_cfg, plan=plan)
    hits = misses = 0
    for leaf in jax.tree_util.tree_leaves(
            packed, is_leaf=lambda l: isinstance(l, (TiledCSC, BlockCSR))):
        if not isinstance(leaf, (TiledCSC, BlockCSR)):
            continue
        if leaf.lead:
            flat_v = leaf.vals.reshape((-1,) + leaf.vals.shape[-4:])
            flat_r = leaf.rows.reshape((-1,) + leaf.rows.shape[-4:])
            leaf = TiledCSC(flat_v[0], flat_r[0], leaf.shape, leaf.tile)
        key = registry.problem_key(leaf, m=16, backend="cpu")
        if cache.get(key) is not None:
            hits += 1
        else:
            misses += 1
    assert hits >= 1 and misses == 0
    # idempotent: a second warmup is all cache hits
    stats2 = planner.warmup_plan(plan, (16,), backend="cpu", cache=cache)
    assert stats2["tuned"] == 0 and stats2["cached"] >= 1


def test_plan_hint_seeds_cold_cache_but_never_overrides_tuned(tmp_path):
    """dispatch_params were recorded at one M; a winner measured at the
    actual (layout, M) must win over them."""
    w = pruning.random_sparse(KEY, (256, 256), 0.3)
    p = sod.pack_param(w, SoDConfig(mode="tiled_csc", density=1.0))
    entry = PackPlan(mode="tiled_csc", shape=(256, 256), cap=p.cap,
                     dispatch_params={"bm": 8}, dtype=str(p.dtype))
    mp = ModelPlan({".w": entry})
    x = jax.random.normal(KEY, (16, 256), jnp.float32)
    cache = autotune.TuningCache(tmp_path / "cache.json")
    autotune.set_cache(cache)
    try:
        # cold cache (interpret backend → pallas_fused, which takes bm):
        # the hint seeds dispatch
        with plan_mod.use_plan(mp), registry.record_dispatches() as log:
            sod.apply(x, p, backend="interpret")
        assert log[-1]["impl"] == "pallas_fused"
        assert log[-1]["params"].get("bm") == 8
        # measured winner at this (layout, M): the hint must not override
        key = registry.problem_key(p, m=16, backend="interpret")
        cache.put(key, "pallas_fused", {"bm": 128}, us=1.0)
        with plan_mod.use_plan(mp), registry.record_dispatches() as log2:
            sod.apply(x, p, backend="interpret")
        assert log2[-1]["source"] == "tuned"
        assert log2[-1]["params"].get("bm") == 128
    finally:
        autotune.set_cache(None)


def test_build_plan_reads_tuned_winner_params(tmp_path):
    """A measured tuning-cache entry's params ride into the plan's dispatch
    hint (the tuning-cache → sodify_params feedback loop)."""
    sod_cfg = SoDConfig(mode="tiled_csc", density=0.3, min_dim=64)
    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(sod=sod_cfg)
    params = build_model(cfg).init(KEY)
    cache = autotune.TuningCache(tmp_path / "tuned.json")
    cold = planner.build_plan(params, sod_cfg, cfg=cfg, cache=cache,
                              m_values=(16,))
    assert all(not e.dispatch_params for e in cold.entries.values())
    planner.warmup_plan(cold, (16,), backend=registry.current_backend(),
                        cache=cache)
    warm = planner.build_plan(params, sod_cfg, cfg=cfg, cache=cache,
                              m_values=(16,))
    tuned_notes = [e.note for e in warm.entries.values()
                   if e.mode != "dense"]
    assert tuned_notes and all(n.startswith("tuned:") for n in tuned_notes)


# ---------------------------------------------------------------------------
# regression: stacked-leaf nm pruning (sodify_params used to silently run
# block_prune for prune_method="nm")
# ---------------------------------------------------------------------------
def test_sodify_params_stacked_nm_prune_matches_pack_param():
    w = jax.random.normal(KEY, (2, 128, 128), jnp.float32)
    sod_cfg = SoDConfig(mode="tiled_csc", density=0.5, prune_method="nm",
                        min_dim=64)
    packed = sodify_params({"w_down": w}, sod_cfg)["w_down"]
    assert isinstance(packed, TiledCSC) and packed.lead == (2,)
    per_slice = [sod.pack_param(w[i], sod_cfg) for i in range(2)]
    cap = max(p.cap for p in per_slice)
    for i in range(2):
        expect = sod.pack_param(w[i], sod_cfg).to_dense()
        got = TiledCSC(packed.vals[i], packed.rows[i], packed.shape,
                       packed.tile).to_dense()
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
    # nm result must differ from what the old silent block_prune fallthrough
    # produced
    block_cfg = dataclasses.replace(sod_cfg, prune_method="block")
    old = sodify_params({"w_down": w}, block_cfg)["w_down"]
    assert not np.array_equal(np.asarray(packed.to_dense()),
                              np.asarray(old.to_dense()))
    assert cap <= 64  # 4:8 structured pruning halves every column


def test_plan_dense_fallback_layers_are_still_pruned():
    """A mode='dense' entry chooses the storage format, not whether the
    layer is sparse: the weight must come back pruned, matching what the
    global-config pack applies before storing."""
    w = jax.random.normal(KEY, (128, 128), jnp.float32)
    entry = PackPlan(mode="dense", shape=(128, 128), density=0.4,
                     prune_method="magnitude", dtype="float32")
    plan = ModelPlan({".w_down": entry})
    out = sodify_params({"w_down": w}, SoDConfig(mode="tiled_csc",
                                                 density=0.4, min_dim=64),
                        plan=plan)["w_down"]
    assert isinstance(out, jax.Array)
    nnz = int(jnp.count_nonzero(out))
    assert nnz == pytest.approx(0.4 * w.size, rel=0.05)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(pruning.magnitude_prune(w, 0.4)))
    # prune=False replays the raw weight
    raw = sodify_params({"w_down": w}, SoDConfig(mode="tiled_csc",
                                                 density=0.4, min_dim=64),
                        prune=False, plan=plan)["w_down"]
    np.testing.assert_array_equal(np.asarray(raw), np.asarray(w))


def test_plan_cap_truncation_warns():
    """Replaying a plan whose cap budget underestimates the data must warn,
    never silently drop weights."""
    # all non-zeros concentrated in the first 32 rows → per-column nnz 32
    w = jnp.zeros((128, 128)).at[:32, :].set(1.0)
    entry = PackPlan(mode="tiled_csc", shape=(128, 128), density=1.0,
                     cap=8, dtype="float32")
    plan = ModelPlan({".w_down": entry})
    cfg = SoDConfig(mode="tiled_csc", density=1.0, min_dim=64)
    with pytest.warns(UserWarning, match="truncated"):
        packed = sodify_params({"w_down": w}, cfg, plan=plan)["w_down"]
    assert packed.cap == 8
    # a sufficient cap replays losslessly with no warning
    import warnings

    ok = ModelPlan({".w_down": dataclasses.replace(entry, cap=32)})
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        packed = sodify_params({"w_down": w}, cfg, plan=ok)["w_down"]
    np.testing.assert_array_equal(np.asarray(packed.to_dense()),
                                  np.asarray(w))


def test_block_csr_explicit_bcap_clamps_tile_nnz():
    """With a plan-provided bcap that truncates, tile_nnz must count the
    stored sub-blocks, not the pre-truncation ones."""
    from repro.core.formats import pack_block_csr

    w = jnp.ones((128, 128))
    p = pack_block_csr(w, tile=(128, 128), br=8, bcap=4)
    assert p.bcap == 4
    assert int(jnp.max(p.tile_nnz)) == 4


def test_block_csr_lossy_bcap_keeps_largest_norm_blocks():
    """ESE-style load capping: truncation drops the smallest-norm
    sub-blocks, not the highest-index ones."""
    from repro.core.formats import pack_block_csr

    # block i (rows 8i..8i+8) filled with value i+1 → norm grows with index
    w = jnp.repeat(jnp.arange(1, 17, dtype=jnp.float32), 8)[:, None] \
        * jnp.ones((1, 128))
    p = pack_block_csr(w, tile=(128, 128), br=8, bcap=4)
    kept = sorted(int(i) for i in np.asarray(p.block_ids).reshape(-1))
    assert kept == [12, 13, 14, 15]
    # lossless bcap keeps the canonical ascending-index layout
    full = pack_block_csr(w, tile=(128, 128), br=8)
    assert list(np.asarray(full.block_ids).reshape(-1)) == list(range(16))
    np.testing.assert_array_equal(np.asarray(full.to_dense()),
                                  np.asarray(w))


def test_drivers_reject_plan_without_sod():
    from repro.launch import serve, train

    with pytest.raises(SystemExit):
        serve.main(["--arch", "llama3.2-1b", "--reduced", "--plan", "auto"])
    with pytest.raises(SystemExit):
        train.main(["--arch", "llama3.2-1b", "--reduced", "--plan", "auto"])


def test_prune_weight_unknown_method_raises():
    w = jnp.ones((128, 128))
    with pytest.raises(ValueError, match="unknown prune method"):
        sod.prune_weight(w, 0.5, "typo")
    bad = SoDConfig(mode="tiled_csc", density=0.5, prune_method="typo",
                    min_dim=64)
    with pytest.raises(ValueError, match="unknown prune method"):
        sodify_params({"w_down": jnp.ones((2, 128, 128))}, bad)


# ---------------------------------------------------------------------------
# legacy (no-plan) abstract bcap now tracks the data-dependent pack
# ---------------------------------------------------------------------------
def test_noplan_abstract_block_bcap_matches_concrete_magnitude():
    """Element-granular pruning keeps ~every sub-block alive; the abstract
    bcap must say nb (it used to say ~1.5·density·nb and diverge)."""
    sod_cfg = SoDConfig(mode="block_csr", density=0.3, min_dim=64)
    w = pruning.random_sparse(KEY, (256, 256), 0.9)  # pre-prune dense-ish
    concrete = sodify_params({"w_down": w}, sod_cfg)["w_down"]
    abstract = sodify_abstract(
        {"w_down": jax.ShapeDtypeStruct((256, 256), jnp.float32)},
        sod_cfg)["w_down"]
    assert abstract.bcap == concrete.bcap == 16  # nb = 128 // 8
