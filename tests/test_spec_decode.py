"""Sparsity-tiered speculative decoding: draft-tier planning, k-token
propose/verify/accept windows, rejected-page rollback accounting, and
bit-identity of accepted tokens with the non-speculative greedy
reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.sod import SoDConfig, sodify_params
from repro.models import attention as attn
from repro.models.model import build_model
from repro.models.transformer import attn_spec
from repro.serving import Engine, Request, poisson_trace, static_generate

KEY = jax.random.PRNGKey(0)


def _llama(sod=False):
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    if sod:
        cfg = cfg.with_(sod=SoDConfig(mode="tiled_csc", density=0.4,
                                      min_dim=64))
    model = build_model(cfg)
    return cfg, model, model.init(KEY)


# ---------------------------------------------------------------------------
# verify attention: bitwise the sequential decode path, batched over C
# ---------------------------------------------------------------------------
def test_paged_verify_matches_sequential_decode():
    """Row i of a C-position verify pass must be bit-equal to the i-th
    sequential paged decode step — the engine's accept rule (and hence
    output identity with non-speculative greedy) rests on exactly this."""
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    spec = attn_spec(cfg)
    params = attn.init_attention(KEY, cfg.d_model, spec)
    b, page, n_logical, c = 2, 4, 4, 3
    n_pages = 1 + b * n_logical
    pool_a = attn.init_paged_pool(n_pages, page, spec)
    kshape = pool_a["k"].shape
    pool_a = {
        "k": jax.random.normal(jax.random.PRNGKey(1), kshape, jnp.bfloat16),
        "v": jax.random.normal(jax.random.PRNGKey(2), kshape, jnp.bfloat16),
    }
    pool_b = dict(pool_a)
    tables = jnp.asarray([[3, 5, 1, 7], [6, 2, 4, 8]], jnp.int32)
    start = jnp.asarray([5, 9], jnp.int32)
    x = jax.random.normal(jax.random.PRNGKey(3), (b, c, cfg.d_model),
                          jnp.bfloat16)

    seq_outs = []
    for i in range(c):
        o, pool_a = attn.paged_decode_attention(
            params, x[:, i:i + 1], pool_a, tables, start + i, spec)
        seq_outs.append(np.asarray(o[:, 0]))

    o_v, pool_b = attn.paged_verify_attention(
        params, x, pool_b, tables, start, jnp.full((b,), 64, jnp.int32),
        spec)
    for i in range(c):
        np.testing.assert_array_equal(np.asarray(o_v[:, i]), seq_outs[i])
    np.testing.assert_array_equal(np.asarray(pool_a["k"]),
                                  np.asarray(pool_b["k"]))
    np.testing.assert_array_equal(np.asarray(pool_a["v"]),
                                  np.asarray(pool_b["v"]))


def test_paged_verify_valid_len_redirects_overflow():
    """Positions at or past ``valid_len`` must scatter to the trash page,
    never into a live page."""
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    spec = attn_spec(cfg)
    params = attn.init_attention(KEY, cfg.d_model, spec)
    b, page, c = 1, 4, 3
    pool = attn.init_paged_pool(4, page, spec)
    live = np.asarray(pool["k"][1:]).copy()
    tables = jnp.asarray([[1, 2, 3]], jnp.int32)
    x = jax.random.normal(KEY, (b, c, cfg.d_model), jnp.bfloat16)
    # start=6, valid_len=7: row 0 writes live, rows 1-2 overflow
    _, pool = attn.paged_verify_attention(
        params, x, pool, tables, jnp.asarray([6], jnp.int32),
        jnp.asarray([7], jnp.int32), spec)
    after = np.asarray(pool["k"][1:])
    changed = np.argwhere(np.any(live != after, axis=tuple(
        range(1, after.ndim))))
    # only page index 1 of the live slice (= page id 2, holding pos 6)
    assert changed.tolist() == [[1]]


# ---------------------------------------------------------------------------
# engine: accepted tokens == non-speculative greedy, across window sizes
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("k", [1, 2, 4])
def test_spec_engine_matches_static_serve(k):
    cfg, model, params = _llama()
    trace = poisson_trace(4, 0.7, max_prompt=10, max_new=6,
                          vocab=cfg.vocab, seed=3)
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=24,
                 spec_k=k, draft_params=params)
    res = eng.run(trace)
    s = res["stats"]
    assert s["completed"] == len(trace)
    for req in trace:
        ref = static_generate(model, params, req)
        assert res["tokens"][req.rid] == ref, f"rid {req.rid}"
    assert s["spec_windows"] > 0
    assert s["draft_proposed"] == s["spec_windows"] * k
    assert 0 <= s["draft_accepted"] <= s["draft_proposed"]
    # every page back after per-window grow/trim cycles
    assert eng.page_pool.free_count == eng.page_pool.n_pages - 1
    assert not eng.page_pool.allocated


def test_spec_self_draft_accepts_full_windows():
    """Window-aligned budgets (6 decode tokens = two full k=2 windows) and
    a self-draft: every proposal must be accepted — the draft pool holds
    bit-exact KV for all committed positions, including the bonus token's
    position a full acceptance commits."""
    cfg, model, params = _llama()
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i, tokens=rng.integers(1, cfg.vocab, size=9),
                    max_new=7, arrival=0) for i in range(3)]
    eng = Engine(model, params, max_slots=2, page_size=8, max_len=40,
                 spec_k=2, draft_params=params)
    res = eng.run(reqs)
    s = res["stats"]
    assert s["acceptance_rate"] == 1.0
    assert s["tokens_per_step"] > 1
    assert s["steps"] < s["generated_tokens"]
    for req in reqs:
        assert res["tokens"][req.rid] == static_generate(model, params, req)


def test_spec_junk_draft_rollback_keeps_identity():
    """A draft from different random weights proposes near-pure garbage:
    heavy per-window rejection and page rollback, yet accepted tokens
    stay bit-identical and the pool drains clean."""
    cfg, model, params = _llama()
    junk = model.init(jax.random.PRNGKey(7))
    trace = poisson_trace(4, 0.7, max_prompt=10, max_new=6,
                          vocab=cfg.vocab, seed=3)
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=24,
                 spec_k=4, draft_params=junk)
    res = eng.run(trace)
    s = res["stats"]
    assert s["completed"] == len(trace)
    assert s["acceptance_rate"] < 0.5
    for req in trace:
        assert res["tokens"][req.rid] == static_generate(model, params, req)
    assert eng.page_pool.free_count == eng.page_pool.n_pages - 1
    assert not eng.page_pool.allocated


def test_spec_sod_tiers_match_static(monkeypatch, tmp_path):
    """Both tiers planner-packed (target at 0.4, draft chosen by the cost
    model): accepted tokens identical to the packed static reference."""
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "tc.json"))
    from repro.runtime import planner

    cfg, model, raw = _llama(sod=True)
    plan = planner.load_or_build("auto", raw, cfg.sod, cfg=cfg,
                                 m_values=(8, 1))
    draft_cfg, draft_plan = planner.build_draft_plan(
        raw, cfg.sod, spec_k=2, cfg=cfg, m_values=(8, 1))
    draft_params = sodify_params(raw, draft_cfg, plan=draft_plan)
    params = sodify_params(raw, cfg.sod, plan=plan)
    trace = poisson_trace(3, 0.7, max_prompt=10, max_new=5,
                          vocab=cfg.vocab, seed=3)
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=24,
                 plan=plan, spec_k=2, draft_params=draft_params,
                 draft_plan=draft_plan)
    res = eng.run(trace)
    assert res["stats"]["completed"] == len(trace)
    for req in trace:
        ref = static_generate(model, params, req, plan=plan)
        assert res["tokens"][req.rid] == ref, f"rid {req.rid}"
    assert not eng.page_pool.allocated


def test_spec_defaults_off_zero_counters():
    """``spec_k=0`` takes the legacy decode path: spec counters stay 0,
    the derived rates report 0/neutral, and no draft state exists."""
    cfg, model, params = _llama()
    trace = poisson_trace(2, 0.6, max_prompt=8, max_new=4,
                          vocab=cfg.vocab, seed=1)
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=16)
    assert not hasattr(eng, "draft_pool")
    res = eng.run(trace)
    s = res["stats"]
    assert s["spec_windows"] == 0 and s["draft_proposed"] == 0
    assert s["draft_accepted"] == 0 and s["acceptance_rate"] == 0.0
    assert s["tokens_per_step"] > 0
    for req in trace:
        assert res["tokens"][req.rid] == static_generate(model, params, req)


# ---------------------------------------------------------------------------
# composed phases: spec decode × chunked prefill × preemption × sharing
# ---------------------------------------------------------------------------
def test_spec_with_chunked_prefill_matches_static():
    """spec_k + prefill_chunk compose: draft windows start only once a
    slot finishes its chunk schedule, draft prompt KV is laid down chunk
    by chunk, and accepted tokens stay bit-identical to the greedy
    reference."""
    cfg, model, params = _llama()
    trace = poisson_trace(4, 0.7, max_prompt=10, max_new=6,
                          vocab=cfg.vocab, seed=3)
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=24,
                 spec_k=2, draft_params=params, prefill_chunk=4)
    res = eng.run(trace)
    s = res["stats"]
    assert s["completed"] == len(trace)
    assert s["spec_windows"] > 0 and s["prefill_chunks"] > 0
    for req in trace:
        assert res["tokens"][req.rid] == static_generate(model, params, req)
    assert eng.page_pool.free_count == eng.page_pool.n_pages - 1
    assert not eng.page_pool.allocated


def test_spec_with_preemption_trims_window_pages():
    """spec_k + preemption on a starved pool: a victim holding
    speculatively grown pages has them *trimmed* (rolled back), never
    swapped — host KV round-trips only committed positions — and every
    request still matches the reference bit-for-bit."""
    cfg, model, params = _llama()
    rng = np.random.default_rng(11)
    reqs = [Request(rid=i, tokens=rng.integers(1, cfg.vocab, size=9),
                    max_new=8, arrival=0) for i in range(4)]
    # lifetime = pages_for(9 + 8 - 1) = 4 pages/seq; 7 usable pages
    # cannot hold two full sequences, so capacity-phase growth must
    # preempt while spec windows are in flight.
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=20,
                 n_pages=8, spec_k=2, draft_params=params, preemption=True)
    res = eng.run(reqs)
    s = res["stats"]
    assert s["completed"] == len(reqs)
    assert s["preemptions"] >= 1 and s["spec_windows"] > 0
    for req in reqs:
        assert res["tokens"][req.rid] == static_generate(model, params, req)
    assert eng.page_pool.free_count == eng.page_pool.n_pages - 1
    assert not eng.page_pool.allocated


def test_all_features_composed_matches_static():
    """The full composition — spec decode, chunked prefill, preemption,
    prefix sharing — on a bursty shared-prefix trace against a starved
    pool, with a cold (independently initialized) draft whose proposals
    almost all reject: preemptions land mid-window, rejected pages roll
    back while refcounted prefix pages stay trie-mapped, and the output
    is still bit-identical with the pool *and* trie draining clean."""
    from repro.serving import stress_spec_trace

    cfg, model, params = _llama()
    cold_draft = model.init(jax.random.PRNGKey(7))
    trace = stress_spec_trace(6, prefix_len=8, max_prompt=14, max_new=8,
                              vocab=cfg.vocab, seed=0, burst=2, rate=0.3)
    eng = Engine(model, params, max_slots=3, page_size=4, max_len=24,
                 n_pages=10, spec_k=2, draft_params=cold_draft,
                 prefill_chunk=4, preemption=True, prefix_sharing=True)
    res = eng.run(trace)
    s = res["stats"]
    assert s["completed"] == len(trace)
    assert s["spec_windows"] > 0 and s["prefill_chunks"] > 0
    assert s["shared_prompt_pages"] >= 1
    assert s["preemptions"] >= 1
    assert s["spec_window_preemptions"] >= 1   # trim-not-swap path ran
    assert s["spec_rollbacks"] >= 1
    for req in trace:
        assert res["tokens"][req.rid] == static_generate(model, params, req)
    assert eng.page_pool.free_count == eng.page_pool.n_pages - 1
    assert not eng.page_pool.allocated
    assert len(eng.trie) == 0


def test_spec_validation_errors():
    cfg, model, params = _llama()
    with pytest.raises(ValueError, match="draft_params"):
        Engine(model, params, max_len=16, spec_k=2)
    hybrid = build_model(configs.reduced(configs.get_config("zamba2-2.7b")))
    with pytest.raises(ValueError, match="paged KV"):
        Engine(hybrid, {}, max_len=16, spec_k=2, draft_params={})


# ---------------------------------------------------------------------------
# planner: draft-tier plan + cost-model density choice
# ---------------------------------------------------------------------------
def test_draft_plan_cost_model(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "tc.json"))
    from repro.runtime import planner

    cfg, model, params = _llama(sod=True)
    plan = planner.load_or_build("auto", params, cfg.sod, cfg=cfg,
                                 m_values=(8, 1))
    d, diag = planner.choose_draft_density(params, cfg.sod, spec_k=4,
                                           cfg=cfg, m_values=(8, 1))
    assert d in planner.DRAFT_DENSITY_LADDER
    assert diag["chosen"] == d
    assert len(diag["candidates"]) == len(planner.DRAFT_DENSITY_LADDER)
    draft_cfg, draft_plan = planner.build_draft_plan(
        params, cfg.sod, spec_k=4, cfg=cfg, m_values=(8, 1))
    assert draft_cfg.density == d
    assert draft_plan.compressed_bytes() < plan.compressed_bytes()
    assert draft_plan.meta["tier"] == "draft"
    assert draft_plan.meta["spec_k"] == 4
    assert draft_plan.meta["density_choice"]["chosen"] == d


def test_draft_qmode_codebook_beats_fp_at_equal_density(monkeypatch,
                                                        tmp_path):
    """Quantizing the draft tier's value storage enters the cost model:
    at every candidate density a codebook draft stores fewer bytes than
    the fp draft, so its cost ratio is strictly lower and its
    tokens-per-cost strictly higher — and the chosen optimum can only
    move toward *denser* (higher-acceptance) tiers, never sparser."""
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "tc.json"))
    from repro.runtime import planner

    cfg, model, params = _llama(sod=True)
    d_fp, diag_fp = planner.choose_draft_density(
        params, cfg.sod, spec_k=4, cfg=cfg, m_values=(8, 1))
    d_cb, diag_cb = planner.choose_draft_density(
        params, cfg.sod, spec_k=4, cfg=cfg, m_values=(8, 1),
        draft_qmode="codebook")
    assert "draft_qmode" not in diag_fp
    assert diag_cb["draft_qmode"] == "codebook"
    for key, fp in diag_fp["candidates"].items():
        cb = diag_cb["candidates"][key]
        assert cb["cost_ratio"] < fp["cost_ratio"], key
        assert cb["tokens_per_cost"] > fp["tokens_per_cost"], key
    assert d_cb >= d_fp

    # end-to-end: the built plan carries the quantized value storage
    draft_cfg, draft_plan = planner.build_draft_plan(
        params, cfg.sod, spec_k=4, cfg=cfg, m_values=(8, 1),
        draft_qmode="codebook")
    assert draft_cfg.qmode == "codebook"
    assert draft_cfg.density == d_cb
    assert draft_plan.meta["density_choice"]["draft_qmode"] == "codebook"


def test_draft_plan_over_dense_target(monkeypatch, tmp_path):
    """A dense (unpacked) target still gets a packed draft tier — the
    draft SoDConfig is synthesized from scratch."""
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "tc.json"))
    from repro.core.sod import DENSE
    from repro.runtime import planner

    cfg, model, params = _llama()
    draft_cfg, draft_plan = planner.build_draft_plan(
        params, DENSE, draft_density=0.2, cfg=cfg, m_values=(8, 1))
    assert draft_cfg.enabled and draft_cfg.density == 0.2
    assert len(draft_plan) >= 1
    # no cost-model diagnostics when the density was pinned explicitly
    assert "density_choice" not in draft_plan.meta


# ---------------------------------------------------------------------------
# serve driver
# ---------------------------------------------------------------------------
def test_serve_spec_decode_end_to_end(monkeypatch, tmp_path):
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "tc.json"))
    from repro.launch import serve

    summary = serve.main([
        "--arch", "llama3.2-1b", "--reduced", "--engine",
        "--requests", "3", "--prompt-len", "6", "--gen", "4",
        "--max-slots", "2", "--page-size", "4",
        "--spec-decode", "2", "--draft-sparsity", "0.5"])
    assert summary["spec_decode"] == 2
    assert summary["completed"] == 3
    assert summary["spec_windows"] > 0
    assert "acceptance_rate" in summary and "tokens_per_step" in summary
    assert summary["draft_bytes"] > 0


def test_serve_spec_decode_flag_validation(capsys):
    from repro.launch import serve

    with pytest.raises(SystemExit):
        serve.main(["--arch", "llama3.2-1b", "--reduced",
                    "--spec-decode", "2"])
    assert "--engine" in capsys.readouterr().err
