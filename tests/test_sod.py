"""Sparse-on-Dense end-to-end: packed model ≡ dense pruned model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import sod
from repro.core.formats import BlockCSR, TiledCSC
from repro.core.sod import SoDConfig, sodify_params, sodify_abstract
from repro.data.pipeline import SyntheticLMData
from repro.models.model import build_model

KEY = jax.random.PRNGKey(0)


def _model_and_batch(arch="llama3.2-1b"):
    cfg = configs.reduced(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    batch = SyntheticLMData(cfg, 2, 64, seed=0).batch(0)
    return cfg, model, params, batch


def test_packed_equals_dense_pruned_at_density_one():
    """With density=1.0 the packed model must match the dense model exactly
    (lossless compression of the same weights)."""
    cfg, model, params, batch = _model_and_batch()
    sod_cfg = SoDConfig(mode="tiled_csc", density=1.0, min_dim=64)
    packed = sodify_params(params, sod_cfg, prune=False)
    n_packed = sum(isinstance(l, TiledCSC) for l in
                   jax.tree_util.tree_leaves(
                       packed, is_leaf=lambda x: isinstance(x, TiledCSC)))
    assert n_packed >= 4
    l_dense, _ = model.loss(params, batch)
    l_packed, _ = model.loss(packed, batch)
    assert float(l_dense) == pytest.approx(float(l_packed), abs=2e-2)


def test_packed_matches_mask_applied_dense():
    """Prune-then-pack ≡ prune-then-run-dense (the compression is exact)."""
    from repro.core import pruning

    cfg, model, params, batch = _model_and_batch()
    sod_cfg = SoDConfig(mode="tiled_csc", density=0.4, min_dim=64)
    packed = sodify_params(params, sod_cfg)
    # manually prune the same leaves and keep dense
    dense_pruned = jax.tree_util.tree_map(
        lambda l: l, params)
    flat, treedef = sod._flatten_named(params)
    out = []
    for name, leaf in flat:
        if sod._packable(name, leaf) and min(leaf.shape[-2:]) >= 64:
            mat = leaf.reshape((-1,) + leaf.shape[-2:])
            mat = jnp.stack([pruning.magnitude_prune(mat[i], 0.4)
                             for i in range(mat.shape[0])])
            out.append(mat.reshape(leaf.shape))
        else:
            out.append(leaf)
    dense_pruned = jax.tree_util.tree_unflatten(treedef, out)
    l_packed, _ = model.loss(packed, batch)
    l_dense, _ = model.loss(dense_pruned, batch)
    assert float(l_packed) == pytest.approx(float(l_dense), abs=2e-2)


def test_block_csr_mode_runs():
    cfg, model, params, batch = _model_and_batch()
    sod_cfg = SoDConfig(mode="block_csr", density=0.5, prune_method="block",
                        min_dim=64)
    packed = sodify_params(params, sod_cfg)
    n_packed = sum(isinstance(l, BlockCSR) for l in
                   jax.tree_util.tree_leaves(
                       packed, is_leaf=lambda x: isinstance(x, BlockCSR)))
    assert n_packed >= 4
    loss, _ = model.loss(packed, batch)
    assert np.isfinite(float(loss))


def test_sodify_abstract_matches_concrete_shapes():
    """Dry-run abstract packing must predict the concrete packed shapes
    (same treedef; concrete cap ≤ abstract budget)."""
    cfg, model, params, _ = _model_and_batch()
    sod_cfg = SoDConfig(mode="tiled_csc", density=0.3, min_dim=64)
    concrete = sodify_params(params, sod_cfg)
    abstract = sodify_abstract(
        jax.eval_shape(lambda: model.init(KEY)), sod_cfg)
    ct = jax.tree_util.tree_structure(concrete)
    at = jax.tree_util.tree_structure(abstract)
    assert ct == at
    for c, a in zip(
            jax.tree_util.tree_leaves(
                concrete, is_leaf=lambda x: isinstance(x, TiledCSC)),
            jax.tree_util.tree_leaves(
                abstract, is_leaf=lambda x: isinstance(x, TiledCSC))):
        if isinstance(c, TiledCSC):
            assert c.vals.shape[:-2] == a.vals.shape[:-2]
            assert c.cap <= a.cap + 16   # binomial budget holds


def test_weight_bytes_accounting():
    """At production matrix sizes compression ≈ 1.5·density + cap tail; toy
    128-dim matrices pay tile-padding overhead (documented)."""
    from repro.core import pruning
    from repro.core.formats import pack_tiled_csc

    w = pruning.random_sparse(KEY, (2048, 2048), 0.25)
    p = pack_tiled_csc(w)
    ratio = p.nbytes_compressed() / p.nbytes_dense()
    assert 0.25 * 1.5 * 0.8 < ratio < 0.25 * 1.5 * 1.9
    # tree-level accounting is consistent
    stats = sod.tree_weight_bytes({"w_down": p})
    assert stats["compressed"] == p.nbytes_compressed()
    assert stats["compressed"] < stats["dense"]


def test_fixed_mask_training_decreases_loss():
    """A few steps of training on the packed model reduce loss; mask fixed."""
    from repro.launch.steps import make_train_step
    from repro.optim import AdamW, AdamWConfig

    cfg, model, params, batch = _model_and_batch()
    packed = sodify_params(params, SoDConfig(mode="tiled_csc", density=0.5,
                                             min_dim=64))
    mask0 = np.asarray(jax.tree_util.tree_leaves(
        packed, is_leaf=lambda x: isinstance(x, TiledCSC))[0].rows)
    opt = AdamW(AdamWConfig(lr=5e-3))
    state = opt.init(packed)
    step = jax.jit(make_train_step(model, opt))
    losses = []
    p = packed
    for i in range(8):
        p, state, metrics = step(p, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
    mask1 = np.asarray(jax.tree_util.tree_leaves(
        p, is_leaf=lambda x: isinstance(x, TiledCSC))[0].rows)
    np.testing.assert_array_equal(mask0, mask1)
