"""Data pipeline: determinism, host slicing, learnable distribution."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import SyntheticLMData


def _cfg():
    return configs.reduced(configs.get_config("llama3.2-1b"))


def test_batch_is_pure_function_of_step():
    d1 = SyntheticLMData(_cfg(), 4, 32, seed=1)
    d2 = SyntheticLMData(_cfg(), 4, 32, seed=1)
    b1, b2 = d1.batch(17), d2.batch(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d1.batch(18)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_targets_are_shifted_tokens():
    d = SyntheticLMData(_cfg(), 2, 16, seed=0)
    b = d.batch(0)
    assert b["tokens"].shape == (2, 16)
    assert b["targets"].shape == (2, 16)


def test_host_slice_partitions_batch():
    d = SyntheticLMData(_cfg(), 8, 16, seed=0)
    b = d.batch(0)
    parts = [d.host_slice(b, h, 4) for h in range(4)]
    recon = np.concatenate([np.asarray(p["tokens"]) for p in parts])
    np.testing.assert_array_equal(recon, np.asarray(b["tokens"]))


def test_bigram_chain_is_learnable():
    """Successor entropy is far below uniform — a model can make progress."""
    cfg = _cfg()
    d = SyntheticLMData(cfg, 8, 256, seed=0, branching=4)
    b = d.batch(0)
    toks = np.asarray(b["tokens"])
    # each token has at most `branching` successors in the chain (per row —
    # row boundaries are not transitions)
    succ = {}
    for row in toks:
        for a, c in zip(row[:-1], row[1:]):
            succ.setdefault(int(a), set()).add(int(c))
    max_succ = max(len(v) for v in succ.values())
    assert max_succ <= 4


def test_vlm_and_audio_batches():
    cfg = configs.reduced(configs.get_config("pixtral-12b"))
    d = SyntheticLMData(cfg, 2, 32, seed=0)
    b = d.batch(0)
    assert b["patch_embeds"].shape == (2, cfg.n_patches, cfg.frontend_dim)
    assert b["tokens"].shape == (2, 32 - cfg.n_patches)

    cfg = configs.reduced(configs.get_config("musicgen-medium"))
    b = SyntheticLMData(cfg, 2, 32, seed=0).batch(0)
    assert b["tokens"].shape == (2, 32, cfg.n_codebooks)
