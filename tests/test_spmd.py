"""SPMD execution layer on a fake 8-device CPU mesh.

The bulk of this module needs 8 jax devices and therefore runs in CI's
``spmd-tier`` job (``XLA_FLAGS=--xla_force_host_platform_device_count=8``
exported before pytest starts); without forced devices the mesh-dependent
tests skip.  One subprocess-isolated acceptance smoke always runs, so plain
tier-1 still proves the headline behaviour: a pjit-sharded ``sod_matmul``
dispatches a shard_map-wrapped Pallas impl (not the XLA oracle) and its
``jax.grad`` matches the dense reference.
"""
import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pruning
from repro.core.formats import BlockCSR, TiledCSC, pack_block_csr, \
    pack_tiled_csc
from repro.kernels import autotune, ops, ref, registry
from repro.runtime import spmd

KEY = jax.random.PRNGKey(11)

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(the CI spmd-tier job sets it)")


def _mesh():
    from repro.launch.mesh import make_fake_mesh

    return make_fake_mesh()


def _packed(shape=(256, 512), density=0.3, fmt="tiled_csc", seed=0):
    w = pruning.random_sparse(jax.random.fold_in(KEY, seed), shape, density)
    if fmt == "block_csr":
        w = pruning.block_prune(w, density)
        return w, pack_block_csr(w)
    return w, pack_tiled_csc(w)


@pytest.fixture
def interpret_backend():
    registry.set_backend_override("interpret")
    yield
    registry.set_backend_override(None)


# ---------------------------------------------------------------------------
# plan derivation / mesh keys
# ---------------------------------------------------------------------------
@needs_mesh
def test_auto_plan_shards_batch_and_columns():
    mesh = _mesh()
    _, p = _packed()                       # Nt = 4, divisible by model=2
    plan = spmd.auto_plan(mesh, p)
    assert plan.batch_axes == ("data",)
    assert plan.col_axis == "model"
    _, p_thin = _packed((256, 128))        # Nt = 1: no column sharding
    assert spmd.auto_plan(mesh, p_thin).col_axis is None


@needs_mesh
def test_mesh_key_in_tuning_cache_key():
    mesh = _mesh()
    _, p = _packed()
    plan = spmd.auto_plan(mesh, p)
    sig = f"{spmd.mesh_key(mesh)}|{plan.signature()}"
    local = spmd._local_packed(p, mesh, plan)
    key = registry.problem_key(local, m=16, backend="interpret", mesh=sig)
    s = autotune.key_str(key)
    assert "mesh=data=4,model=2" in s
    # same local problem without the mesh must land on a different entry
    key_plain = registry.problem_key(local, m=16, backend="interpret")
    assert autotune.key_str(key_plain) != s


@needs_mesh
def test_tuned_local_shard_entry_feeds_mesh_dispatch(tmp_path):
    """Per-local-shard tune() → the shard_map body's lookup hits it."""
    mesh = _mesh()
    _, p = _packed()
    plan = spmd.auto_plan(mesh, p)
    sig = f"{spmd.mesh_key(mesh)}|{plan.signature()}"
    local = spmd._local_packed(p, mesh, plan)
    cache = autotune.TuningCache(tmp_path / "cache.json")
    autotune.set_cache(cache)
    try:
        x_l = jax.random.normal(KEY, (12, 256))
        entry = autotune.tune(x_l, local, backend="interpret", mesh=sig,
                              cache=cache, measure_fn=lambda fn: 1.0)
        assert entry["impl"] == "pallas_fused"
        key = registry.problem_key(local, m=12, backend="interpret",
                                   mesh=sig)
        assert autotune.lookup(key) == entry
    finally:
        autotune.set_cache(None)


@needs_mesh
def test_warmup_params_spmd_counts_local_layouts(tmp_path):
    mesh = _mesh()
    _, p1 = _packed((256, 512), seed=1)
    _, p2 = _packed((256, 512), seed=2)    # same layout as p1 → one entry
    _, p3 = _packed((128, 256), seed=3)
    cache = autotune.TuningCache(tmp_path / "warm.json")
    stats = spmd.warmup_params_spmd(
        {"a": p1, "b": p2, "c": p3, "dense": jnp.zeros((4,))},
        (48,), mesh, backend="cpu", cache=cache)
    assert stats["tuned"] == 2
    stats2 = spmd.warmup_params_spmd(
        {"a": p1, "c": p3}, (48,), mesh, backend="cpu", cache=cache)
    assert stats2 == {"tuned": 0, "cached": 2, "skipped": 0}


# ---------------------------------------------------------------------------
# forward + grad correctness per plan
# ---------------------------------------------------------------------------
def _grads_vs_oracle(fn, x, p, fn_ref):
    g = jax.grad(lambda x, p: (fn(x, p) ** 2).sum(),
                 argnums=(0, 1), allow_int=True)(x, p)
    g_ref = jax.grad(lambda x, p: (fn_ref(x, p) ** 2).sum(),
                     argnums=(0, 1), allow_int=True)(x, p)
    return g, g_ref


@needs_mesh
@pytest.mark.parametrize("plan_kw,shape", [
    ({"batch_axes": ("data",)}, (300, 512)),
    ({"batch_axes": ("data",), "col_axis": "model"}, (300, 512)),
    # row parallelism shards Kt: K must tile evenly; ragged N instead
    ({"batch_axes": ("data",), "row_axis": "model"}, (512, 300)),
    ({"batch_axes": ("data",), "gather_axis": "model"}, (300, 512)),
    ({"gather_axis": "data"}, (300, 512)),
])
def test_plans_match_dense_reference(plan_kw, shape, interpret_backend):
    """Forward and jax.grad under every partition plan ≡ the dense
    reference, including exactly-zero grads at padding slots.  Ragged
    shapes exercise the pad-and-slice boundaries."""
    mesh = _mesh()
    w, p = _packed(shape, 0.25, seed=4)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (44, shape[0]))
    plan = spmd.SpmdPlan(**plan_kw)

    def fn(x, p):
        return spmd.sod_matmul_spmd(x, p, mesh=mesh, plan=plan)

    y = fn(x, p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               atol=5e-4, rtol=1e-4)
    (gx, gp), (gx_r, gp_r) = _grads_vs_oracle(fn, x, p, ref.sod_matmul_ref)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r),
                               atol=2e-2, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gp.vals), np.asarray(gp_r.vals),
                               atol=2e-2, rtol=1e-3)
    pad = np.asarray(p.rows) < 0
    assert np.all(np.asarray(gp.vals)[pad] == 0)


@needs_mesh
def test_block_csr_spmd_grads(interpret_backend):
    mesh = _mesh()
    w, pb = _packed((256, 512), 0.3, "block_csr", seed=5)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (32, 256))

    def fn(x, p):
        return spmd.sod_matmul_spmd(
            x, p, mesh=mesh,
            plan=spmd.SpmdPlan(batch_axes=("data",), col_axis="model"))

    np.testing.assert_allclose(np.asarray(fn(x, pb)), np.asarray(x @ w),
                               atol=5e-4, rtol=1e-4)
    (gx, gp), (gx_r, gp_r) = _grads_vs_oracle(fn, x, pb,
                                              ref.block_matmul_ref)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_r),
                               atol=2e-2, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gp.block_vals),
                               np.asarray(gp_r.block_vals),
                               atol=2e-2, rtol=1e-3)
    pad = np.asarray(pb.block_ids) < 0
    assert np.all(np.asarray(gp.block_vals)[pad] == 0)


# ---------------------------------------------------------------------------
# dispatch: shard_map-wrapped pallas, not the oracle
# ---------------------------------------------------------------------------
@needs_mesh
def test_mesh_dispatch_uses_pallas_not_oracle(interpret_backend):
    """Acceptance: under an active mesh, ops.sod_matmul auto-routes through
    the SPMD layer and the body dispatches a Pallas impl with a
    mesh-qualified problem key — not the XLA scatter+dot oracle."""
    mesh = _mesh()
    w, p = _packed(seed=6)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (48, 256))
    with mesh, registry.record_dispatches() as log:
        y = ops.sod_matmul(x, p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               atol=5e-4, rtol=1e-4)
    assert log, "mesh dispatch did not consult the registry"
    assert all(rec["impl"] == "pallas_fused" for rec in log)
    assert all(rec["key"].mesh for rec in log)


@needs_mesh
def test_tpu_cold_cache_promotes_pallas_only_inside_wrapper():
    """The cold-cache TPU guard still pins *unwrapped* dispatch to natively
    partitionable impls, but the mesh-qualified key (inside shard_map)
    promotes the pallas kernels."""
    _, p = _packed(seed=7)
    unwrapped, _ = registry.choose(
        registry.problem_key(p, m=64, backend="tpu"))
    assert not unwrapped.requires_shard_map
    wrapped, _ = registry.choose(
        registry.problem_key(p, m=64, backend="tpu", mesh="data=4|dp=data"))
    assert wrapped.name == "pallas_fused"


@needs_mesh
def test_opt_outs_respected(interpret_backend, monkeypatch):
    mesh = _mesh()
    w, p = _packed(seed=8)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (16, 256))
    with mesh, registry.record_dispatches() as log:
        ops.sod_matmul(x, p, spmd=None)            # explicit opt-out
    assert all(not rec["key"].mesh for rec in log)
    monkeypatch.setenv("REPRO_SPMD", "0")          # process-wide kill switch
    with mesh, registry.record_dispatches() as log2:
        ops.sod_matmul(x, p)
    assert all(not rec["key"].mesh for rec in log2)


# ---------------------------------------------------------------------------
# end-to-end: pjit-sharded model step
# ---------------------------------------------------------------------------
@needs_mesh
def test_pjit_train_step_runs_fused_kernels(interpret_backend):
    """A jit'd sharded train step on the fake mesh routes every packed
    matmul through the SPMD layer (forward and backward both trace), and
    the loss stays finite."""
    from repro import configs
    from repro.core.sod import SoDConfig, sodify_params
    from repro.data.pipeline import SyntheticLMData
    from repro.launch import steps as steps_mod
    from repro.models.model import LM
    from repro.optim.adamw import AdamW, AdamWConfig
    from repro.runtime import sharding as shard_mod

    mesh = _mesh()
    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(
        sod=SoDConfig(mode="tiled_csc", density=0.4, min_dim=64))
    model = LM(cfg)
    params = sodify_params(model.init(jax.random.PRNGKey(0)), cfg.sod)
    opt = AdamW(AdamWConfig())
    opt_state = opt.init(params)
    data = SyntheticLMData(cfg, 8, 32, seed=0)
    batch = data.batch(0)

    p_specs = shard_mod.param_specs(params, cfg, mesh)
    p_sh = shard_mod.to_shardings(p_specs, mesh)
    o_sh = shard_mod.to_shardings(
        shard_mod.opt_state_specs(opt_state, p_specs, mesh), mesh)
    b_sh = shard_mod.to_shardings(shard_mod.batch_specs(batch, mesh), mesh)

    step = jax.jit(steps_mod.make_train_step(model, opt, mesh=mesh),
                   in_shardings=(p_sh, o_sh, b_sh),
                   out_shardings=(p_sh, o_sh, None))
    with mesh, registry.record_dispatches() as log:
        _, _, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    spmd_recs = [r for r in log if r["key"].mesh]
    assert spmd_recs, "no packed matmul went through the SPMD layer"
    assert {r["impl"] for r in spmd_recs} == {"pallas_fused"}


@needs_mesh
def test_sharded_grad_matches_unsharded_step(interpret_backend):
    """loss/grads of the mesh-sharded model ≡ the single-device model."""
    from repro import configs
    from repro.core.sod import SoDConfig, sodify_params
    from repro.data.pipeline import SyntheticLMData
    from repro.launch import steps as steps_mod
    from repro.models.model import LM

    cfg = configs.reduced(configs.get_config("llama3.2-1b")).with_(
        sod=SoDConfig(mode="tiled_csc", density=0.5, min_dim=64))
    model = LM(cfg)
    params = sodify_params(model.init(jax.random.PRNGKey(1)), cfg.sod)
    batch = SyntheticLMData(cfg, 4, 32, seed=1).batch(0)

    loss_ref, _, grads_ref = steps_mod.make_loss_and_grads(model)(
        params, batch)
    mesh = _mesh()
    loss_sh, _, grads_sh = steps_mod.make_loss_and_grads(model, mesh=mesh)(
        params, batch)
    np.testing.assert_allclose(float(loss_sh), float(loss_ref),
                               atol=1e-4, rtol=1e-4)
    for leaf_sh, leaf_ref in zip(
            jax.tree_util.tree_leaves(grads_sh),
            jax.tree_util.tree_leaves(grads_ref)):
        if leaf_sh.dtype == jax.dtypes.float0:
            continue
        np.testing.assert_allclose(
            np.asarray(leaf_sh, jnp.float32),
            np.asarray(leaf_ref, jnp.float32), atol=5e-2, rtol=5e-3)


# ---------------------------------------------------------------------------
# MoE all-to-all dispatch
# ---------------------------------------------------------------------------
@needs_mesh
def test_moe_a2a_matches_block_dispatch():
    """shard_map all-to-all token exchange ≡ the capacity-scatter path with
    block-local ranking (blocks = token shards), forward and grads."""
    from repro.models import moe

    spec = moe.MoESpec(n_experts=8, n_experts_padded=8, top_k=2, d_model=64,
                       d_ff=128, capacity_factor=8.0, dispatch_blocks=8)
    params = moe.init_moe(KEY, spec, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (4, 32, 64))
    y_ref, aux_ref = moe.moe_mlp(params, x, spec)

    mesh = _mesh()
    spec_a2a = dataclasses.replace(spec, a2a_axis="model")
    with mesh:
        y, aux = moe.moe_mlp(params, x, spec_a2a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)
    np.testing.assert_allclose(float(aux), float(aux_ref), atol=1e-6)

    def loss(params, x, s):
        with mesh:
            y, aux = moe.moe_mlp(params, x, s)
        return (y ** 2).sum() + aux

    g = jax.grad(loss)(params, x, spec_a2a)
    g_ref = jax.grad(loss)(params, x, spec)
    for k in ("w_gate", "w_up", "w_down", "router"):
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]),
                                   atol=1e-3, rtol=1e-3, err_msg=k)


@needs_mesh
def test_moe_a2a_falls_back_when_shapes_dont_divide():
    from repro.models import moe

    spec = moe.MoESpec(n_experts=6, n_experts_padded=6, top_k=2, d_model=64,
                       d_ff=128, a2a_axis="model")   # 6 % 2 == 0 but t odd
    params = moe.init_moe(KEY, spec, jnp.float32)
    x = jax.random.normal(KEY, (1, 17, 64))          # 17 tokens: no divide
    with _mesh():
        y, aux = moe.moe_mlp(params, x, spec)
    assert np.all(np.isfinite(np.asarray(y)))


# ---------------------------------------------------------------------------
# sharding-rule plans
# ---------------------------------------------------------------------------
@needs_mesh
def test_packed_matmul_plans_follow_param_specs():
    from repro import configs
    from repro.runtime import sharding as shard_mod

    mesh = _mesh()
    cfg = configs.get_config("llama3.2-1b")
    _, up = _packed((256, 512), seed=9)     # w_up: N-sharded → col plan
    _, down = _packed((512, 256), seed=10)  # w_down: K-sharded → row plan
    plans = shard_mod.packed_matmul_plans(
        {"blocks": {"mlp": {"w_up": up, "w_down": down}}}, cfg, mesh)
    assert plans[".blocks.mlp.w_up"].col_axis == "model"
    assert plans[".blocks.mlp.w_down"].row_axis == "model"
    for plan in plans.values():
        assert plan.batch_axes == ("data",)


@needs_mesh
def test_planner_attaches_spmd_plans_and_dispatch_uses_them(
        interpret_backend, tmp_path):
    """build_plan(mesh=) records each leaf's resident-sharding SpmdPlan;
    under use_plan a bare sod.apply runs shard_map-wrapped under exactly
    that plan — including after a JSON round trip."""
    from repro import configs
    from repro.core import plan as plan_mod
    from repro.core import sod
    from repro.core.plan import ModelPlan
    from repro.core.sod import SoDConfig, sodify_params
    from repro.runtime import planner

    mesh = _mesh()
    cfg = configs.get_config("llama3.2-1b")
    sodc = SoDConfig(mode="tiled_csc", density=0.3, min_dim=128)
    wu = pruning.random_sparse(jax.random.fold_in(KEY, 21), (256, 512), 0.3)
    wd = pruning.random_sparse(jax.random.fold_in(KEY, 22), (512, 256), 0.3)
    params = {"blocks": {"mlp": {"w_up": wu, "w_down": wd}}}
    plan = planner.build_plan(params, sodc, cfg=cfg, mesh=mesh,
                              m_values=(48,))
    assert plan.mesh == spmd.mesh_key(mesh)
    assert plan.get(".blocks.mlp.w_up").spmd["col_axis"] == "model"
    assert plan.get(".blocks.mlp.w_down").spmd["row_axis"] == "model"
    # round trip: the loaded plan is the plan
    loaded = ModelPlan.load(plan.save(tmp_path / "plan.json"))
    assert loaded.entries == plan.entries and loaded.mesh == plan.mesh

    packed = sodify_params(params, sodc, plan=loaded)
    x = jax.random.normal(jax.random.fold_in(KEY, 23), (48, 256),
                          jnp.float32)
    with mesh, plan_mod.use_plan(loaded), \
            registry.record_dispatches() as log:
        y = jax.jit(lambda x, w: sod.apply(x, w))(
            x, packed["blocks"]["mlp"]["w_up"])
    assert log and "col=model" in log[-1]["key"].mesh
    np.testing.assert_allclose(
        np.asarray(y),
        np.asarray(x @ packed["blocks"]["mlp"]["w_up"].to_dense()),
        atol=2e-2)


# ---------------------------------------------------------------------------
# acceptance smoke (always runs: subprocess forces its own devices)
# ---------------------------------------------------------------------------
def test_spmd_acceptance_subprocess():
    """ISSUE 2 acceptance, isolated from this process's device count: on a
    fake 8-device mesh a pjit-sharded sod_matmul dispatches a
    shard_map-wrapped Pallas impl (not the XLA oracle), and forward +
    jax.grad match the dense reference."""
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import tempfile
os.environ['REPRO_TUNING_CACHE'] = os.path.join(
    tempfile.mkdtemp(), 'cache.json')
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core import pruning
from repro.core.formats import pack_tiled_csc
from repro.kernels import ops, registry
mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ('data', 'model'))
w = pruning.random_sparse(jax.random.PRNGKey(0), (256, 512), 0.3)
p = pack_tiled_csc(w)
x = jax.random.normal(jax.random.PRNGKey(1), (48, 256))
registry.set_backend_override('interpret')
def loss(x, p):
    with mesh:
        return (jax.jit(lambda x, p: ops.sod_matmul(x, p))(x, p) ** 2).sum()
with registry.record_dispatches() as log:
    gx, gp = jax.grad(loss, argnums=(0, 1), allow_int=True)(x, p)
assert log and all(r['impl'] == 'pallas_fused' and r['key'].mesh
                   for r in log), log
gx_ref, gw_ref = jax.grad(lambda x, w: ((x @ w) ** 2).sum(),
                          argnums=(0, 1))(x, w)
assert np.allclose(np.asarray(gx), np.asarray(gx_ref), atol=2e-2)
pad = np.asarray(p.rows) < 0
assert np.all(np.asarray(gp.vals)[pad] == 0)
print('OK')
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
