"""Recurrent blocks: chunked parallel forms ≡ sequential recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm, xlstm

KEY = jax.random.PRNGKey(0)


def test_mamba_chunked_equals_decode():
    spec = ssm.MambaSpec(d_model=64, d_state=16, expand=2, headdim=32,
                         chunk=32)
    params = ssm.init_mamba(KEY, spec, dtype=jnp.float32)
    x = jax.random.normal(KEY, (2, 128, 64), jnp.float32) * 0.5
    y = ssm.mamba_forward(params, x, spec)
    assert not bool(jnp.any(jnp.isnan(y)))

    cache = ssm.init_mamba_cache(2, spec, dtype=jnp.float32)

    def step(cache, t):
        xt = jax.lax.dynamic_slice(x, (0, t, 0), (2, 1, 64))
        out, cache = ssm.mamba_decode_step(params, xt, cache, spec)
        return cache, out[:, 0]

    _, ys = jax.lax.scan(step, cache, jnp.arange(128))
    np.testing.assert_allclose(np.asarray(ys.transpose(1, 0, 2)),
                               np.asarray(y), atol=2e-3)


@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_mamba_chunk_invariance(chunk):
    base = ssm.MambaSpec(d_model=32, d_state=8, expand=2, headdim=16,
                         chunk=64)
    params = ssm.init_mamba(KEY, base, dtype=jnp.float32)
    x = jax.random.normal(KEY, (1, 64, 32), jnp.float32) * 0.5
    y64 = ssm.mamba_forward(params, x, base)
    spec = ssm.MambaSpec(d_model=32, d_state=8, expand=2, headdim=16,
                         chunk=chunk)
    np.testing.assert_allclose(np.asarray(ssm.mamba_forward(params, x, spec)),
                               np.asarray(y64), atol=2e-3)


def test_mlstm_chunked_equals_decode():
    spec = xlstm.XLSTMSpec(d_model=64, n_heads=4, chunk=16)
    params = xlstm.init_mlstm(KEY, spec, dtype=jnp.float32)
    x = jax.random.normal(KEY, (2, 64, 64), jnp.float32) * 0.5
    y, _ = xlstm.mlstm_block(params, x, spec)
    cache = xlstm.init_mlstm_cache(2, spec, dtype=jnp.float32)

    def step(cache, t):
        xt = jax.lax.dynamic_slice(x, (0, t, 0), (2, 1, 64))
        out, cache = xlstm.mlstm_block(params, xt, spec, cache=cache,
                                       decode=True)
        return cache, out[:, 0]

    _, ys = jax.lax.scan(step, cache, jnp.arange(64))
    np.testing.assert_allclose(np.asarray(ys.transpose(1, 0, 2)),
                               np.asarray(y), atol=2e-3)


def test_mlstm_chunk_invariance():
    spec8 = xlstm.XLSTMSpec(d_model=64, n_heads=4, chunk=8)
    spec32 = xlstm.XLSTMSpec(d_model=64, n_heads=4, chunk=32)
    params = xlstm.init_mlstm(KEY, spec8, dtype=jnp.float32)
    x = jax.random.normal(KEY, (2, 64, 64), jnp.float32) * 0.5
    y8, _ = xlstm.mlstm_block(params, x, spec8)
    y32, _ = xlstm.mlstm_block(params, x, spec32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), atol=2e-3)


def test_slstm_streaming_state():
    spec = xlstm.XLSTMSpec(d_model=64, n_heads=4)
    params = xlstm.init_slstm(KEY, spec, dtype=jnp.float32)
    x = jax.random.normal(KEY, (2, 64, 64), jnp.float32) * 0.5
    full, _ = xlstm.slstm_scan(params, x, spec)
    st = xlstm.init_slstm_cache(2, spec)
    y1, st = xlstm.slstm_scan(params, x[:, :32], spec, state=st)
    y2, _ = xlstm.slstm_scan(params, x[:, 32:], spec, state=st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)), np.asarray(full),
        atol=2e-3)


def test_mamba_state_decay_property():
    """exp gating: with zero input the SSM state decays monotonically."""
    spec = ssm.MambaSpec(d_model=32, d_state=8, expand=2, headdim=16)
    params = ssm.init_mamba(KEY, spec, dtype=jnp.float32)
    cache = ssm.init_mamba_cache(1, spec, dtype=jnp.float32)
    cache = {**cache, "ssm": cache["ssm"] + 1.0}
    x = jnp.zeros((1, 1, 32))
    norms = []
    for _ in range(4):
        _, cache = ssm.mamba_decode_step(params, x, cache, spec)
        norms.append(float(jnp.sum(jnp.abs(cache["ssm"]))))
    assert norms[0] > norms[-1]
