"""Required per-architecture smoke tests: reduced config, one forward +
one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import SyntheticLMData
from repro.launch.steps import make_train_step
from repro.models.model import build_model
from repro.optim import AdamW, AdamWConfig

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def _batch(cfg):
    return SyntheticLMData(cfg, B, S, seed=0).batch(0)


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = configs.reduced(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)

    logits, aux, _ = model.apply(params, batch)
    if cfg.family == "audio":
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab)
    elif cfg.family == "vlm":
        assert logits.shape == (B, S, cfg.vocab)   # patches + text
    else:
        assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits)))

    opt = AdamW(AdamWConfig(lr=1e-3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    p2, o2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 1.0 < loss < 20.0
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, pair: acc or bool(jnp.any(pair)),
        jax.tree_util.tree_map(
            lambda a, b: jnp.any(a != b) if a.dtype.kind == "f" else False,
            params, p2),
        False)
    assert moved


@pytest.mark.parametrize("arch", ["gemma2-27b", "qwen2-moe-a2.7b",
                                  "zamba2-2.7b", "xlstm-125m",
                                  "musicgen-medium"])
def test_decode_step_per_family(arch):
    cfg = configs.reduced(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    cache = model.init_cache(B, 32)
    if cfg.family == "audio":
        tok = jnp.zeros((B, 1, cfg.n_codebooks), jnp.int32)
    else:
        tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok, jnp.asarray(0))
    assert not bool(jnp.any(jnp.isnan(logits)))
    # cache structurally unchanged
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


def test_full_config_param_counts_match_billing():
    expect = {
        "gemma2-27b": 27.2, "llama3.2-1b": 1.24, "internlm2-1.8b": 1.89,
        "yi-34b": 34.4, "pixtral-12b": 12.3, "qwen2-moe-a2.7b": 14.3,
        "granite-moe-1b-a400m": 1.33, "musicgen-medium": 1.82,
        "zamba2-2.7b": 2.42, "xlstm-125m": 0.20,
    }
    for arch, bn in expect.items():
        got = configs.get_config(arch).param_count() / 1e9
        assert abs(got - bn) / bn < 0.15, (arch, got, bn)


def test_moe_active_params():
    cfg = configs.get_config("qwen2-moe-a2.7b")
    assert cfg.active_param_count() / 1e9 == pytest.approx(2.7, rel=0.15)
    cfg = configs.get_config("granite-moe-1b-a400m")
    assert cfg.active_param_count() / 1e9 < 0.6


def test_scan_vs_unrolled_consistency():
    cfg = configs.reduced(configs.get_config("internlm2-1.8b"))
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)
    l1, _ = model.loss(params, batch)
    l2, _ = build_model(cfg.with_(scan_layers=False)).loss(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-2
