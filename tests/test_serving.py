"""Serving: prefill + decode ≡ full forward; greedy loop determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.pipeline import SyntheticLMData
from repro.models.model import build_model

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-27b",
                                  "musicgen-medium"])
def test_prefill_then_decode_matches_full_forward(arch):
    cfg = configs.reduced(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 2, 32
    data = SyntheticLMData(cfg, b, s, seed=0)
    batch = {k: v for k, v in data.batch(0).items() if k != "targets"}

    # full forward logits at position s-1
    logits_full, _, _ = model.apply(params, batch)
    # prefill over the first s-1 tokens, then decode token s-1
    prompt = jax.tree_util.tree_map(
        lambda t: t[:, : s - 1] if t.shape[1:2] == (s,) else t, batch)
    if cfg.family == "vlm":
        prompt = {"tokens": batch["tokens"][:, :-1],
                  "patch_embeds": batch["patch_embeds"]}
    _, cache = model.prefill(params, prompt)

    def grow(t):
        # pad cache seq dim (== s-1) up to s
        if t.ndim >= 4 and (s - 1) in t.shape[-3:-2]:
            pad = [(0, 0)] * t.ndim
            pad[-3] = (0, 1)
            return jnp.pad(t, pad)
        return t

    cache = jax.tree_util.tree_map(grow, cache)
    last_tok = batch["tokens"][:, s - 1 - (cfg.n_patches if cfg.family == "vlm" else 0):][:, :1]
    if cfg.family == "audio":
        last_tok = batch["tokens"][:, -1:, :]
    else:
        last_tok = batch["tokens"][:, -1:]
    pos = s - 1 + (cfg.n_patches if cfg.family == "vlm" else 0)
    logits_dec, _ = model.decode_step(params, cache, last_tok,
                                      jnp.asarray(pos, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(logits_full[:, -1]),
        atol=0.1, rtol=0.05)


def test_greedy_decode_deterministic():
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    cache = model.init_cache(2, 16)
    tok = jnp.zeros((2, 1), jnp.int32)
    outs1, outs2 = [], []
    for run in (outs1, outs2):
        c = cache
        t = tok
        for i in range(6):
            logits, c = model.decode_step(params, c, t, jnp.asarray(i))
            t = jnp.argmax(logits, axis=-1).reshape(2, 1)
            run.append(np.asarray(t))
    np.testing.assert_array_equal(np.concatenate(outs1),
                                  np.concatenate(outs2))


def test_serve_driver_end_to_end():
    from repro.launch import serve

    summary = serve.main([
        "--arch", "llama3.2-1b", "--reduced", "--batch", "2",
        "--prompt-len", "8", "--gen", "4"])
    assert summary["generated"] == 4
    assert summary["decode_tok_per_s"] > 0


def test_sample_tokens_defensive_extraction():
    """Regression: the old ``jnp.asarray(outs)[:8, 0]`` assumed (B, 1) token
    steps — it crashed on empty output lists and misreported the audio
    family's (B, 1, C) codebook stacks."""
    from repro.launch.serve import _sample_tokens

    # token model: per-step (B, 1)
    outs = [jnp.full((4, 1), i) for i in range(10)]
    assert _sample_tokens(outs) == list(range(8))
    # audio: per-step (B, 1, C) — codebook 0 of batch row 0, one per step
    outs = [(jnp.arange(3) + 10 * i).reshape(1, 1, 3) for i in range(4)]
    assert _sample_tokens(outs) == [0, 10, 20, 30]
    # small --gen and empty output must not crash
    assert _sample_tokens([jnp.ones((2, 1), jnp.int32)]) == [1]
    assert _sample_tokens([]) == []
    assert _sample_tokens([jnp.zeros((0,), jnp.int32)]) == []


def test_serve_audio_family_reports_sample():
    """The audio family used to crash/misreport sample extraction."""
    from repro.launch import serve

    summary = serve.main([
        "--arch", "musicgen-medium", "--reduced", "--batch", "2",
        "--prompt-len", "4", "--gen", "2"])
    assert summary["generated"] == 2
    assert len(summary["sample"]) == 2
    assert all(isinstance(t, int) for t in summary["sample"])


def test_serve_plan_dump_and_replay(tmp_path):
    """serve --plan auto dumps a plan that replays to identical packing and
    identical generated tokens."""
    from repro.launch import serve

    plan_path = tmp_path / "plan.json"
    s1 = serve.main([
        "--arch", "llama3.2-1b", "--reduced", "--batch", "2",
        "--prompt-len", "8", "--gen", "3", "--sod", "tiled_csc",
        "--density", "0.4", "--plan", "auto",
        "--plan-json", str(plan_path)])
    assert plan_path.exists()
    s2 = serve.main([
        "--arch", "llama3.2-1b", "--reduced", "--batch", "2",
        "--prompt-len", "8", "--gen", "3", "--sod", "tiled_csc",
        "--density", "0.4", "--plan", str(plan_path)])
    assert s1["plan_layers"] == s2["plan_layers"] >= 4
    assert s1["plan_bytes"] == s2["plan_bytes"]
    assert s1["sample"] == s2["sample"]
