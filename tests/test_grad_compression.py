"""Top-k gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import grad as G

KEY = jax.random.PRNGKey(0)


def test_topk_roundtrip_full_ratio():
    g = jax.random.normal(KEY, (64, 32))
    vals, idx, err = G.topk_compress(g, 1.0)
    np.testing.assert_allclose(np.asarray(err), 0.0, atol=1e-7)
    out = G.topk_decompress(vals, idx, g.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=1e-6)


def test_topk_keeps_largest_and_error_is_rest():
    g = jnp.asarray([1.0, -5.0, 0.1, 3.0])
    vals, idx, err = G.topk_compress(g, 0.5)
    assert set(np.asarray(idx).tolist()) == {1, 3}
    np.testing.assert_allclose(np.asarray(err), [1.0, 0.0, 0.1, 0.0])
    recon = G.topk_decompress(vals, idx, (4,))
    np.testing.assert_allclose(np.asarray(recon + err), np.asarray(g),
                               atol=1e-7)


def test_error_feedback_is_unbiased_over_time():
    """Sum of transmitted + final residual == sum of raw grads."""
    gs = [jax.random.normal(jax.random.fold_in(KEY, i), (128,))
          for i in range(5)]
    err = jnp.zeros((128,))
    sent = jnp.zeros((128,))
    for g in gs:
        vals, idx, err = G.topk_compress(g + err, 0.25)
        sent += G.topk_decompress(vals, idx, (128,))
    total = sent + err
    np.testing.assert_allclose(np.asarray(total),
                               np.asarray(sum(gs)), atol=1e-4)


def test_accumulate_running_mean():
    a = {"w": jnp.asarray([2.0])}
    b = {"w": jnp.asarray([4.0])}
    acc = G.accumulate(a, None, 1)
    acc = G.accumulate(b, acc, 2)
    np.testing.assert_allclose(np.asarray(acc["w"]), [3.0])
