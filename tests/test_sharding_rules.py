"""Pure-logic tests for partition rules and dry-run accounting helpers."""
import numpy as np
import pytest

from repro import configs
from repro.launch.dryrun import _extrapolate, collective_bytes
from repro.runtime.sharding import _matrix_spec


def _cfg(name="llama3.2-1b"):
    return configs.get_config(name)


class TestMatrixSpec:
    def test_attention_projections(self):
        cfg = _cfg()
        assert _matrix_spec(".blocks.attn.wq", (2048, 2048), cfg, 16) == \
            (None, "model")
        # llama kv heads (8) < TP (16) → replicate
        assert _matrix_spec(".blocks.attn.wk", (2048, 512), cfg, 16) == \
            (None, None)
        assert _matrix_spec(".blocks.attn.wo", (2048, 2048), cfg, 16) == \
            ("model", None)

    def test_kv_sharded_when_divisible(self):
        cfg = _cfg("gemma2-27b")   # kv=16
        assert _matrix_spec(".blocks.attn.wk", (4608, 2048), cfg, 16) == \
            (None, "model")

    def test_mlp(self):
        cfg = _cfg()
        assert _matrix_spec(".blocks.mlp.w_gate", (2048, 8192), cfg, 16) == \
            (None, "model")
        assert _matrix_spec(".blocks.mlp.w_down", (8192, 2048), cfg, 16) == \
            ("model", None)

    def test_embed_vocab_sharded(self):
        cfg = _cfg()
        assert _matrix_spec(".embed", (128256, 2048), cfg, 16) == \
            ("model", None)

    def test_router_replicated(self):
        cfg = _cfg("qwen2-moe-a2.7b")
        assert _matrix_spec(".blocks.moe.router", (2048, 64), cfg, 16) == \
            (None, None)


class TestCollectiveParser:
    HLO = """
  %all-gather.3 = f32[16,1,8,32768,8,64]{5,3,2,1,0,4} all-gather(%x), dims
  %all-reduce.1 = bf16[1024,512]{1,0} all-reduce(%y), channel_id=2
  %rs = f32[128]{0} reduce-scatter(%z), channel_id=3
  %dot.1 = f32[64,64]{1,0} dot(%a, %b)
"""

    def test_counts_and_bytes(self):
        out = collective_bytes(self.HLO)
        ag = 16 * 1 * 8 * 32768 * 8 * 64 * 4
        ar = 1024 * 512 * 2 * 2           # ×2 ring RS+AG
        rs = 128 * 4
        assert out["all-gather"] == ag
        assert out["all-reduce"] == ar
        assert out["reduce-scatter"] == rs
        assert out["total"] == ag + ar + rs
        assert out["counts"]["all-gather"] == 1

    def test_ignores_non_collectives(self):
        out = collective_bytes("%dot = f32[8,8]{1,0} dot(%a, %b)")
        assert out["total"] == 0


class TestProbeExtrapolation:
    def test_affine_law_exact(self):
        a1 = {"cost": {"flops": 100.0, "bytes_accessed": 10.0,
                       "transcendentals": 0.0},
              "collectives": {"all-gather": 4.0, "all-reduce": 2.0,
                              "reduce-scatter": 0, "all-to-all": 0,
                              "collective-permute": 0, "total": 6.0}}
        a2 = {"cost": {"flops": 160.0, "bytes_accessed": 16.0,
                       "transcendentals": 0.0},
              "collectives": {"all-gather": 6.0, "all-reduce": 3.0,
                              "reduce-scatter": 0, "all-to-all": 0,
                              "collective-permute": 0, "total": 9.0}}
        out = _extrapolate(a1, a2, 1, 2, 10)
        # per-group flops = 60, outside = 40 → 40 + 600
        assert out["cost"]["flops"] == pytest.approx(640.0)
        assert out["cost"]["bytes_accessed"] == pytest.approx(64.0)
        # collectives: per-group 3, outside 3 → 3 + 30
        assert out["collectives"]["total"] == pytest.approx(33.0)
        assert out["collectives"]["all-gather"] == pytest.approx(22.0)


class TestRooflineModelFlops:
    def test_train_flops_scale(self):
        import benchmarks.roofline as R

        f = R.model_flops_per_chip("llama3.2-1b", "train_4k")
        # 6·N·D / 256 chips within 2× (attention + head conventions)
        expect = 6 * 1.24e9 * 256 * 4096 / 256
        assert 0.5 < f / expect < 2.0

    def test_decode_much_smaller_than_train(self):
        import benchmarks.roofline as R

        tr = R.model_flops_per_chip("yi-34b", "train_4k")
        de = R.model_flops_per_chip("yi-34b", "decode_32k")
        assert de < tr / 1000


def test_padded_vocab_property():
    assert configs.get_config("granite-moe-1b-a400m").padded_vocab % 128 == 0
    assert configs.get_config("gemma2-27b").padded_vocab == 256_000
