"""Continuous-batching serving engine: paged pool invariants, ragged
decode correctness, and engine-vs-static-serve token identity."""
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # CI installs hypothesis; bare
    from _hypothesis_stub import given, settings, st  # noqa: E501  envs skip the property tests

from repro import configs
from repro.models import attention as attn
from repro.models.model import build_model
from repro.models.transformer import attn_spec
from repro.serving import (
    Engine,
    PagePool,
    PoolExhausted,
    Request,
    bucket_len,
    poisson_trace,
    static_generate,
)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# page pool
# ---------------------------------------------------------------------------
def test_page_pool_invariants():
    pool = PagePool(8, page_size=4)
    assert pool.free_count == 7          # page 0 reserved as trash
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert PagePool.TRASH_PAGE not in a + b
    assert len(set(a + b)) == 5          # no double allocation
    pool.free(a)
    assert pool.free_count == 5          # frees return to the pool
    c = pool.alloc(4)
    assert len(set(b + c)) == 6
    with pytest.raises(PoolExhausted):
        pool.alloc(2)                    # only 1 free
    (still_free,) = set(range(1, 8)) - set(b) - set(c)
    with pytest.raises(ValueError):
        pool.free([still_free])          # double free of an unheld page
    with pytest.raises(ValueError):
        pool.free([PagePool.TRASH_PAGE])  # trash page is never allocated
    assert pool.pages_for(9) == 3


@settings(max_examples=60, deadline=None)
@given(st.integers(4, 24),
       st.lists(st.tuples(st.integers(0, 6), st.integers(0, 10**6)),
                min_size=1, max_size=120))
def test_page_pool_refcount_partition_property(n_pages, program):
    """Random alloc/retain/free/trim/fork/swap programs against a model:
    refcounts never go negative (every release on an unheld page raises
    instead), the trash page is never handed out, and the free list plus
    the live (refcount >= 1) set partitions the pool exactly — with the
    host-swapped tally consistent with what actually left the device."""
    pool = PagePool(n_pages, page_size=4)
    refs: dict[int, int] = {}     # model: page id -> expected refcount
    host = 0                      # model: pages swapped out, not yet back
    for op, r in program:
        live = sorted(refs)
        if op == 0:                                 # alloc
            k = r % (pool.free_count + 1)
            for p in pool.alloc(k):
                assert p != PagePool.TRASH_PAGE and p not in refs
                refs[p] = 1
        elif op == 1 and live:                      # retain (share)
            p = live[r % len(live)]
            pool.retain([p])
            refs[p] += 1
        elif op == 2 and live:                      # free one reference
            p = live[r % len(live)]
            freed = pool.free([p])
            refs[p] -= 1
            assert (freed == [p]) == (refs[p] == 0)
            if refs[p] == 0:
                del refs[p]
        elif op == 3 and live:                      # spec rollback
            p = live[r % len(live)]
            before = pool.trimmed_pages
            freed = pool.trim([p])
            refs[p] -= 1
            assert pool.trimmed_pages - before == len(freed)
            if refs[p] == 0:
                del refs[p]
        elif op == 4:                               # copy-on-write fork
            shared = [p for p in live if refs[p] >= 2]
            if shared and pool.can_alloc(1):
                p = shared[r % len(shared)]
                new = pool.fork(p)
                refs[p] -= 1
                assert new not in refs and new != PagePool.TRASH_PAGE
                refs[new] = 1
        elif op == 5 and live:                      # preempt: swap out
            p = live[r % len(live)]
            before = pool.swapped_out_pages
            freed = pool.swap_out([p])
            refs[p] -= 1
            assert (freed == [p]) == (refs[p] == 0)
            assert pool.swapped_out_pages - before == len(freed)
            host += len(freed)
            if refs[p] == 0:
                del refs[p]
        elif op == 6 and host:                      # resume: swap in
            k = min(host, r % (pool.free_count + 1))
            for p in pool.swap_in(k):
                assert p != PagePool.TRASH_PAGE and p not in refs
                refs[p] = 1
            host -= k
        # invariants after every operation
        assert PagePool.TRASH_PAGE not in pool.allocated
        assert pool.allocated == frozenset(refs)
        for p, c in refs.items():
            assert c >= 1 and pool.ref_count(p) == c
        # free + live partitions the usable pool (page 0 reserved)
        assert pool.free_count + len(refs) == pool.n_pages - 1
        assert pool.swapped_in_pages <= pool.swapped_out_pages
    # a release on a page nobody holds must raise, never go negative
    victim = next(iter(refs)) if refs else pool.alloc(1)[0]
    pool.free([victim] * pool.ref_count(victim))
    with pytest.raises(ValueError):
        pool.free([victim])
    assert pool.ref_count(victim) == 0


def test_bucket_len():
    assert bucket_len(5, 8) == 8
    assert bucket_len(8, 8) == 8
    assert bucket_len(9, 8) == 16
    # prompts longer than the attention chunk round to lcm(page, chunk)
    assert bucket_len(70, 8, chunk=64) == 128
    assert bucket_len(60, 8, chunk=64) == 64


# ---------------------------------------------------------------------------
# explicit cache growth (replaces the serve driver's shape heuristic)
# ---------------------------------------------------------------------------
def test_grow_cache_pads_only_sequence_axes():
    cfg = configs.reduced(configs.get_config("zamba2-2.7b"))
    model = build_model(cfg)
    # batch == conv-state width scenarios are exactly where the old
    # ``t.shape[-3] == prompt_len`` heuristic mis-grew non-sequence leaves
    b, max_len = 3, 8
    cache = model.init_cache(b, max_len)
    grown = model.grow_cache(cache, 12)
    assert grown["k"].shape[-3] == 12 and grown["v"].shape[-3] == 12
    # recurrent state untouched — no sequence axis anywhere
    assert grown["ssm"].shape == cache["ssm"].shape
    assert jax.tree_util.tree_map(
        lambda t: t.shape, grown["conv"]) == jax.tree_util.tree_map(
        lambda t: t.shape, cache["conv"])
    # no-op when already long enough
    again = model.grow_cache(grown, 10)
    assert again["k"].shape == grown["k"].shape


def test_grow_cache_heuristic_regression():
    """A hybrid conv leaf whose batch dim equals the prompt length must
    NOT be grown (the old serve heuristic padded it)."""
    cfg = configs.reduced(configs.get_config("zamba2-2.7b"))
    model = build_model(cfg)
    s = cfg.ssm_conv - 1                  # make batch == a conv leaf dim
    cache = model.init_cache(s, s)
    grown = model.grow_cache(cache, s + 4)
    assert grown["conv"]["x"].shape == cache["conv"]["x"].shape
    assert grown["k"].shape[-3] == s + 4


# ---------------------------------------------------------------------------
# vector-position + paged decode attention
# ---------------------------------------------------------------------------
def _toy_attention():
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    spec = attn_spec(cfg)
    params = attn.init_attention(KEY, cfg.d_model, spec)
    return cfg, spec, params


def test_decode_attention_vector_pos_matches_scalar():
    cfg, spec, params = _toy_attention()
    b, s_max = 3, 16
    cache = {
        "k": jax.random.normal(jax.random.PRNGKey(1),
                               (b, s_max, spec.n_kv_heads, spec.head_dim),
                               jnp.bfloat16),
        "v": jax.random.normal(jax.random.PRNGKey(2),
                               (b, s_max, spec.n_kv_heads, spec.head_dim),
                               jnp.bfloat16),
    }
    x = jax.random.normal(jax.random.PRNGKey(3), (b, 1, cfg.d_model),
                          jnp.bfloat16)
    o_s, c_s = attn.decode_attention(params, x, cache, jnp.asarray(5), spec)
    o_v, c_v = attn.decode_attention(params, x, cache,
                                     jnp.full((b,), 5, jnp.int32), spec)
    np.testing.assert_array_equal(np.asarray(o_s), np.asarray(o_v))
    np.testing.assert_array_equal(np.asarray(c_s["k"]), np.asarray(c_v["k"]))


def test_decode_attention_ragged_rows_independent():
    """Each row of a staggered-``pos`` batch equals the same row decoded
    alone at its own scalar position (incl. a sliding-window layer)."""
    cfg, spec, params = _toy_attention()
    b, s_max = 3, 16
    pos = jnp.asarray([2, 7, 11], jnp.int32)
    cache = {
        "k": jax.random.normal(jax.random.PRNGKey(1),
                               (b, s_max, spec.n_kv_heads, spec.head_dim),
                               jnp.bfloat16),
        "v": jax.random.normal(jax.random.PRNGKey(2),
                               (b, s_max, spec.n_kv_heads, spec.head_dim),
                               jnp.bfloat16),
    }
    x = jax.random.normal(jax.random.PRNGKey(3), (b, 1, cfg.d_model),
                          jnp.bfloat16)
    for window in (None, 4):
        o_v, c_v = attn.decode_attention(params, x, cache, pos, spec,
                                         window=window)
        for row in range(b):
            sub = jax.tree_util.tree_map(lambda t: t[row:row + 1], cache)
            o_r, c_r = attn.decode_attention(
                params, x[row:row + 1], sub, pos[row], spec, window=window)
            np.testing.assert_array_equal(np.asarray(o_v[row]),
                                          np.asarray(o_r[0]))
            np.testing.assert_array_equal(np.asarray(c_v["k"][row]),
                                          np.asarray(c_r["k"][0]))


def test_paged_decode_matches_dense():
    """With pages holding the same KV content, paged decode is bit-equal
    to the dense vector-``pos`` path."""
    cfg, spec, params = _toy_attention()
    b, page, n_logical = 2, 4, 3          # 12 cache positions per row
    s_max = page * n_logical
    cache = {
        "k": jax.random.normal(jax.random.PRNGKey(1),
                               (b, s_max, spec.n_kv_heads, spec.head_dim),
                               jnp.bfloat16),
        "v": jax.random.normal(jax.random.PRNGKey(2),
                               (b, s_max, spec.n_kv_heads, spec.head_dim),
                               jnp.bfloat16),
    }
    # scatter the dense rows into a shared pool at scrambled page ids
    n_pages = 1 + b * n_logical
    pool = attn.init_paged_pool(n_pages, page, spec)
    tables = np.asarray([[3, 5, 1], [6, 2, 4]], np.int32)
    pk = np.array(pool["k"])
    pv = np.array(pool["v"])
    for row in range(b):
        for j in range(n_logical):
            pk[tables[row, j]] = np.asarray(
                cache["k"][row, j * page:(j + 1) * page])
            pv[tables[row, j]] = np.asarray(
                cache["v"][row, j * page:(j + 1) * page])
    pool = {"k": jnp.asarray(pk), "v": jnp.asarray(pv)}
    x = jax.random.normal(jax.random.PRNGKey(3), (b, 1, cfg.d_model),
                          jnp.bfloat16)
    pos = jnp.asarray([5, 9], jnp.int32)
    o_d, c_d = attn.decode_attention(params, x, cache, pos, spec)
    o_p, pool = attn.paged_decode_attention(params, x, pool,
                                            jnp.asarray(tables), pos, spec)
    np.testing.assert_array_equal(np.asarray(o_d), np.asarray(o_p))
    # the written slots round-trip through the pages too
    for row in range(b):
        p = int(pos[row])
        np.testing.assert_array_equal(
            np.asarray(pool["k"][tables[row, p // page], p % page]),
            np.asarray(c_d["k"][row, p]))


# ---------------------------------------------------------------------------
# engine vs static-batch serve (the PR's acceptance gate)
# ---------------------------------------------------------------------------
def _sod_plan(cfg, params, monkeypatch, tmp_path):
    """Planner-built PackPlan against a fresh (cold) tuning cache so the
    engine (M = max_slots) and static reference (M = 1) resolve the same
    cold-cache kernel choice."""
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(tmp_path / "tc.json"))
    from repro.runtime import planner

    plan = planner.load_or_build("auto", params, cfg.sod, cfg=cfg,
                                 m_values=(8, 1))
    return plan


@pytest.mark.parametrize("arch", ["llama3.2-1b", "xlstm-125m"])
def test_engine_matches_static_serve_sod_plan(arch, monkeypatch, tmp_path):
    """Ragged trace (staggered arrivals, mixed gen lengths) through the
    engine produces greedy tokens identical to per-request static serve,
    with planner-packed SoD weights — attention + recurrent families."""
    from repro.core.sod import SoDConfig, sodify_params

    cfg = configs.reduced(configs.get_config(arch)).with_(
        sod=SoDConfig(mode="tiled_csc", density=0.4, min_dim=64))
    model = build_model(cfg)
    params = model.init(KEY)
    plan = _sod_plan(cfg, params, monkeypatch, tmp_path)
    assert plan is not None and len(plan) >= 1
    params = sodify_params(params, cfg.sod, plan=plan)

    trace = poisson_trace(4, 0.7, max_prompt=10, max_new=5,
                          vocab=cfg.vocab, seed=3)
    # ragged by construction: staggered arrivals, mixed lengths
    assert len({r.arrival for r in trace}) > 1
    assert len({len(r.tokens) for r in trace}) > 1
    eng = Engine(model, params, max_slots=3, page_size=4, max_len=32,
                 plan=plan)
    res = eng.run(trace)
    assert res["stats"]["completed"] == len(trace)
    for req in trace:
        ref = static_generate(model, params, req, plan=plan)
        assert res["tokens"][req.rid] == ref, f"rid {req.rid}"
    assert res["stats"]["warmup_s"] > 0
    assert res["stats"]["steady_tok_per_s"] > 0


def test_engine_matches_static_serve_windowed_paged():
    """Sliding-window layers through the paged path: the window mask must
    clip gathered pages exactly as it clips the dense cache."""
    cfg = configs.reduced(configs.get_config("gemma2-27b")).with_(
        sliding_window=6)
    model = build_model(cfg)
    params = model.init(KEY)
    trace = poisson_trace(3, 0.6, max_prompt=10, max_new=8,
                          vocab=cfg.vocab, seed=1)
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=24)
    res = eng.run(trace)
    for req in trace:
        assert res["tokens"][req.rid] == static_generate(model, params, req)


def test_engine_matches_static_serve_hybrid_dense():
    cfg = configs.reduced(configs.get_config("zamba2-2.7b"))
    model = build_model(cfg)
    params = model.init(KEY)
    trace = poisson_trace(3, 0.6, max_prompt=8, max_new=4,
                          vocab=cfg.vocab, seed=5)
    eng = Engine(model, params, max_slots=2, max_len=24)
    res = eng.run(trace)
    for req in trace:
        assert res["tokens"][req.rid] == static_generate(model, params, req)


def test_engine_page_pressure_reuses_pages():
    """A pool too small for all requests at once forces head-of-line
    waiting; freed pages are reused and results stay correct."""
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    reqs = [Request(rid=i,
                    tokens=np.full(6, 7 * i + 1, np.int32),
                    max_new=4, arrival=0)
            for i in range(4)]
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=12,
                 n_pages=7)
    res = eng.run(reqs)
    assert res["stats"]["completed"] == 4
    for req in reqs:
        assert res["tokens"][req.rid] == static_generate(model, params, req)
    # all pages returned to the pool...
    assert eng.page_pool.free_count == eng.page_pool.n_pages - 1
    assert not eng.page_pool.allocated
    # ...and total allocations exceeded the pool size → pages were reused
    total_pages = sum(len(s.pages) for s in eng._finished.values())
    assert total_pages > eng.page_pool.n_pages - 1


def test_engine_admission_reserves_growth_pages():
    """Regression: admission must hold back pages running sequences will
    still claim via growth — otherwise admitting a newcomer drains the
    pool and a later page-boundary crossing dies mid-decode instead of
    the newcomer simply waiting its turn."""
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    # pool of 4 usable pages; each request needs 3 over its lifetime
    # (bucket 8 = 2 pages, last write at position 8 → a 3rd page), so the
    # second request must wait even though 2 pages are free at its arrival
    reqs = [Request(rid=0, tokens=np.full(6, 3, np.int32), max_new=4,
                    arrival=0),
            Request(rid=1, tokens=np.full(6, 9, np.int32), max_new=4,
                    arrival=1)]
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=12,
                 n_pages=5)
    res = eng.run(reqs)
    assert res["stats"]["completed"] == 2
    for req in reqs:
        assert res["tokens"][req.rid] == static_generate(model, params, req)
    assert eng.page_pool.free_count == 4


def test_engine_rejects_unservable():
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=16)
    with pytest.raises(ValueError, match="needs"):
        eng.submit(Request(rid=0, tokens=np.zeros(14, np.int32), max_new=8))
    vlm = build_model(configs.reduced(configs.get_config("pixtral-12b")))
    with pytest.raises(NotImplementedError):
        Engine(vlm, {}, max_len=16)


# ---------------------------------------------------------------------------
# chunked prefill / preemption / prefix sharing
# ---------------------------------------------------------------------------
def test_page_pool_refcount_fork_swap():
    pool = PagePool(8, page_size=4)
    a = pool.alloc(2)
    pool.retain(a)                       # second sequence maps both pages
    assert pool.ref_count(a[0]) == 2
    assert pool.free(a) == []            # first drop: nothing recycled
    assert pool.free_count == 5
    # copy-on-write: exchange the ref on a shared page for a private one
    pool.retain([a[1]])
    forked = pool.fork(a[1])
    assert forked not in a and pool.ref_count(a[1]) == 1
    assert pool.ref_count(forked) == 1 and pool.forks == 1
    with pytest.raises(ValueError, match="copy-on-write"):
        pool.fork(forked)                # exclusive pages just write
    assert sorted(pool.free(a) + pool.free([forked])) == sorted(
        a + [forked])
    with pytest.raises(ValueError):
        pool.free([a[0]])                # double free still raises
    # swap accounting round-trip
    b = pool.alloc(3)
    assert pool.swap_out(b) == b
    c = pool.swap_in(3)
    assert pool.swapped_out_pages == 3 and pool.swapped_in_pages == 3
    pool.free(c)
    assert pool.free_count == pool.n_pages - 1 and not pool.allocated


def test_prefix_trie_register_match_drop():
    from repro.serving import PrefixTrie

    trie = PrefixTrie(page_size=4)
    toks = np.arange(11, dtype=np.int32)          # 2 full pages + tail
    trie.register(toks, [5, 6], upto_page=2)
    assert trie.match(toks) == [5, 6]
    assert trie.match(toks[:9]) == [5, 6]         # prefix of a chain
    assert trie.match(toks[:7]) == [5]            # only full pages match
    other = toks.copy()
    other[5] += 1                                 # diverges in page 1
    assert trie.match(other) == [5]
    # existing nodes win: re-registering the same chunk keeps page 5
    trie.register(toks, [9, 6], upto_page=1)
    assert trie.match(toks[:4]) == [5]
    trie.drop(5)                                  # freed page → chain gone
    assert trie.match(toks) == []
    assert len(trie) == 1                         # page 6 detached, kept
    trie.drop(6)
    assert len(trie) == 0


def _llama_engine(params=None, **kw):
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    model = build_model(cfg)
    if params is None:
        params = model.init(KEY)
    return cfg, model, params


def test_engine_chunked_prefill_boundary_lengths():
    """Prompt lengths below / at / straddling the chunk size (incl. not
    divisible by it) all produce tokens identical to the fused-prefill
    static reference."""
    cfg, model, params = _llama_engine()
    reqs = [Request(rid=i,
                    tokens=(np.arange(1, p + 1, dtype=np.int32)
                            * (i + 3)) % cfg.vocab,
                    max_new=4, arrival=i)
            for i, p in enumerate([3, 4, 7, 9])]
    eng = Engine(model, params, max_slots=3, page_size=4, max_len=24,
                 prefill_chunk=4)
    res = eng.run(reqs)
    assert res["stats"]["completed"] == len(reqs)
    # 3→1 chunk, 4→1, 7→2, 9→3: splitting actually happened
    assert res["stats"]["prefill_chunks"] == 7
    for req in reqs:
        assert res["tokens"][req.rid] == static_generate(
            model, params, req), f"rid {req.rid}"


def test_engine_preemption_victim_order_and_identity():
    """A pool too small for three concurrent decodes forces preemption:
    the youngest arrival is evicted first (the oldest request is never
    preempted), every sequence completes, and tokens stay bit-identical
    through the swap-out/swap-in cycles."""
    cfg, model, params = _llama_engine()
    reqs = [Request(rid=i,
                    tokens=(np.arange(8, dtype=np.int32)
                            * (3 * i + 7)) % cfg.vocab,
                    max_new=8, arrival=i)
            for i in range(3)]
    eng = Engine(model, params, max_slots=3, page_size=4, max_len=16,
                 n_pages=8, prefill_chunk=4, preemption=True)
    res = eng.run(reqs)
    assert res["stats"]["preemptions"] >= 1
    assert res["stats"]["swapped_in_pages"] >= 1
    assert eng.preempt_log, "pool of 7 usable pages must force eviction"
    # victim ordering: rid 0 arrived first → highest priority → never out
    assert 0 not in eng.preempt_log
    for req in reqs:
        assert res["tokens"][req.rid] == static_generate(
            model, params, req), f"rid {req.rid}"
    assert eng.page_pool.free_count == eng.page_pool.n_pages - 1
    assert not eng.page_pool.allocated


def test_engine_swap_roundtrip_restores_exact_kv():
    """Swap-out then swap-in lands the sequence's KV pages back on device
    byte-for-byte (at fresh page ids)."""
    cfg, model, params = _llama_engine()
    req = Request(rid=0, tokens=np.arange(1, 9, dtype=np.int32),
                  max_new=6, arrival=0)
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=16,
                 prefill_chunk=4, preemption=True)
    eng.submit(req)
    for _ in range(4):                    # prefill chunks + a decode step
        eng.step()
    (seq,) = eng.sched.active.values()
    assert not seq.is_prefilling and len(seq.pages) >= 2
    n = len(seq.pages)
    before = jax.device_get(eng._gather_pages(
        eng.pool, eng._padded_ids(seq.pages)))
    old_pages = list(seq.pages)
    eng._preempt(seq)
    assert eng.sched.swapped and not eng.sched.active
    eng._swap_in(seq)
    assert len(seq.pages) == len(old_pages)
    after = jax.device_get(eng._gather_pages(
        eng.pool, eng._padded_ids(seq.pages)))
    for k in ("k", "v"):
        np.testing.assert_array_equal(
            np.asarray(before[k][:, :, :n], np.float32),
            np.asarray(after[k][:, :, :n], np.float32))
    res = eng.run(warmup=False)           # drive to completion
    assert res["tokens"][0] == static_generate(model, params, req)


def test_engine_prefix_sharing_saves_pages():
    """Overlapping requests with one common prefix map its pages once:
    fresh prompt-page allocations stay strictly below the sum of prompt
    pages, tokens match the static reference, and the trie and pool are
    empty after the trace drains."""
    from repro.serving import shared_prefix_trace

    cfg, model, params = _llama_engine()
    trace = shared_prefix_trace(4, prefix_len=8, max_prompt=12, max_new=6,
                                vocab=cfg.vocab, seed=2, arrival_gap=3)
    eng = Engine(model, params, max_slots=3, page_size=4, max_len=24,
                 prefill_chunk=4, prefix_sharing=True)
    res = eng.run(trace)
    s = res["stats"]
    assert s["shared_prompt_pages"] > 0
    assert s["prompt_pages_fresh"] < s["prompt_pages_total"]
    for req in trace:
        assert res["tokens"][req.rid] == static_generate(
            model, params, req), f"rid {req.rid}"
    assert not eng.page_pool.allocated
    assert len(eng.trie) == 0


def test_engine_cow_fork_refcount_accounting():
    """Identical page-aligned prompts share every prompt page; the
    sharer's recompute of its last token copy-on-write-forks the final
    shared page.  No page leaks or double frees survive the trace (the
    pool raises on either), and the allocator drains clean."""
    cfg, model, params = _llama_engine()
    tok = (np.arange(8, dtype=np.int32) * 5 + 2) % cfg.vocab
    reqs = [Request(rid=i, tokens=tok.copy(), max_new=6, arrival=i * 3)
            for i in range(3)]
    eng = Engine(model, params, max_slots=3, page_size=4, max_len=16,
                 prefill_chunk=4, prefix_sharing=True)
    res = eng.run(reqs)
    s = res["stats"]
    assert s["cow_forks"] >= 1
    assert s["shared_prompt_pages"] >= 2
    ref = static_generate(model, params, reqs[0])
    for req in reqs:                      # identical prompts, one ref
        assert res["tokens"][req.rid] == ref
    assert eng.page_pool.forks == s["cow_forks"]
    assert eng.page_pool.free_count == eng.page_pool.n_pages - 1
    assert not eng.page_pool.allocated
    assert len(eng.trie) == 0


def test_engine_cow_fork_under_pool_pressure():
    """Regression: a COW fork whose capacity hunt preempts the page's
    only other holder must skip the fork (the page became private) —
    forking a refcount-1 page raises.  Donor decoding on 3 of 4 usable
    pages; two identical sharers admitted together: the first fork takes
    the last free page, the second triggers preemption of the donor,
    which drops the target page's refcount to 1."""
    cfg, model, params = _llama_engine()
    tok = (np.arange(8, dtype=np.int32) * 11 + 3) % cfg.vocab
    reqs = [Request(rid=0, tokens=tok.copy(), max_new=8, arrival=0),
            Request(rid=1, tokens=tok.copy(), max_new=6, arrival=3),
            Request(rid=2, tokens=tok.copy(), max_new=6, arrival=3)]
    eng = Engine(model, params, max_slots=3, page_size=4, max_len=16,
                 n_pages=5, prefill_chunk=4, preemption=True,
                 prefix_sharing=True)
    res = eng.run(reqs)
    assert res["stats"]["completed"] == 3
    assert res["stats"]["cow_forks"] >= 1
    assert res["stats"]["preemptions"] >= 1
    ref = static_generate(model, params, reqs[0])[:6]
    for req in reqs:
        assert res["tokens"][req.rid][:6] == ref[:len(
            res["tokens"][req.rid][:6])]
        assert res["tokens"][req.rid] == static_generate(
            model, params, req), f"rid {req.rid}"
    assert not eng.page_pool.allocated and len(eng.trie) == 0


def test_engine_chunked_rejects_prompt_past_attn_chunk():
    """Chunked prefill's single-block attention is only bit-identical to
    the fused reference for prompts within one attention chunk — longer
    prompts must be rejected up front, not silently diverge."""
    cfg, model, params = _llama_engine()
    eng = Engine(model, params, max_slots=2, page_size=4,
                 max_len=cfg.attn_chunk + 32, prefill_chunk=8)
    with pytest.raises(ValueError, match="attn_chunk"):
        eng.submit(Request(rid=0,
                           tokens=np.zeros(cfg.attn_chunk + 1, np.int32),
                           max_new=2))


def test_engine_feature_flag_validation():
    cfg, model, params = _llama_engine()
    with pytest.raises(ValueError, match="prefix sharing"):
        Engine(model, params, max_len=16, prefix_sharing=True)
    hybrid = build_model(configs.reduced(configs.get_config("zamba2-2.7b")))
    with pytest.raises(ValueError, match="paged-KV"):
        Engine(hybrid, {}, max_len=16, prefill_chunk=4)


# ---------------------------------------------------------------------------
# drivers / reporting
# ---------------------------------------------------------------------------
def test_serve_engine_mode_end_to_end():
    from repro.launch import serve

    summary = serve.main([
        "--arch", "llama3.2-1b", "--reduced", "--engine",
        "--requests", "3", "--prompt-len", "6", "--gen", "3",
        "--max-slots", "2", "--page-size", "4"])
    assert summary["engine"] is True
    assert summary["completed"] == 3
    # compile/warmup reported separately from steady-state throughput
    assert summary["warmup_s"] > 0
    assert summary["steady_tok_per_s"] > 0
    assert "p50_latency_s" in summary and "p99_latency_s" in summary


def test_serve_static_reports_warmup_separately():
    from repro.launch import serve

    summary = serve.main([
        "--arch", "llama3.2-1b", "--reduced", "--batch", "2",
        "--prompt-len", "8", "--gen", "4"])
    assert summary["warmup_s"] > 0
    assert summary["steady_tok_per_s"] > 0
    # the steady number excludes the first (compiling) step, so it beats
    # the folded-in average by construction
    assert summary["steady_tok_per_s"] >= summary["decode_tok_per_s"]


def test_stacked_lead_bytes_accounting():
    """Regression: nbytes_dense ignored stacked lead dims, overstating
    stacked leaves' compression ratio by prod(lead)."""
    from repro.core import formats, pruning

    w = pruning.magnitude_prune(
        jax.random.normal(KEY, (2, 128, 128), jnp.float32), 0.3)
    p = formats.pack_tiled_csc(w)
    assert p.nbytes_dense() == 2 * 128 * 128 * 2
    assert p.nbytes_compressed() < p.nbytes_dense()


def test_run_stats_keys_all_in_glossary():
    """Every counter `Engine.run()` emits must be documented in the
    docs/serving.md glossary — a new stat without a glossary row fails
    here, not in a doc review six PRs later."""
    doc = (pathlib.Path(__file__).resolve().parent.parent
           / "docs" / "serving.md").read_text()
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    req = Request(rid=0, tokens=np.arange(1, 5, dtype=np.int32),
                  max_new=2, arrival=0)
    eng = Engine(model, params, max_slots=1, page_size=4, max_len=8)
    res = eng.run([req])
    missing = [k for k in res["stats"] if f"`{k}`" not in doc]
    assert not missing, (
        f"stats keys missing from the docs/serving.md glossary: {missing}")


def test_example_serve_decode_imports():
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "examples" / "serve_decode.py")
    spec = importlib.util.spec_from_file_location("serve_decode", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert callable(mod.main) and callable(mod.demo_engine)
