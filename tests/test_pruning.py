"""Pruning mechanics: densities, structures, layerwise profiles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # CI installs hypothesis; bare
    from _hypothesis_stub import given, settings, st  # noqa: E501  envs skip the property tests


from repro.core import formats, pruning

KEY = jax.random.PRNGKey(7)


@settings(max_examples=20, deadline=None)
@given(density=st.floats(0.05, 0.95), seed=st.integers(0, 2**16))
def test_magnitude_density_target(density, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 96))
    out = pruning.magnitude_prune(w, density)
    got = formats.density(out)
    assert abs(got - density) < 0.02 + 2.0 / w.size
    # kept values are exactly the original values
    mask = np.asarray(out) != 0
    np.testing.assert_allclose(np.asarray(out)[mask], np.asarray(w)[mask])


def test_magnitude_keeps_largest():
    w = jnp.arange(1.0, 101.0).reshape(10, 10)
    out = pruning.magnitude_prune(w, 0.25)
    kept = np.sort(np.asarray(out).reshape(-1))[-25:]
    np.testing.assert_allclose(kept, np.arange(76.0, 101.0))


@pytest.mark.parametrize("n,m", [(2, 4), (4, 8), (1, 8)])
def test_nm_structure(n, m):
    w = jax.random.normal(KEY, (m * 10, 16))
    out = np.asarray(pruning.nm_prune(w, n=n, m=m, axis=0))
    groups = out.reshape(10, m, 16)
    nnz = (groups != 0).sum(axis=1)
    assert (nnz <= n).all()


def test_block_prune_structure():
    w = jax.random.normal(KEY, (128, 256))
    out = np.asarray(pruning.block_prune(w, 0.5, block=(8, 128)))
    blocks = out.reshape(16, 8, 2, 128)
    alive = np.abs(blocks).sum(axis=(1, 3)) > 0
    assert abs(alive.mean() - 0.5) < 0.1
    # alive blocks untouched
    mask = np.repeat(np.repeat(alive, 8, 0).reshape(128, 2), 128, 1)
    np.testing.assert_allclose(out[mask], np.asarray(w)[mask])


def test_prune_tree_respects_structure_and_small_leaves():
    params = {
        "w_big": jax.random.normal(KEY, (128, 128)),
        "norm": jnp.ones((128,)),
        "tiny": jax.random.normal(KEY, (4, 4)),
    }
    out = pruning.prune_tree(params, 0.3, min_size=1024)
    assert abs(formats.density(out["w_big"]) - 0.3) < 0.05
    np.testing.assert_allclose(np.asarray(out["norm"]),
                               np.asarray(params["norm"]))
    np.testing.assert_allclose(np.asarray(out["tiny"]),
                               np.asarray(params["tiny"]))


def test_prune_tree_layerwise_callable():
    params = {"a": {"w_down": jax.random.normal(KEY, (64, 64))},
              "b": {"w_down": jax.random.normal(KEY, (64, 64))}}
    dens = lambda name: 0.1 if ".a" in name else 0.5
    out = pruning.prune_tree(params, dens, min_size=1000)
    assert formats.density(out["a"]["w_down"]) < 0.2
    assert formats.density(out["b"]["w_down"]) > 0.4


def test_paper_profiles_match_table3():
    p = pruning.PAPER_PROFILES
    assert abs(np.mean(p["alexnet_conv"].layer_densities) - 0.41) < 0.05
    assert abs(np.mean(p["vgg16_conv"].layer_densities) - 0.33) < 0.05
    assert abs(np.mean(p["bert_squad"].layer_densities) - 0.33) < 0.03
    assert abs(np.mean(p["bert_mnli"].layer_densities) - 0.13) < 0.03
    assert p["bert_squad"].input_density == 1.0
    # SQuAD per-layer range 0.04-0.5 (Section IV-D)
    assert min(p["bert_squad"].layer_densities) >= 0.04
    assert max(p["bert_squad"].layer_densities) <= 0.5
