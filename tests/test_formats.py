"""Format round-trips, byte accounting, gradients — incl. hypothesis sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # CI installs hypothesis; bare
    from _hypothesis_stub import given, settings, st  # noqa: E501  envs skip the property tests


from repro.core import formats, pruning


def _rand_sparse(seed, shape, density, dtype=jnp.float32):
    return pruning.random_sparse(jax.random.PRNGKey(seed), shape, density,
                                 dtype)


# ---------------------------------------------------------------------------
# TiledCSC
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,tile,density", [
    ((128, 128), (128, 128), 0.3),
    ((300, 260), (128, 128), 0.15),
    ((64, 200), (64, 128), 0.5),
    ((513, 129), (128, 128), 0.05),
])
def test_tiled_csc_roundtrip(shape, tile, density):
    w = _rand_sparse(0, shape, density)
    p = formats.pack_tiled_csc(w, tile=tile)
    np.testing.assert_allclose(np.asarray(p.to_dense()), np.asarray(w))


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(8, 200), n=st.integers(8, 200),
    density=st.floats(0.02, 0.95), seed=st.integers(0, 2**16),
)
def test_tiled_csc_roundtrip_hypothesis(k, n, density, seed):
    w = _rand_sparse(seed, (k, n), density)
    p = formats.pack_tiled_csc(w, tile=(128, 128))
    np.testing.assert_allclose(np.asarray(p.to_dense()), np.asarray(w))


def test_tiled_csc_leading_dims():
    w = _rand_sparse(1, (3, 2, 200, 130), 0.25)
    p = formats.pack_tiled_csc(w, tile=(128, 128))
    assert p.lead == (3, 2)
    np.testing.assert_allclose(np.asarray(p.to_dense()), np.asarray(w))
    # tree_map slicing (what lax.scan does) stays consistent
    p1 = jax.tree_util.tree_map(lambda t: t[1], p)
    np.testing.assert_allclose(np.asarray(p1.to_dense()), np.asarray(w[1]))


def test_tiled_csc_lossy_cap_keeps_largest():
    w = _rand_sparse(2, (128, 128), 0.9)
    p = formats.pack_tiled_csc(w, cap=16)
    d = np.asarray(p.to_dense())
    assert (np.count_nonzero(d, axis=0) <= 16).all()
    # kept entries are a subset of the original with the largest magnitudes
    col = 0
    orig = np.asarray(w)[:, col]
    kept = np.nonzero(d[:, col])[0]
    dropped = np.setdiff1d(np.nonzero(orig)[0], kept)
    if len(dropped) and len(kept):
        assert np.abs(orig[kept]).min() >= np.abs(orig[dropped]).max() - 1e-6


def test_tiled_csc_grad_exact_on_mask():
    w = _rand_sparse(3, (256, 128), 0.3)
    p = formats.pack_tiled_csc(w)
    g = jax.grad(lambda q: jnp.sum(q.to_dense() ** 2), allow_int=True)(p)
    np.testing.assert_allclose(np.asarray(g.vals), 2 * np.asarray(p.vals),
                               rtol=1e-5, atol=1e-6)


def test_tiled_csc_bytes_paper_encoding():
    w = _rand_sparse(4, (256, 256), 0.25)
    p = formats.pack_tiled_csc(w)
    # 16-bit value + 8-bit index per slot
    assert p.nbytes_compressed() == p.vals.size * 3
    assert p.nbytes_dense() == 256 * 256 * 2
    assert p.compression_ratio() < 1.0


# ---------------------------------------------------------------------------
# BlockCSR
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("density", [0.1, 0.4, 0.8])
def test_block_csr_roundtrip(density):
    w = pruning.block_prune(_rand_sparse(5, (300, 260), 0.8), density)
    p = formats.pack_block_csr(w)
    np.testing.assert_allclose(np.asarray(p.to_dense()), np.asarray(w))
    nz_frac = float(jnp.count_nonzero(p.tile_nnz)) / p.tile_nnz.size
    assert nz_frac <= 1.0


def test_block_csr_leading_dims():
    w = pruning.block_prune(_rand_sparse(6, (256, 128), 0.9), 0.5)
    ws = jnp.stack([w, w * 2.0])
    p = formats.pack_block_csr(ws)
    np.testing.assert_allclose(np.asarray(p.to_dense()), np.asarray(ws))


# ---------------------------------------------------------------------------
# Bitmap + pointer CSC
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(k=st.integers(4, 100), n=st.integers(4, 100),
       density=st.floats(0.05, 0.9), seed=st.integers(0, 2**16))
def test_bitmap_roundtrip(k, n, density, seed):
    w = _rand_sparse(seed, (k, n), density)
    b = formats.pack_bitmap(w)
    np.testing.assert_allclose(np.asarray(b.to_dense()), np.asarray(w))


def test_csc_pointer_roundtrip_and_bytes():
    w = np.asarray(_rand_sparse(7, (120, 80), 0.2))
    csc = formats.pack_csc(w)
    np.testing.assert_allclose(formats.unpack_csc(csc), w)
    nnz = csc["values"].shape[0]
    assert formats.csc_nbytes(csc) == (nnz * 24 + 81 * 32) // 8
    # compressed beats dense below the paper's breakeven (~2/3 density)
    assert formats.csc_nbytes(csc) < w.size * 2


def test_density_helper():
    assert formats.density(np.zeros((4, 4))) == 0.0
    assert formats.density(np.ones((4, 4))) == 1.0


# ---------------------------------------------------------------------------
# Quantized value storage (int8 / fp8 / codebook)
# ---------------------------------------------------------------------------
QMODES = [m for m in ("int8", "fp8", "codebook")
          if m != "fp8" or formats.fp8_dtype() is not None]


def _pack(fmt, w, qmode="none"):
    if fmt == "tiled_csc":
        return formats.pack_tiled_csc(w, qmode=qmode)
    return formats.pack_block_csr(w, qmode=qmode)


@pytest.mark.parametrize("qmode", QMODES)
@pytest.mark.parametrize("fmt", ["tiled_csc", "block_csr"])
def test_quantized_pack_preserves_sparsity_and_shrinks(fmt, qmode):
    """Quantized packs keep the zero pattern exactly, bound the value
    error, and strictly shrink the byte footprint vs the fp pack."""
    w = _rand_sparse(11, (256, 300), 0.3)
    if fmt == "block_csr":
        w = pruning.block_prune(_rand_sparse(11, (256, 300), 0.8), 0.3)
    fp = _pack(fmt, w)
    q = _pack(fmt, w, qmode=qmode)
    dq = np.asarray(q.to_dense())
    dense = np.asarray(w)
    # zeros stay exactly zero (padding + pruned slots map to code 0)
    assert (dq[dense == 0] == 0).all()
    absmax = np.abs(dense).max()
    err = np.abs(dq - dense).max()
    if qmode == "int8":
        assert err <= absmax / 253  # half-step of absmax/127 per-tile scale
    elif qmode == "fp8":
        # e4m3: 3 mantissa bits -> half-ulp rel err 2^-4, plus granularity
        assert err <= 0.07 * absmax
    else:  # codebook: values snap to the 16-entry shared table
        book = np.asarray(q.codebook).ravel()
        nz = dq[dense != 0]
        assert np.isin(nz, book).all()
        rel = np.linalg.norm(dq - dense) / np.linalg.norm(dense)
        assert rel < 0.5
    assert q.nbytes_compressed() < fp.nbytes_compressed()
    assert q.qmode == qmode


@settings(max_examples=20, deadline=None)
@given(k=st.integers(16, 160), n=st.integers(16, 160),
       density=st.floats(0.05, 0.9), seed=st.integers(0, 2**16))
def test_int8_quant_roundtrip_error_bound_hypothesis(k, n, density, seed):
    """Property: per-tile int8 scaling bounds elementwise error by half a
    quantization step of the tile's absmax, at any shape/density."""
    w = _rand_sparse(seed, (k, n), density)
    p = formats.pack_tiled_csc(w, qmode="int8")
    dq = np.asarray(p.to_dense())
    dense = np.asarray(w)
    assert np.abs(dq - dense).max() <= max(np.abs(dense).max(), 1e-30) / 253
    assert (dq[dense == 0] == 0).all()


@pytest.mark.parametrize("qmode", QMODES)
def test_quantized_stacked_lead_dims(qmode):
    """Stacked (lead-dim) packs quantize per slice and slice consistently
    under tree_map — scale is per (slice, tile), codebook per slice."""
    w = _rand_sparse(12, (3, 128, 130), 0.25)
    p = formats.pack_tiled_csc(w, qmode=qmode)
    p1 = jax.tree_util.tree_map(lambda t: t[1], p)
    np.testing.assert_allclose(np.asarray(p1.to_dense()),
                               np.asarray(p.to_dense())[1])


def test_quantize_packed_identity_and_double_quant_rejected():
    w = _rand_sparse(13, (128, 128), 0.3)
    p = formats.pack_tiled_csc(w)
    assert formats.quantize_packed(p, "none") is p
    q = formats.quantize_packed(p, "int8")
    assert formats.quantize_packed(q, "int8") is q
    with pytest.raises(ValueError, match="already quantized"):
        formats.quantize_packed(q, "codebook")


def test_quantized_grad_flows_into_scale():
    """Training gradients reach the quantization side bands: d/dscale of a
    loss over to_dense() is the chain-rule sum over the tile's codes."""
    w = _rand_sparse(14, (128, 128), 0.3)
    q = formats.pack_tiled_csc(w, qmode="int8")
    g = jax.grad(lambda c: jnp.sum(c.to_dense()), allow_int=True)(q)
    codes = np.asarray(q.vals, np.float32)
    np.testing.assert_allclose(np.asarray(g.scale),
                               codes.sum(axis=(-2, -1)), rtol=1e-5)
