"""MoE routing invariants and shared-expert path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe

KEY = jax.random.PRNGKey(3)


def _spec(**kw):
    base = dict(n_experts=8, n_experts_padded=8, top_k=2, d_model=32,
                d_ff=64, capacity_factor=2.0)
    base.update(kw)
    return moe.MoESpec(**base)


def test_moe_output_shape_and_aux():
    spec = _spec()
    params = moe.init_moe(KEY, spec, dtype=jnp.float32)
    x = jax.random.normal(KEY, (2, 16, 32), jnp.float32)
    y, aux = moe.moe_mlp(params, x, spec)
    assert y.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) >= 0.0
    assert not bool(jnp.any(jnp.isnan(y)))


def test_moe_padded_experts_get_no_tokens():
    spec = _spec(n_experts=6, n_experts_padded=8)
    params = moe.init_moe(KEY, spec, dtype=jnp.float32)
    x = jax.random.normal(KEY, (1, 64, 32), jnp.float32)
    logits = jnp.dot(x.reshape(-1, 32), params["router"])
    pad_mask = jnp.arange(8) >= 6
    masked = jnp.where(pad_mask[None], -1e30, logits)
    probs = jax.nn.softmax(masked, -1)
    _, ids = jax.lax.top_k(probs, spec.top_k)
    assert int(jnp.max(ids)) < 6


def test_moe_single_expert_equals_mlp():
    """With one expert and top-1 routing the MoE == that expert's MLP."""
    spec = _spec(n_experts=1, n_experts_padded=1, top_k=1,
                 capacity_factor=8.0)
    params = moe.init_moe(KEY, spec, dtype=jnp.float32)
    x = jax.random.normal(KEY, (1, 8, 32), jnp.float32)
    y, _ = moe.moe_mlp(params, x, spec)
    xt = x.reshape(-1, 32)
    h = jax.nn.silu(xt @ params["w_gate"][0]) * (xt @ params["w_up"][0])
    expect = (h @ params["w_down"][0]).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               atol=2e-4, rtol=1e-3)


def test_moe_capacity_drops_dont_nan():
    spec = _spec(capacity_factor=0.01)   # force drops
    params = moe.init_moe(KEY, spec, dtype=jnp.float32)
    x = jax.random.normal(KEY, (2, 64, 32), jnp.float32)
    y, _ = moe.moe_mlp(params, x, spec)
    assert not bool(jnp.any(jnp.isnan(y)))


def test_shared_expert_contributes():
    spec = _spec(n_shared=1, d_shared_ff=64)
    params = moe.init_moe(KEY, spec, dtype=jnp.float32)
    x = jax.random.normal(KEY, (1, 8, 32), jnp.float32)
    y_with, _ = moe.moe_mlp(params, x, spec)
    params2 = dict(params)
    params2["shared"] = jax.tree_util.tree_map(jnp.zeros_like,
                                               params["shared"])
    y_without, _ = moe.moe_mlp(params2, x, spec)
    assert float(jnp.abs(y_with - y_without).max()) > 1e-5


def test_pad_experts_helper():
    assert moe.pad_experts(60, 16) == 64
    assert moe.pad_experts(32, 16) == 32
    assert moe.pad_experts(7, 4) == 8
