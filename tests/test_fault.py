"""Fault tolerance: watchdog, elastic mesh math, restart-from-checkpoint."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.runtime import fault


class FakeDev:
    def __init__(self, i):
        self.id = i

    def __repr__(self):
        return f"d{self.id}"


def test_surviving_mesh_shrinks_data_axis():
    devs = [FakeDev(i) for i in range(32)]
    mesh = fault.surviving_mesh(devs, failed_ids={3, 17}, model_axis=4)
    assert mesh.shape["model"] == 4
    assert mesh.shape["data"] == 7          # 30 survivors → 7×4 = 28 used
    ids = {d.id for d in mesh.devices.reshape(-1)}
    assert not ids & {3, 17}


def test_surviving_mesh_insufficient_raises():
    devs = [FakeDev(i) for i in range(4)]
    with pytest.raises(RuntimeError):
        fault.surviving_mesh(devs, failed_ids={0, 1}, model_axis=4)


def test_straggler_watchdog():
    wd = fault.StragglerWatchdog(factor=3.0, warmup=3)
    for _ in range(5):
        assert not wd.observe(1.0)
    assert wd.observe(10.0)
    assert wd.strays == 1
    assert not wd.observe(1.0)
    assert wd.strays == 0


def test_resilient_runner_restarts_from_checkpoint(tmp_path):
    state = {"x": jnp.zeros(())}
    ck = Checkpointer(tmp_path)
    ck.save(0, state)
    calls = {"n": 0}

    def step_fn(step):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("simulated device failure")
        return {"loss": 1.0}

    loaded = {}
    runner = fault.ResilientRunner(
        step_fn, ck, fault.FaultConfig(ckpt_every=100, max_restarts=1),
        state_of=lambda: state,
        load_state=lambda s: loaded.update(s))
    res = runner.run_step(1)
    assert res.restarted and res.metrics["loss"] == 1.0
    assert "x" in loaded                      # state was restored
    assert calls["n"] == 2                    # deterministic replay


def test_resilient_runner_gives_up_after_max_restarts(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(0, {"x": jnp.zeros(())})

    def bad_step(step):
        raise RuntimeError("persistent failure")

    runner = fault.ResilientRunner(
        bad_step, ck, fault.FaultConfig(max_restarts=0),
        state_of=lambda: {"x": jnp.zeros(())}, load_state=lambda s: None)
    with pytest.raises(RuntimeError):
        runner.run_step(1)


def test_runner_checkpoints_on_schedule(tmp_path):
    ck = Checkpointer(tmp_path)
    state = {"x": jnp.zeros(())}
    runner = fault.ResilientRunner(
        lambda step: {"loss": 0.0}, ck,
        fault.FaultConfig(ckpt_every=2),
        state_of=lambda: state, load_state=lambda s: None)
    for s in range(5):
        runner.run_step(s)
    ck.wait()
    assert 4 in ck.all_steps()
