"""Pallas kernel sweeps vs the pure-jnp oracles (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats, pruning
from repro.kernels import ops, ref
from repro.kernels.decompress import decompress_pallas
from repro.kernels.sod_matmul import sod_matmul_pallas

KEY = jax.random.PRNGKey(42)


def _case(shape, density, dtype=jnp.float32, seed=0):
    w = pruning.random_sparse(jax.random.fold_in(KEY, seed), shape, density,
                              dtype)
    return w


@pytest.mark.parametrize("kn,m,density,tile", [
    ((256, 256), 128, 0.3, (128, 128)),
    ((300, 260), 77, 0.15, (128, 128)),
    ((512, 384), 4, 0.5, (128, 128)),
    ((200, 130), 33, 0.08, (64, 128)),
    ((128, 640), 256, 0.9, (128, 128)),
])
def test_sod_matmul_shapes(kn, m, density, tile):
    w = _case(kn, density)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (m, kn[0]), jnp.float32)
    p = formats.pack_tiled_csc(w, tile=tile)
    y = ops.sod_matmul(x, p, impl="pallas")
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.sod_matmul_ref(x, p)),
        atol=5e-4, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sod_matmul_dtypes(dtype):
    w = _case((256, 256), 0.3, dtype)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (64, 256)).astype(dtype)
    p = formats.pack_tiled_csc(w)
    y = ops.sod_matmul(x, p, impl="pallas")
    yr = ref.sod_matmul_ref(x, p)
    tol = 5e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("density", [0.05, 0.3, 0.7])
def test_block_matmul_sweep(density):
    w = pruning.block_prune(_case((384, 256), 0.9), density)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (96, 384))
    p = formats.pack_block_csr(w)
    y = ops.sod_matmul(x, p, impl="pallas")
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(ref.block_matmul_ref(x, p)),
        atol=5e-4, rtol=1e-4)


def test_block_matmul_skips_zero_tiles():
    # zero lower half of macro tiles → tile_nnz rows are 0 there
    w = _case((256, 256), 0.5)
    w = w.at[128:].set(0)
    p = formats.pack_block_csr(w)
    assert int(jnp.count_nonzero(p.tile_nnz[1])) == 0
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (32, 256))
    y = ops.sod_matmul(x, p, impl="pallas")
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               atol=5e-4, rtol=1e-4)


@pytest.mark.parametrize("shape,density", [
    ((128, 128), 0.2), ((300, 260), 0.4), ((64, 512), 0.05)])
def test_decompress_kernel(shape, density):
    p = formats.pack_tiled_csc(_case(shape, density))
    d = ops.decompress(p)
    np.testing.assert_allclose(np.asarray(d), np.asarray(p.to_dense()),
                               atol=1e-6)


def test_sod_matmul_nd_batch_and_bypass():
    w = _case((300, 260), 0.2)
    p = formats.pack_tiled_csc(w)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (2, 5, 300))
    y = ops.sod_matmul(x, p, impl="pallas")
    assert y.shape == (2, 5, 260)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                               atol=5e-4, rtol=1e-4)
    # dense bypass
    yd = ops.sod_matmul(x, w)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(x @ w),
                               atol=5e-4, rtol=1e-4)


def test_kernel_rejects_bad_shapes():
    p = formats.pack_tiled_csc(_case((256, 256), 0.3))
    x = jax.random.normal(KEY, (8, 200))      # wrong K
    with pytest.raises(ValueError):
        ops.sod_matmul(x, p, impl="pallas")


def test_cost_estimate_reflects_compression():
    """The kernel's advertised bytes must scale with density (the paper's
    memory-traffic claim, consumed by the roofline)."""
    x = jax.random.normal(KEY, (128, 512))
    lo = formats.pack_tiled_csc(_case((512, 512), 0.1, seed=7))
    hi = formats.pack_tiled_csc(_case((512, 512), 0.8, seed=8))
    assert lo.nbytes_compressed() < 0.35 * hi.nbytes_compressed()
    assert lo.nbytes_compressed() < lo.nbytes_dense()
