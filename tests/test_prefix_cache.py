"""Persistent multi-tier prefix cache: tier lifecycle, restart survival
from disk, admission reclaim under pressure, and cache-off identity."""
import pathlib
import sys

import jax
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # CI installs hypothesis; bare
    from _hypothesis_stub import given, settings, st  # noqa: E501  envs skip the property tests

from repro import configs
from repro.models.model import build_model
from repro.serving import (
    Engine,
    PagePool,
    PrefixCache,
    repeated_prompt_trace,
    static_generate,
)

KEY = jax.random.PRNGKey(0)


def _gather_stub(page):
    """Deterministic page-keyed host snapshot, stands in for the engine's
    jitted per-page gather in unit tests."""
    return {"k": np.full((4,), page, np.float32),
            "v": np.full((4,), -page, np.float32)}


# ---------------------------------------------------------------------------
# unit: tier lifecycle against a bare pool
# ---------------------------------------------------------------------------
def test_prefix_cache_key_pins_full_context():
    """Keys hash the *entire* token prefix: two chunks with identical
    tokens but different histories never alias."""
    a = np.arange(8, dtype=np.int32)
    b = a.copy()
    b[0] += 1                       # differs only before the last chunk
    assert PrefixCache.key(a) != PrefixCache.key(b)
    assert PrefixCache.key(a) == PrefixCache.key(list(a))


def test_prefix_cache_tier_lifecycle(tmp_path):
    """hold -> budget demotion (leaf-first) -> host fetch -> disk
    write-through, with the pool's refcounts balanced throughout."""
    pool = PagePool(8, page_size=4)
    dropped = []
    cache = PrefixCache(pool, page_bytes=32, budget_bytes=2 * 32,
                        cache_dir=tmp_path, gather=_gather_stub,
                        on_page_freed=dropped.append)
    pages = pool.alloc(3)           # a completed sequence's chain
    keys = [f"k{j}" for j in range(3)]
    # completion holds leaf-first so parents end up MRU-newer than
    # children — demotions then peel the leaf, never orphan a parent
    for j in (2, 1, 0):
        cache.hold(keys[j], pages[j])
    # budget is 2 pages: the third hold demoted the LRU entry — the leaf,
    # because it was held first.  The sequence still references the page,
    # so only the cache's ref dropped (no free, no trie notification).
    assert cache.held_pages == (pages[1], pages[0])
    assert dropped == []
    pool.free(pages)                # sequence completes: cache sole holder
    assert cache.bytes_by_tier()["hbm"] == 2 * 32
    assert cache.bytes_by_tier()["disk"] > 0
    assert cache.peek(keys[2]) == "host"
    # host fetch round-trips the gathered bytes and consumes the entry
    kv, tier = cache.fetch(keys[2])
    assert tier == "host"
    np.testing.assert_array_equal(kv["k"], np.full((4,), pages[2]))
    assert cache.peek(keys[2]) == "disk"      # write-through persisted
    kv, tier = cache.fetch(keys[2])
    assert tier == "disk"
    np.testing.assert_array_equal(kv["v"], np.full((4,), -pages[2]))
    # reclaim demotes LRU-first: child before parent
    assert cache.reclaimable() == 2
    assert cache.reclaim(2) == 2
    assert dropped == [pages[1], pages[0]]
    assert not cache.held_pages
    assert pool.free_count == pool.n_pages - 1
    # a fresh cache on the same dir inherits the spilled chunks
    again = PrefixCache(pool, page_bytes=32, cache_dir=tmp_path,
                        gather=_gather_stub)
    assert again.peek(keys[0]) == "disk"
    assert again.bytes_by_tier()["disk"] == cache.bytes_by_tier()["disk"]


def test_prefix_cache_hold_is_idempotent_and_touch_reorders():
    pool = PagePool(8, page_size=4)
    cache = PrefixCache(pool, page_bytes=32, budget_bytes=4 * 32,
                        gather=_gather_stub)
    a, b = pool.alloc(2)
    cache.hold("a", a)
    cache.hold("b", b)
    assert pool.ref_count(a) == 2
    cache.hold("a", a)              # re-hold = LRU touch, not a new ref
    assert pool.ref_count(a) == 2
    assert cache.held_pages == (b, a)
    cache.touch(b)
    assert cache.held_pages == (a, b)
    cache.flush()
    assert pool.ref_count(a) == 1 and pool.ref_count(b) == 1


@settings(max_examples=40, deadline=None)
@given(st.integers(6, 16), st.integers(0, 4),
       st.lists(st.tuples(st.integers(0, 7), st.integers(0, 10**6)),
                min_size=1, max_size=100))
def test_prefix_cache_pool_partition_property(n_pages, budget_pages,
                                              program):
    """Random programs mixing sequence alloc/retain/free with cache
    hold/touch/reclaim/fetch/flush: the cache holds exactly one pool
    reference per HBM entry, the HBM tier never exceeds its byte budget,
    reclaimable() counts exactly the sole-holder entries, demoted chunks
    round-trip their bytes through the host tier, and the pool's
    free+live partition invariant survives everything."""
    pool = PagePool(n_pages, page_size=4)
    freed_log = []
    cache = PrefixCache(pool, page_bytes=32,
                        budget_bytes=budget_pages * 32,
                        gather=_gather_stub,
                        on_page_freed=freed_log.append)
    seq_refs: dict[int, int] = {}   # model: sequence-side refcounts only
    for op, r in program:
        live = sorted(seq_refs)
        held = list(cache.held_pages)
        if op == 0:                                 # admit: alloc pages
            k = r % (pool.free_count + 1)
            for p in pool.alloc(k):
                seq_refs[p] = 1
        elif op == 1 and live:                      # share (cow/trie)
            p = live[r % len(live)]
            pool.retain([p])
            seq_refs[p] += 1
        elif op == 2 and live:                      # sequence completes
            p = live[r % len(live)]
            freed = pool.free([p])
            seq_refs[p] -= 1
            if seq_refs[p] == 0:
                del seq_refs[p]
                assert bool(freed) == (not cache.held(p))
        elif op == 3 and live:                      # retention hold
            p = live[r % len(live)]
            cache.hold(f"k{p}", p)
        elif op == 4 and held:                      # admission hit: touch
            cache.touch(held[r % len(held)])
        elif op == 5 and held:                      # admission pressure
            want = r % 3 + 1
            got = cache.reclaim(want)
            assert got <= want
        elif op == 6 and cache.host_keys:           # promotion: fetch
            key = cache.host_keys[r % len(cache.host_keys)]
            kv, tier = cache.fetch(key)
            assert tier == "host"
            np.testing.assert_array_equal(
                kv["k"], np.full((4,), int(key[1:]), np.float32))
        elif op == 7:                               # drain
            cache.flush()
        # invariants after every operation
        held_set = set(cache.held_pages)
        assert len(held_set) == len(cache.held_pages)
        assert len(held_set) * 32 <= max(cache.budget_bytes, 0) or not held_set
        for p in held_set:
            assert cache.held(p)
            assert p in pool.allocated
            assert pool.ref_count(p) == seq_refs.get(p, 0) + 1
        for p, c in seq_refs.items():
            if p not in held_set:
                assert pool.ref_count(p) == c
        assert cache.reclaimable() == sum(
            1 for p in held_set if p not in seq_refs)
        assert pool.free_count + len(pool.allocated) == pool.n_pages - 1
        assert cache.bytes_by_tier()["hbm"] == len(held_set) * 32
    # drain: release every sequence ref, flush the cache — nothing leaks
    for p, c in list(seq_refs.items()):
        pool.free([p] * c)
    cache.flush()
    assert not cache.held_pages
    assert pool.free_count == pool.n_pages - 1
    assert not pool.allocated


# ---------------------------------------------------------------------------
# engine: two-epoch tiering, restart survival, cache-off identity
# ---------------------------------------------------------------------------
def _llama_cache_setup():
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    probe = model.init_paged_pool(2, 4)
    k = probe["k"]
    page_nbytes = 2 * (k.size // k.shape[2]) * k.dtype.itemsize
    return cfg, model, params, page_nbytes


def _epoch(cfg, seed=0, rid_base=0):
    return repeated_prompt_trace(3, prefix_len=8, suffix_len=4, max_new=4,
                                 vocab=cfg.vocab, page_size=4, seed=seed,
                                 arrival_gap=2, rid_base=rid_base)


def _cache_engine(model, params, *, budget_bytes, cache_dir=None,
                  n_pages=12):
    return Engine(model, params, max_slots=2, page_size=4, max_len=16,
                  n_pages=n_pages, prefill_chunk=4, prefix_sharing=True,
                  prefix_cache_budget=budget_bytes,
                  prefix_cache_dir=cache_dir)


def test_engine_second_epoch_prefills_zero_fresh_pages(tmp_path):
    """The tentpole gate: a repeated system prompt's second epoch resolves
    entirely from cache tiers (HBM holds + host promotions) — the fresh
    page counter must not move — with tokens bit-identical to the static
    reference and a clean pool/trie/HBM drain after the flush."""
    cfg, model, params, page_nbytes = _llama_cache_setup()
    eng = _cache_engine(model, params, budget_bytes=3 * page_nbytes,
                        cache_dir=tmp_path)
    res1 = eng.run(_epoch(cfg, rid_base=0))
    fresh1 = res1["stats"]["prompt_pages_fresh"]
    assert fresh1 > 0
    res2 = eng.run(_epoch(cfg, rid_base=3))
    s = res2["stats"]
    assert s["prompt_pages_fresh"] == fresh1, "second epoch re-prefilled"
    assert s["prefix_hits"] >= 1
    assert s["prefix_host_hits"] >= 1, "budget squeeze never exercised host"
    assert s["prefix_demotions_disk"] >= 1 and s["prefix_bytes_disk"] > 0
    assert s["reprefill_tokens_saved"] > 0
    for req in _epoch(cfg, rid_base=0) + _epoch(cfg, rid_base=3):
        assert res2["tokens"][req.rid] == static_generate(
            model, params, req), f"rid {req.rid}"
    eng.flush_prefix_cache()
    assert not eng.page_pool.allocated
    assert eng.page_pool.free_count == eng.page_pool.n_pages - 1
    assert len(eng.trie) == 0
    assert eng.prefix_cache.bytes_by_tier()["hbm"] == 0
    assert eng.stats["prefix_bytes_hbm"] == 0


def test_engine_restart_survives_from_disk(tmp_path):
    """Disk-spilled chunks outlive the engine: a freshly constructed
    engine pointed at the same cache dir serves the same prompts with
    zero fresh prefill pages, promoting every page from disk, and emits
    bit-identical tokens."""
    cfg, model, params, page_nbytes = _llama_cache_setup()
    # budget 0: every retention demotes immediately -> pure host/disk
    eng = _cache_engine(model, params, budget_bytes=0, cache_dir=tmp_path)
    eng.run(_epoch(cfg))
    assert eng.stats["prefix_demotions_disk"] >= 1
    assert list(pathlib.Path(tmp_path).glob("*.npz"))
    del eng

    fresh_eng = _cache_engine(model, params, budget_bytes=0,
                              cache_dir=tmp_path)
    res = fresh_eng.run(_epoch(cfg))
    s = res["stats"]
    assert s["prompt_pages_fresh"] == 0, "restart re-prefilled"
    assert s["prefix_disk_hits"] >= 1
    assert s["prefix_host_hits"] == 0          # fresh engine: host empty
    for req in _epoch(cfg):
        assert res["tokens"][req.rid] == static_generate(
            model, params, req), f"rid {req.rid}"
    fresh_eng.flush_prefix_cache()
    assert not fresh_eng.page_pool.allocated
    assert len(fresh_eng.trie) == 0


def test_engine_cache_off_tokens_identical(tmp_path):
    """Turning the cache on must not perturb tokens: the same trace with
    and without retention emits bit-identical sequences."""
    cfg, model, params, page_nbytes = _llama_cache_setup()
    outs = []
    for budget in (0, None):
        eng = (Engine(model, params, max_slots=2, page_size=4, max_len=16,
                      n_pages=12, prefill_chunk=4, prefix_sharing=True)
               if budget is None else
               _cache_engine(model, params, budget_bytes=3 * page_nbytes,
                             cache_dir=tmp_path))
        outs.append(eng.run(_epoch(cfg))["tokens"])
    assert outs[0] == outs[1]


def test_engine_admission_reclaims_cold_pages_under_pressure(tmp_path):
    """A pool sized so retained pages block admission: the engine must
    demote cold cache entries instead of stalling, and still complete
    every request with reference-identical tokens."""
    cfg, model, params, page_nbytes = _llama_cache_setup()
    # 8 usable pages; each prompt needs 3 + decode growth, retention
    # would pin 3 — admission only proceeds by reclaiming cold entries
    eng = _cache_engine(model, params, budget_bytes=8 * page_nbytes,
                        n_pages=9)
    trace = _epoch(cfg, rid_base=0) + _epoch(cfg, seed=7, rid_base=3)
    res = eng.run(trace)
    s = res["stats"]
    assert s["completed"] == len(trace)
    assert s["prefix_demotions_host"] >= 1, "pressure never forced reclaim"
    for req in trace:
        assert res["tokens"][req.rid] == static_generate(
            model, params, req), f"rid {req.rid}"
    eng.flush_prefix_cache()
    assert not eng.page_pool.allocated
    assert len(eng.trie) == 0


def test_engine_cache_requires_prefix_sharing():
    cfg, model, params, _ = _llama_cache_setup()
    with pytest.raises(ValueError, match="prefix_sharing"):
        Engine(model, params, max_slots=2, page_size=4, max_len=16,
               prefill_chunk=4, prefix_cache_budget=1)


# ---------------------------------------------------------------------------
# CLI plumbing
# ---------------------------------------------------------------------------
def test_serve_cli_cache_flags_require_prefix_sharing():
    from repro.launch import serve

    with pytest.raises(SystemExit):
        serve.main(["--arch", "llama3.2-1b", "--reduced", "--engine",
                    "--prefill-chunk", "4", "--prefix-cache-budget", "1"])
    with pytest.raises(SystemExit):
        serve.main(["--arch", "llama3.2-1b", "--reduced", "--engine",
                    "--prefill-chunk", "4", "--prefix-cache-dir", "/tmp/x"])


def test_serve_cli_cache_end_to_end(tmp_path):
    from repro.launch import serve

    summary = serve.main([
        "--arch", "llama3.2-1b", "--reduced", "--engine",
        "--requests", "2", "--prompt-len", "8", "--gen", "3",
        "--max-slots", "2", "--page-size", "4", "--prefill-chunk", "4",
        "--prefix-sharing",
        "--prefix-cache-budget", str(1 << 30),
        "--prefix-cache-dir", str(tmp_path)])
    assert summary["prefix_cache_budget"] == 1 << 30
    assert summary["prefix_cache_dir"] == str(tmp_path)
    for key in ("prefix_hits", "prefix_misses", "prefix_bytes_hbm",
                "reprefill_tokens_saved"):
        assert key in summary, key


# ---------------------------------------------------------------------------
# docs gates: bench cache counters must be in the serving glossary
# ---------------------------------------------------------------------------
def test_bench_cache_counters_all_in_glossary():
    """Every prefix-cache counter the stress bench emits (plus the
    second-epoch gate field) must have a backticked glossary row in
    docs/serving.md — same contract as the engine stats keys."""
    root = pathlib.Path(__file__).resolve().parents[1]
    sys.path.insert(0, str(root / "benchmarks"))
    try:
        import serving_bench
    finally:
        sys.path.pop(0)
    glossary = (root / "docs" / "serving.md").read_text()
    names = set(serving_bench.CACHE_COUNTERS) | {"epoch2_fresh_pages"}
    missing = [n for n in sorted(names) if f"`{n}`" not in glossary]
    assert not missing, f"docs/serving.md glossary missing: {missing}"
