"""Checkpointer: round-trip, commit marker, async, GC, elastic dtype."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer

KEY = jax.random.PRNGKey(0)


def _state(scale=1.0):
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4) * scale,
                   "b": jnp.ones((4,)) * scale},
        "opt": {"step": jnp.asarray(7, jnp.int32),
                "m": {"w": jnp.zeros((3, 4)), "b": jnp.zeros((4,))}},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    state = _state()
    ck.save(3, state)
    out = ck.restore(3, jax.tree_util.tree_map(jnp.zeros_like, state))
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _state(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_committed_marker_guards_partial(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _state())
    # simulate a partial (uncommitted) later checkpoint
    bad = pathlib.Path(tmp_path) / "step_00000009"
    bad.mkdir()
    (bad / "MANIFEST.msgpack").write_bytes(b"junk")
    assert ck.latest_step() == 5
    with pytest.raises(FileNotFoundError):
        ck.restore(9, _state())


def test_gc_keeps_last_n(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(scale=s))
    assert ck.all_steps() == [3, 4]


def test_restore_casts_to_template_dtype(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": jnp.ones((4,), jnp.float32)})
    out = ck.restore(1, {"w": jnp.zeros((4,), jnp.bfloat16)})
    assert out["w"].dtype == jnp.bfloat16


def test_restore_missing_leaf_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, {"w": jnp.ones((4,))})
    with pytest.raises(KeyError):
        ck.restore(1, {"w": jnp.ones((4,)), "extra": jnp.ones((2,))})
