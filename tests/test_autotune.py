"""Kernel registry + autotuner: dispatch, cache round-trip, numerics."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # CI installs hypothesis; bare
    from _hypothesis_stub import given, settings, st  # envs skip these

from repro.core import formats, pruning
from repro.core.sod import SoDConfig, apply, pack_param
from repro.kernels import autotune, ops, ref, registry

KEY = jax.random.PRNGKey(7)


@pytest.fixture
def tmp_cache(tmp_path):
    cache = autotune.TuningCache(tmp_path / "tuning_cache.json")
    autotune.set_cache(cache)
    yield cache
    autotune.set_cache(None)


def _packed(shape=(256, 256), density=0.3, fmt="tiled_csc", seed=0):
    w = pruning.random_sparse(jax.random.fold_in(KEY, seed), shape, density)
    if fmt == "block_csr":
        w = pruning.block_prune(w, density)
        return w, formats.pack_block_csr(w)
    return w, formats.pack_tiled_csc(w)


# ---------------------------------------------------------------------------
# registry dispatch
# ---------------------------------------------------------------------------
def test_cpu_cold_cache_dispatches_jnp_oracle():
    _, p = _packed()
    impl, params = registry.choose(registry.problem_key(p, m=64,
                                                        backend="cpu"))
    assert impl.name == "jnp_oracle"
    assert impl.differentiable


def test_interpret_backend_dispatches_pallas():
    _, p = _packed()
    impl, _ = registry.choose(registry.problem_key(p, m=64,
                                                   backend="interpret"))
    assert impl.name == "pallas_fused"
    _, pb = _packed(fmt="block_csr")
    impl_b, _ = registry.choose(registry.problem_key(pb, m=64,
                                                     backend="interpret"))
    assert impl_b.name == "pallas_block"


def test_sod_config_auto_dispatches_through_registry_cpu_and_interpret():
    """Acceptance: SoDConfig(impl="auto") goes through the registry on both
    the CPU (jnp) and TPU-interpret (pallas) paths, numerically identical."""
    cfg = SoDConfig(mode="tiled_csc", density=0.4, min_dim=64)
    w = pruning.random_sparse(KEY, (256, 192), 0.4)
    p = pack_param(w, cfg, prune=False)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (16, 256))
    want = np.asarray(x @ w)

    y_cpu = apply(x, p, cfg)                     # backend=cpu -> jnp oracle
    np.testing.assert_allclose(np.asarray(y_cpu), want, atol=5e-4, rtol=1e-4)

    registry.set_backend_override("interpret")   # -> pallas path
    try:
        y_int = apply(x, p, cfg)
    finally:
        registry.set_backend_override(None)
    np.testing.assert_allclose(np.asarray(y_int), want, atol=5e-4, rtol=1e-4)


def test_tpu_cold_cache_restricted_to_partitionable():
    """Cold-cache dispatch on a real TPU mesh must stay on impls XLA can
    partition under pjit (pallas_call has no GSPMD rule); a tuned entry is
    an explicit opt-in and still wins."""
    _, p = _packed()
    key = registry.problem_key(p, m=256, backend="tpu")
    impl, _ = registry.choose(key)
    assert impl.spmd_partitionable
    impl_tuned, _ = registry.choose(
        key, tuned={"impl": "pallas_fused", "params": {}})
    assert impl_tuned.name == "pallas_fused"


def test_every_capable_impl_matches_ref():
    for fmt in ("tiled_csc", "block_csr"):
        w, p = _packed((300, 260), 0.25, fmt, seed=3)
        x = jax.random.normal(jax.random.fold_in(KEY, 2), (24, 300))
        fn_ref = (ref.sod_matmul_ref if fmt == "tiled_csc"
                  else ref.block_matmul_ref)
        want = np.asarray(fn_ref(x, p))
        for backend in ("cpu", "interpret"):
            key = registry.problem_key(p, m=24, backend=backend)
            for impl in registry.candidates(key):
                y = impl.run(x, p, backend=backend,
                             **impl.default_params(key))
                np.testing.assert_allclose(
                    np.asarray(y), want, atol=5e-4, rtol=1e-4,
                    err_msg=f"{impl.name} on {backend} ({fmt})")


def test_pallas_impls_differentiable_vs_oracle():
    """The custom VJPs must produce the oracle's gradients (incl. exact
    zeros at padding slots — fixed-mask training stays on the mask)."""
    w, p = _packed((300, 260), 0.25, seed=5)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (16, 300))
    impl = registry.get_impl("pallas_fused")
    params = impl.default_params(registry.problem_key(p, m=16,
                                                      backend="cpu"))

    def loss_pallas(x, p):
        return (impl.run(x, p, backend="cpu", **params) ** 2).sum()

    def loss_ref(x, p):
        return (ref.sod_matmul_ref(x, p) ** 2).sum()

    gx_p, gp_p = jax.grad(loss_pallas, argnums=(0, 1), allow_int=True)(x, p)
    gx_r, gp_r = jax.grad(loss_ref, argnums=(0, 1), allow_int=True)(x, p)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r),
                               atol=2e-2, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gp_p.vals), np.asarray(gp_r.vals),
                               atol=2e-2, rtol=1e-3)
    # padding slots carry exactly-zero gradient
    pad = np.asarray(p.rows) < 0
    assert np.all(np.asarray(gp_p.vals)[pad] == 0)


def test_block_vjp_matches_oracle():
    """pallas_block's custom VJP (tiles5 reshape + block_ids gather) must
    reproduce the oracle's gradients, with exact zeros at padding blocks."""
    w, pb = _packed((300, 260), 0.3, "block_csr", seed=6)
    x = jax.random.normal(jax.random.fold_in(KEY, 9), (16, 300))
    impl = registry.get_impl("pallas_block")
    params = impl.default_params(registry.problem_key(pb, m=16,
                                                      backend="cpu"))

    def loss_pallas(x, p):
        return (impl.run(x, p, backend="cpu", **params) ** 2).sum()

    def loss_ref(x, p):
        return (ref.block_matmul_ref(x, p) ** 2).sum()

    gx_p, gp_p = jax.grad(loss_pallas, argnums=(0, 1), allow_int=True)(x, pb)
    gx_r, gp_r = jax.grad(loss_ref, argnums=(0, 1), allow_int=True)(x, pb)
    np.testing.assert_allclose(np.asarray(gx_p), np.asarray(gx_r),
                               atol=2e-2, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(gp_p.block_vals),
                               np.asarray(gp_r.block_vals),
                               atol=2e-2, rtol=1e-3)
    pad = np.asarray(pb.block_ids) < 0
    assert np.all(np.asarray(gp_p.block_vals)[pad] == 0)


def test_k_slab_variants_match():
    """Non-resident K-slab (re-decompress per use) is numerically identical
    to the resident default."""
    from repro.kernels.sod_matmul import sod_matmul_pallas

    w, p = _packed((300, 260), 0.2, seed=9)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (64, 300))
    xp = jnp.pad(x, ((0, 0), (0, p.grid[0] * p.tile[0] - 300)))
    y0 = sod_matmul_pallas(xp, p, bm=64, k_slab=0)[:, :260]
    y1 = sod_matmul_pallas(xp, p, bm=64, k_slab=1)[:, :260]
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(x @ w),
                               atol=5e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# tuning cache
# ---------------------------------------------------------------------------
def test_cache_roundtrip_warm_run_skips_measurement(tmp_cache):
    """Acceptance: cold-cache tune measures; warm-cache run (same process or
    a reload from disk) never re-measures."""
    _, p = _packed((256, 256), 0.3, seed=11)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (32, 256))
    calls = []

    def counting_measure(fn):
        calls.append(1)
        jax.block_until_ready(fn())
        return float(len(calls))

    entry = autotune.tune(x, p, backend="cpu", cache=tmp_cache,
                          measure_fn=counting_measure)
    assert calls, "cold cache must measure"
    assert entry["impl"] in registry.all_impls()
    n_cold = len(calls)

    # warm, same cache object
    autotune.tune(x, p, backend="cpu", cache=tmp_cache,
                  measure_fn=counting_measure)
    assert len(calls) == n_cold

    # warm, reloaded from disk
    reloaded = autotune.TuningCache(tmp_cache.path)
    assert len(reloaded) == len(tmp_cache)
    autotune.tune(x, p, backend="cpu", cache=reloaded,
                  measure_fn=counting_measure)
    assert len(calls) == n_cold

    # and the dispatcher consumes the persisted winner
    autotune.set_cache(reloaded)
    key = registry.problem_key(p, m=32, backend="cpu")
    impl, params = registry.choose(key, tuned=autotune.lookup(key))
    assert impl.name == entry["impl"]


def test_set_cache_pins_nondefault_path(tmp_path):
    """A cache installed via set_cache (launch --tuning-cache) must keep
    serving dispatch lookups even though its path differs from the env
    default — previously get_cache() silently evicted it."""
    cache = autotune.TuningCache(tmp_path / "pinned.json")
    autotune.set_cache(cache)
    try:
        assert autotune.get_cache() is cache
    finally:
        autotune.set_cache(None)


def test_tune_dedups_trials_on_canonical_params(tmp_cache):
    """bm values that clamp to the same effective block size must be
    measured once, and the cache must record what actually ran."""
    _, p = _packed((256, 256), 0.3, seed=19)
    x = jax.random.normal(jax.random.fold_in(KEY, 8), (8, 256))  # tiny M
    trials = []
    entry = autotune.tune(x, p, backend="interpret", cache=tmp_cache,
                          top_k=8, measure_fn=lambda fn: 1.0,
                          trials_out=trials)
    sigs = [(name, tuple(sorted(params.items())))
            for name, params, _ in trials]
    assert len(sigs) == len(set(sigs)), f"duplicate trials: {sigs}"
    # every pallas trial records the clamped bm (m=8 -> bm=8), not raw 128
    for name, params, _ in trials:
        if name == "pallas_fused":
            assert params["bm"] <= 8
    assert entry["params"] == dict(
        registry.get_impl(entry["impl"]).canonical_params(
            registry.problem_key(p, m=8, backend="interpret"),
            entry["params"], 8))


def test_cache_invalidated_by_kernel_hash(tmp_cache, monkeypatch):
    _, p = _packed((256, 256), 0.3, seed=13)
    x = jax.random.normal(jax.random.fold_in(KEY, 6), (32, 256))
    autotune.tune(x, p, backend="cpu", cache=tmp_cache,
                  measure_fn=lambda fn: 1.0)
    assert len(tmp_cache) == 1

    # simulate a kernel-source edit: stored hash no longer matches
    raw = json.loads(tmp_cache.path.read_text())
    raw["kernel_hash"] = "0" * 16
    tmp_cache.path.write_text(json.dumps(raw))
    stale = autotune.TuningCache(tmp_cache.path)
    assert len(stale) == 0


def test_tune_always_measures_the_default_config(tmp_cache):
    """The status-quo config is always a candidate, so the tuned choice can
    never silently lose to the seed's hard-coded parameters."""
    _, p = _packed((256, 256), 0.3, seed=17)
    x = jax.random.normal(jax.random.fold_in(KEY, 7), (32, 256))
    trials = []
    autotune.tune(x, p, backend="interpret", cache=tmp_cache,
                  measure_fn=lambda fn: 1.0, trials_out=trials)
    key = registry.problem_key(p, m=32, backend="interpret")
    fused = registry.get_impl("pallas_fused")
    default_canon = fused.canonical_params(key, fused.default_params(key), 32)
    assert ("pallas_fused", default_canon) in [
        (name, params) for name, params, _ in trials]


def test_warmup_params_covers_packed_tree(tmp_cache):
    cfg = SoDConfig(mode="tiled_csc", density=0.5, min_dim=64)
    params = {
        "wq": pack_param(pruning.random_sparse(KEY, (128, 128), 0.5), cfg,
                         prune=False),
        "w_up": pack_param(
            pruning.random_sparse(jax.random.fold_in(KEY, 1), (128, 256),
                                  0.5), cfg, prune=False),
        "bias": jnp.zeros((128,)),
    }
    stats = autotune.warmup_params(params, (16,), backend="cpu",
                                   cache=tmp_cache)
    assert stats["tuned"] == 2
    stats2 = autotune.warmup_params(params, (16,), backend="cpu",
                                    cache=tmp_cache)
    assert stats2 == {"tuned": 0, "cached": 2}


# ---------------------------------------------------------------------------
# property test: tuned output ≡ ref across formats (runs when hypothesis is
# installed, e.g. in CI)
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(
    k=st.integers(2, 5), n=st.integers(2, 5),
    density=st.floats(0.05, 0.9), fmt=st.sampled_from(
        ["tiled_csc", "block_csr"]),
    m=st.sampled_from([1, 8, 33]),
)
def test_tuned_dispatch_matches_ref_property(k, n, density, fmt, m):
    k, n = k * 64, n * 64
    w = pruning.random_sparse(jax.random.fold_in(KEY, k * n), (k, n), density)
    if fmt == "block_csr":
        w = pruning.block_prune(w, density)
        p = formats.pack_block_csr(w)
        fn_ref = ref.block_matmul_ref
    else:
        p = formats.pack_tiled_csc(w)
        fn_ref = ref.sod_matmul_ref
    x = jax.random.normal(jax.random.fold_in(KEY, m + k), (m, k))
    for backend in ("cpu", "interpret"):
        y = ops.sod_matmul(x, p, impl="auto", backend=backend)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(fn_ref(x, p)), atol=5e-4, rtol=1e-4,
            err_msg=f"{fmt} m={m} k={k} n={n} d={density:.2f} {backend}")
