"""End-to-end behaviour tests: training converges, SoD trains, resume works,
compressed collectives are exact on a forced-device mesh."""
import subprocess
import sys

import numpy as np
import pytest

from repro.launch import train as train_mod


def test_training_loss_decreases(tmp_path):
    summary = train_mod.main([
        "--arch", "llama3.2-1b", "--reduced", "--steps", "60",
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path), "--log-every", "50"])
    assert summary["last_loss"] < summary["first_loss"] - 0.2


def test_training_with_sod_packed_params(tmp_path):
    summary = train_mod.main([
        "--arch", "llama3.2-1b", "--reduced", "--steps", "25",
        "--batch", "4", "--seq", "64", "--lr", "3e-3",
        "--sod", "tiled_csc", "--density", "0.4",
        "--ckpt-dir", str(tmp_path), "--log-every", "20"])
    assert np.isfinite(summary["last_loss"])
    assert summary["mean_last10"] < summary["first_loss"] + 0.1


def test_resume_from_checkpoint(tmp_path):
    train_mod.main([
        "--arch", "llama3.2-1b", "--reduced", "--steps", "12",
        "--batch", "2", "--seq", "32", "--ckpt-every", "5",
        "--ckpt-dir", str(tmp_path), "--log-every", "50"])
    summary = train_mod.main([
        "--arch", "llama3.2-1b", "--reduced", "--steps", "16",
        "--batch", "2", "--seq", "32", "--ckpt-every", "5",
        "--ckpt-dir", str(tmp_path), "--resume", "--log-every", "50"])
    assert summary["steps"] == 16


def test_small_mesh_distribution_subprocess():
    """Sharded train step compiles on a forced 8-device mesh — the
    miniature of the production dry-run, isolated in a subprocess so the
    forced device count never leaks into this process."""
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, numpy as np
from jax.sharding import Mesh
from repro import configs
from repro.models.model import LM
from repro.launch import specs as S, steps as T
from repro.runtime import sharding as R
from repro.configs.base import ShapeConfig
from repro.optim.adamw import AdamW, AdamWConfig
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4), ('data', 'model'))
cfg = configs.reduced(configs.get_config('llama3.2-1b'))
model = LM(cfg)
params = S.abstract_params(model)
p_specs = R.param_specs(params, cfg, mesh)
p_sh = R.to_shardings(p_specs, mesh)
opt = AdamW(AdamWConfig())
opt_state = jax.eval_shape(opt.init, params)
o_sh = R.to_shardings(R.opt_state_specs(opt_state, p_specs, mesh), mesh)
inputs = S.input_specs(cfg, ShapeConfig('t', 'train', 128, 8))
b_sh = R.to_shardings(R.batch_specs(inputs['batch'], mesh), mesh)
with mesh:
    c = jax.jit(T.make_train_step(model, opt),
                in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None)).lower(
        params, opt_state, inputs['batch']).compile()
assert 'all-reduce' in c.as_text()
print('OK')
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout


def test_sod_fsdp_collectives_subprocess():
    """Compressed weight all-gather + compressed grad all-reduce are exact
    on a real (forced-device) mesh."""
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.core import pruning
from repro.core.formats import pack_tiled_csc
from repro.runtime import sod_fsdp
mesh = Mesh(np.asarray(jax.devices()).reshape(4, 2), ('data', 'model'))
key = jax.random.PRNGKey(0)
w = pruning.random_sparse(key, (256, 512), 0.3)
p = pack_tiled_csc(w, tile=(128, 128))
x = jax.random.normal(key, (16, 256))
with mesh:
    ps = sod_fsdp.shard_packed(p, mesh)
    y = sod_fsdp.sod_fsdp_matmul(x, ps, mesh)
assert np.allclose(np.asarray(y), np.asarray(x @ w), atol=2e-3)
g = jax.random.normal(key, (8, 4096))
with mesh:
    dense, _ = sod_fsdp.compressed_grad_allreduce(g, mesh, ratio=1.0)
expect = np.asarray(g).reshape(4, 2, 4096).mean(0)
assert np.allclose(np.asarray(dense)[:2], expect, atol=1e-5)
print('OK')
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
