"""Tracing + metrics layer: trace-event validity, histogram accuracy,
no-op-by-default guarantees, and tokens bit-identical with tracing on."""
import importlib.util
import json
import pathlib

import numpy as np
import pytest

from repro import configs, obs
from repro.models.model import build_model
from repro.serving import Engine, Request

import jax

KEY = jax.random.PRNGKey(0)
REPO = pathlib.Path(__file__).resolve().parent.parent


def _load_trace_report():
    path = REPO / "scripts" / "trace_report.py"
    spec = importlib.util.spec_from_file_location("trace_report", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _validate_trace(data: dict) -> list[dict]:
    """Assert Chrome trace-event invariants; return the event list.

    * required keys on every event (string pid/tid are valid);
    * timestamps non-decreasing per (pid, tid) track;
    * ``B``/``E`` nest LIFO per tid — depth never negative, ends at 0;
    * counter (``C``) events carry numeric args only.
    """
    assert isinstance(data, dict) and "traceEvents" in data
    events = data["traceEvents"]
    last_ts: dict[tuple, float] = {}
    depth: dict[str, int] = {}
    for ev in events:
        for key in ("name", "ph", "ts", "pid", "tid"):
            assert key in ev, f"event missing {key!r}: {ev}"
        track = (str(ev["pid"]), str(ev["tid"]))
        assert ev["ts"] >= last_ts.get(track, 0.0), \
            f"ts went backwards on track {track}"
        last_ts[track] = ev["ts"]
        tid = str(ev["tid"])
        if ev["ph"] == "B":
            depth[tid] = depth.get(tid, 0) + 1
        elif ev["ph"] == "E":
            depth[tid] = depth.get(tid, 0) - 1
            assert depth[tid] >= 0, f"E without B on tid {tid}"
        elif ev["ph"] == "C":
            assert all(isinstance(v, (int, float))
                       for v in ev["args"].values()), ev
        elif ev["ph"] == "i":
            assert ev.get("s") == "t"
    assert all(d == 0 for d in depth.values()), f"unclosed spans: {depth}"
    return events


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------
def test_tracer_export_valid_and_balanced(tmp_path):
    tr = obs.Tracer()
    with tr.span("outer", track="engine", step=0):
        with tr.span("inner", track="engine"):
            tr.instant("tick", track="lifecycle", rid=1)
        tr.counter("pool_pages", {"free": 3, "live": 5}, track="pool")
    tr.begin("dangling", track="engine")     # export must synthesize the E
    out = tr.export(tmp_path / "t.json")
    data = json.loads(pathlib.Path(out).read_text())
    events = _validate_trace(data)
    assert data["displayTimeUnit"] == "ms"
    by_ph = {e["ph"] for e in events}
    assert by_ph == {"B", "E", "i", "C"}
    names = [e["name"] for e in events if e["ph"] == "B"]
    assert names == ["outer", "inner", "dangling"]
    # args survive, non-JSON values are repr()'d not fatal
    outer = next(e for e in events if e["name"] == "outer")
    assert outer["args"] == {"step": 0}


def test_tracer_ring_buffer_bounds_memory():
    tr = obs.Tracer(capacity=10)
    for i in range(50):
        tr.instant(f"e{i}", track="engine")
    assert len(tr._events) == 10
    assert tr._events[0]["name"] == "e40"   # oldest dropped, newest kept


def test_null_tracer_is_inert(tmp_path):
    nt = obs.NULL_TRACER
    assert isinstance(nt, obs.NullTracer) and nt.enabled is False
    with nt.span("x", track="engine"):      # all entry points are no-ops
        nt.instant("y")
        nt.counter("z", {"a": 1})
    assert nt.export(tmp_path / "never.json") is None
    assert not (tmp_path / "never.json").exists()


def test_install_tracer_round_trip():
    assert obs.get_tracer() is obs.NULL_TRACER
    live = obs.Tracer()
    try:
        assert obs.install_tracer(live) is live
        assert obs.get_tracer() is live
    finally:
        assert obs.install_tracer(None) is obs.NULL_TRACER
    assert obs.get_tracer() is obs.NULL_TRACER


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_histogram_percentiles_vs_numpy():
    rng = np.random.default_rng(7)
    vals = rng.lognormal(mean=-4.0, sigma=1.5, size=4000)
    h = obs.Histogram()
    for v in vals:
        h.observe(float(v))
    for q in (50, 90, 99):
        exact = float(np.percentile(vals, q))
        approx = h.percentile(q)
        # log buckets grow by 2**(1/8) ~ 9%; interpolation keeps the
        # estimate within about half a bucket of the true quantile
        assert approx == pytest.approx(exact, rel=0.12), f"p{q}"
    s = h.summary()
    assert s["count"] == 4000
    assert s["min"] == pytest.approx(vals.min())
    assert s["max"] == pytest.approx(vals.max())
    assert s["mean"] == pytest.approx(vals.mean(), rel=1e-6)


def test_histogram_edge_cases():
    h = obs.Histogram()
    assert h.percentile(50) == 0.0          # empty: defined, not NaN
    h.observe(0.0123)
    for q in (1, 50, 99):                   # single value: clamped exact
        assert h.percentile(q) == pytest.approx(0.0123)
    h2 = obs.Histogram()
    h2.observe(0.0)                         # below lo lands in bucket 0
    h2.observe(1e9)                         # above hi clamps to last
    assert h2.summary()["count"] == 2
    assert h2.percentile(99) <= 1e9


def test_stats_view_is_a_real_dict_surface():
    m = obs.Metrics()
    view = m.stats_view()
    view["a"] = 1
    view.update({"b": 2.5, "c": 0})
    view["a"] += 4
    assert view["a"] == 5 and len(view) == 3
    assert dict(view) == {"a": 5, "b": 2.5, "c": 0}
    assert list(view) == ["a", "b", "c"]    # insertion order preserved
    del view["c"]
    assert "c" not in view
    m.counter("hits", 3)
    assert view["hits"] == 3                # registry and view share state
    assert m.snapshot()["counters"]["a"] == 5


def test_metrics_snapshot_shape():
    m = obs.Metrics()
    m.gauge("g", 7.0)
    m.observe("lat_s", 0.25)
    snap = m.snapshot()
    assert set(snap) == {"counters", "gauges", "histograms"}
    assert snap["gauges"]["g"] == 7.0
    assert snap["histograms"]["lat_s"]["count"] == 1


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------
def _engine_run(tracer=None):
    cfg = configs.reduced(configs.get_config("llama3.2-1b"))
    model = build_model(cfg)
    params = model.init(KEY)
    reqs = [Request(rid=i, tokens=(np.arange(6, dtype=np.int32) * 7 + i)
                    % cfg.vocab, max_new=4, arrival=i)
            for i in range(3)]
    eng = Engine(model, params, max_slots=2, page_size=4, max_len=16,
                 tracer=tracer)
    return eng, eng.run(reqs)


def test_engine_tokens_identical_traced_vs_untraced(tmp_path):
    _, res_off = _engine_run()
    tr = obs.Tracer()
    eng, res_on = _engine_run(tracer=tr)
    assert res_on["tokens"] == res_off["tokens"]
    # every pre-existing stat is bit-identical; the latency percentiles
    # are timing-derived, so compare key sets only
    assert set(res_on["stats"]) == set(res_off["stats"])
    for k in ("completed", "steps", "preemptions", "cow_forks"):
        assert res_on["stats"][k] == res_off["stats"][k]

    out = tr.export(tmp_path / "engine.json")
    events = _validate_trace(json.loads(pathlib.Path(out).read_text()))
    tracks = {str(e["tid"]) for e in events}
    assert {"engine", "lifecycle", "pool"} <= tracks
    assert any(t.startswith("slot") for t in tracks)
    steps = [e for e in events
             if e["name"] == "step" and e["ph"] == "B"]
    assert len(steps) == res_on["stats"]["steps"]
    reqs = {e["name"] for e in events if e.get("cat") == "request"}
    assert reqs == {"req0", "req1", "req2"}

    # the report tool parses it and attributes engine self-time
    trp = _load_trace_report()
    rep = trp.report(out, track="engine")
    assert rep["events"] == len(events)
    assert any(k.endswith(":step") for k in rep["spans"])
    assert {r["request"] for r in rep["slowest_requests"]} == reqs
    assert trp.main([str(out), "--track", "engine"]) == 0


def test_engine_latency_stats_present_and_sane():
    eng, res = _engine_run(tracer=obs.Tracer())
    for k in ("queue_wait_p50_s", "queue_wait_p99_s", "ttft_p50_s",
              "ttft_p99_s", "tpot_p50_s", "tpot_p99_s"):
        assert k in res["stats"] and res["stats"][k] >= 0.0
    assert res["stats"]["ttft_p99_s"] >= res["stats"]["ttft_p50_s"]
    hists = eng.metrics.snapshot()["histograms"]
    assert hists["ttft_s"]["count"] == res["stats"]["completed"]


def test_serve_engine_trace_and_metrics_files(tmp_path):
    from repro.launch import serve

    trace = tmp_path / "serve.trace.json"
    mjson = tmp_path / "serve.metrics.json"
    summary = serve.main([
        "--arch", "llama3.2-1b", "--reduced", "--engine",
        "--sod", "tiled_csc", "--density", "0.4",
        "--requests", "2", "--prompt-len", "6", "--gen", "3",
        "--max-slots", "2", "--page-size", "4",
        "--trace", str(trace), "--metrics-json", str(mjson)])
    assert summary["trace"] == str(trace)
    _validate_trace(json.loads(trace.read_text()))
    snap = json.loads(mjson.read_text())
    assert snap["counters"]["completed"] == 2
    assert "ttft_s" in snap["histograms"]
    assert summary["kernel_dispatch"]        # impl[source] -> count
    assert obs.get_tracer() is obs.NULL_TRACER   # driver uninstalled it


def test_obs_metric_names_all_in_glossary():
    """Every gauge/histogram the engine's metrics registry emits must be
    documented in docs/observability.md — same gate style as the
    serving-stats glossary check."""
    doc = (REPO / "docs" / "observability.md").read_text()
    eng, _ = _engine_run(tracer=obs.Tracer())
    snap = eng.metrics.snapshot()
    names = list(snap["gauges"]) + list(snap["histograms"])
    assert names, "engine run recorded no gauges/histograms"
    missing = [n for n in names if f"`{n}`" not in doc]
    assert not missing, (
        f"metric names missing from docs/observability.md: {missing}")
