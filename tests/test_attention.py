"""Chunked attention vs naive reference: causal, windows, softcap, decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.layers import apply_rope

B, S, D = 2, 256, 128
SPEC = A.AttnSpec(n_heads=8, n_kv_heads=4, head_dim=32, chunk_q=64,
                  chunk_k=64)
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    params = A.init_attention(KEY, D, SPEC, dtype=jnp.float32)
    x = jax.random.normal(KEY, (B, S, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    return params, x, pos


def naive(params, x, pos, spec, window=None):
    q = (x @ params["wq"]).reshape(B, S, spec.n_heads, spec.head_dim)
    k = (x @ params["wk"]).reshape(B, S, spec.n_kv_heads, spec.head_dim)
    v = (x @ params["wv"]).reshape(B, S, spec.n_kv_heads, spec.head_dim)
    q = apply_rope(q, pos, spec.rope_theta)
    k = apply_rope(k, pos, spec.rope_theta)
    g = spec.n_heads // spec.n_kv_heads
    qg = q.reshape(B, S, spec.n_kv_heads, g, spec.head_dim)
    s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k) * spec.q_scale
    if spec.softcap:
        s = spec.softcap * jnp.tanh(s / spec.softcap)
    i = jnp.arange(S)
    m = i[None, :] <= i[:, None]
    if window:
        m &= i[None, :] > i[:, None] - window
    s = jnp.where(m[None, None, None], s, -2e38)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqc,bckh->bqkgh", p, v).reshape(B, S, -1)
    return o @ params["wo"]


def test_causal(setup):
    params, x, pos = setup
    y = A.full_attention(params, x, SPEC, pos)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(naive(params, x, pos, SPEC)),
                               atol=2e-3)


@pytest.mark.parametrize("window", [17, 64, 96, 128, 255])
def test_sliding_window(setup, window):
    params, x, pos = setup
    y = A.full_attention(params, x, SPEC, pos, window=window)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(naive(params, x, pos, SPEC, window)),
        atol=2e-3)


def test_softcap(setup):
    params, x, pos = setup
    spec = A.AttnSpec(n_heads=8, n_kv_heads=4, head_dim=32, chunk_q=64,
                      chunk_k=64, softcap=20.0)
    y = A.full_attention(params, x, spec, pos)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(naive(params, x, pos, spec)),
                               atol=2e-3)


def test_decode_matches_full(setup):
    params, x, pos = setup
    cache = A.init_cache(B, S, SPEC, dtype=jnp.float32)

    def step(cache, t):
        xt = jax.lax.dynamic_slice(x, (0, t, 0), (B, 1, D))
        out, cache = A.decode_attention(params, xt, cache, t, SPEC)
        return cache, out

    cache, outs = jax.lax.scan(step, cache, jnp.arange(S))
    outs = outs.transpose(1, 0, 2, 3).reshape(B, S, D)
    np.testing.assert_allclose(np.asarray(outs),
                               np.asarray(naive(params, x, pos, SPEC)),
                               atol=2e-3)


def test_mqa_grouping(setup):
    spec = A.AttnSpec(n_heads=8, n_kv_heads=1, head_dim=32, chunk_q=64,
                      chunk_k=64)
    params = A.init_attention(KEY, D, spec, dtype=jnp.float32)
    x = jax.random.normal(KEY, (B, S, D), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    y = A.full_attention(params, x, spec, pos)

    def naive_mqa():
        q = (x @ params["wq"]).reshape(B, S, 8, 32)
        k = (x @ params["wk"]).reshape(B, S, 1, 32)
        v = (x @ params["wv"]).reshape(B, S, 1, 32)
        q = apply_rope(q, pos, spec.rope_theta)
        k = apply_rope(k, pos, spec.rope_theta)
        qg = q.reshape(B, S, 1, 8, 32)
        s = jnp.einsum("bqkgh,bckh->bkgqc", qg, k) * spec.q_scale
        i = jnp.arange(S)
        s = jnp.where((i[None, :] <= i[:, None])[None, None, None], s, -2e38)
        p = jax.nn.softmax(s, -1)
        return jnp.einsum("bkgqc,bckh->bqkgh", p, v).reshape(B, S, -1) \
            @ params["wo"]

    np.testing.assert_allclose(np.asarray(y), np.asarray(naive_mqa()),
                               atol=2e-3)
