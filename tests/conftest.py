"""Test-session hermeticity: never read/write the user's tuning cache."""
import os
import tempfile

# Must be set before repro.kernels.autotune resolves the cache path (it
# re-checks the env on every get_cache(), so setting it at conftest import
# time is sufficient and keeps every test cold-cache by default).
# Unconditional override: a developer's exported REPRO_TUNING_CACHE must
# not leak stale tuned winners into dispatch-behavior tests.
os.environ["REPRO_TUNING_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="repro_test_"), "tuning_cache.json")
