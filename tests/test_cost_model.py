"""Cost model: paper-claim windows + structural properties (hypothesis)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # CI installs hypothesis; bare
    from _hypothesis_stub import given, settings, st  # noqa: E501  envs skip the property tests


from repro.core import cost_model as cm
from repro.core.cost_model import Workload


def test_all_paper_claims_reproduced():
    """The complete claim table from benchmarks must pass."""
    import sys
    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parents[1]))
    from benchmarks import paper_figs, paper_real_models

    failures = []
    for fn in paper_figs.ALL + paper_real_models.ALL:
        _, checks = fn()
        failures += [c[0] for c in checks if not c[3]]
    assert not failures, failures


@settings(max_examples=25, deadline=None)
@given(d=st.floats(0.05, 0.95))
def test_sod_effective_throughput_density_invariant(d):
    """Paper Fig. 8a: SoD T/A constant across density."""
    w0 = Workload(512, 1024, 1024, 1.0, 1.0)
    wd = Workload(512, 1024, 1024, d, 1.0)
    r0 = cm.sparse_on_dense(w0).tops_per_mm2()
    rd = cm.sparse_on_dense(wd).tops_per_mm2()
    assert rd == pytest.approx(r0, rel=1e-6)


@settings(max_examples=25, deadline=None)
@given(d1=st.floats(0.05, 0.9), d2=st.floats(0.05, 0.9))
def test_sod_energy_monotone_in_density(d1, d2):
    """Less density → less memory traffic → more energy-efficient."""
    lo, hi = sorted((d1, d2))
    wl = Workload(512, 2048, 2048, lo, 1.0)
    wh = Workload(512, 2048, 2048, hi, 1.0)
    assert cm.sparse_on_dense(wl).tops_per_watt >= \
        cm.sparse_on_dense(wh).tops_per_watt - 1e-9


@settings(max_examples=20, deadline=None)
@given(d=st.floats(0.05, 0.95))
def test_sparse_accels_never_beat_their_peak(d):
    w = Workload(1024, 1024, 1024, d, d)
    for fn in (cm.ese, cm.scnn, cm.snap, cm.sigma):
        r = fn(w)
        assert r.cycles > 0 and r.energy_pj > 0


def test_dense_baseline_insensitive_to_density():
    """The dense baseline always receives dense-format data (Fig. 6 note)."""
    a = cm.dense_baseline(Workload(512, 1024, 1024, 0.2, 1.0))
    b = cm.dense_baseline(Workload(512, 1024, 1024, 1.0, 1.0))
    assert a.energy_pj == pytest.approx(b.energy_pj)
    assert a.cycles == pytest.approx(b.cycles)


def test_scnn_stride_penalty():
    w = Workload(3025, 363, 96, 0.84, 1.0)
    slow = cm.scnn(w, stride=4, kernel_size=11)
    fast = cm.scnn(w, stride=1, kernel_size=11)
    assert slow.cycles > 3 * fast.cycles


def test_compression_footprint_breakeven():
    """CSC (16b value + 8b index) beats dense below ~2/3 density."""
    below = Workload(1, 128, 128, 0.6, 1.0)
    above = Workload(1, 128, 128, 0.7, 1.0)
    dense_bits = 16.0
    assert below.dw * 24 < dense_bits
    assert above.dw * 24 > dense_bits


def test_breakdown_fig5():
    b = cm.sod_breakdown()
    assert 0.01 <= b["decomp_over_pe"] <= 0.03
    assert b["sram_mm2"] > b["pe_array_mm2"]   # memory dominates the chip
