"""Real-benchmark reproductions: Table III + Figs 12-14.

Layer-wise AlexNet/VGG-16 (ImageNet, magnitude-pruned [16]) against
SCNN/SNAP, and BERT (SQuAD/MNLI, movement-pruned [15]) against ESE, using
the per-layer density profiles of ``repro.core.pruning.PAPER_PROFILES``.
Conv layers are the paper's GEMM mapping (im2col): M = output pixels,
K = C_in·k·k, N = C_out.
"""
from __future__ import annotations

import dataclasses

from repro.core import cost_model as cm
from repro.core.cost_model import Workload
from repro.core.pruning import PAPER_PROFILES


@dataclasses.dataclass(frozen=True)
class ConvLayer:
    name: str
    m: int          # output H×W
    k: int          # Cin · kh · kw
    n: int          # Cout
    kernel: int
    stride: int = 1


ALEXNET = (
    ConvLayer("conv1", 55 * 55, 11 * 11 * 3, 96, 11, stride=4),
    ConvLayer("conv2", 27 * 27, 5 * 5 * 96, 256, 5),
    ConvLayer("conv3", 13 * 13, 3 * 3 * 256, 384, 3),
    ConvLayer("conv4", 13 * 13, 3 * 3 * 384, 384, 3),
    ConvLayer("conv5", 13 * 13, 3 * 3 * 384, 256, 3),
)
ALEXNET_DI = (1.00, 0.85, 0.60, 0.47, 0.53)     # avg 0.69 (Table III)

_VGG_CH = (64, 64, 128, 128, 256, 256, 256, 512, 512, 512, 512, 512, 512)
_VGG_HW = (224, 224, 112, 112, 56, 56, 56, 28, 28, 28, 14, 14, 14)
VGG16 = tuple(
    ConvLayer(f"conv{i+1}", hw * hw, 9 * (3 if i == 0 else _VGG_CH[i - 1]),
              c, 3)
    for i, (c, hw) in enumerate(zip(_VGG_CH, _VGG_HW))
)
VGG_DI = (1.00, 0.55, 0.53, 0.52, 0.60, 0.58, 0.52, 0.62, 0.65, 0.63,
          0.68, 0.31, 0.74)                      # avg ≈0.61 (Table III)

# BERT-base GEMMs per layer (seq M): QKV ×3, attn-out, FFN up, FFN down
BERT_BASE = lambda m: (
    ConvLayer("qkv", m, 768, 3 * 768, 1),
    ConvLayer("attn_out", m, 768, 768, 1),
    ConvLayer("ffn_up", m, 768, 3072, 1),
    ConvLayer("ffn_down", m, 3072, 768, 1),
)


def _conv_ratio(layer: ConvLayer, dw: float, di: float, rival: str):
    w = Workload(layer.m, layer.k, layer.n, dw, di)
    s = cm.sparse_on_dense(w)
    if rival == "scnn":
        o = cm.scnn(w, stride=layer.stride, kernel_size=layer.kernel)
    elif rival == "snap":
        o = cm.snap(w)
    else:
        raise ValueError(rival)
    return (s.tops_per_mm2() / o.tops_per_mm2(),
            s.tops_per_watt / o.tops_per_watt,
            w.dense_macs)


def conv_comparison(layers, dws, dis, rival: str, tag: str):
    rows, tas, ees, weights = [], [], [], []
    for layer, dw, di in zip(layers, dws, dis):
        ta, ee, macs = _conv_ratio(layer, dw, di, rival)
        rows.append((f"{tag}_{layer.name}_ta", ta))
        rows.append((f"{tag}_{layer.name}_e", ee))
        tas.append(ta)
        ees.append(ee)
        weights.append(macs)
    tot = sum(weights)
    avg_ta = sum(t * w for t, w in zip(tas, weights)) / tot
    avg_e = sum(e * w for e, w in zip(ees, weights)) / tot
    rows.append((f"{tag}_avg_ta", avg_ta))
    rows.append((f"{tag}_avg_e", avg_e))
    return rows, avg_ta, avg_e


def alexnet_vs_scnn():
    prof = PAPER_PROFILES["alexnet_conv"]
    rows, avg_ta, avg_e = conv_comparison(
        ALEXNET, prof.layer_densities, ALEXNET_DI, "scnn", "fig13_alexnet")
    checks = [
        ("fig13: AlexNet avg T/A vs SCNN ≈11.9×", avg_ta, (6.0, 20.0),
         6.0 <= avg_ta <= 20.0),
        ("fig13: AlexNet energy vs SCNN > 1 (kernel>1 psum reuse)", avg_e,
         (1.0, None), avg_e > 1.0),
    ]
    return rows, checks


def vgg_vs_scnn():
    prof = PAPER_PROFILES["vgg16_conv"]
    rows, avg_ta, avg_e = conv_comparison(
        VGG16, prof.layer_densities, VGG_DI, "scnn", "fig13_vgg")
    checks = [
        ("fig13: VGG-16 avg T/A vs SCNN ≈3.3×", avg_ta, (2.3, 5.5),
         2.3 <= avg_ta <= 5.5),
        ("fig13: VGG-16 avg energy vs SCNN ≈1.5×", avg_e, (1.0, 2.3),
         1.0 <= avg_e <= 2.3),
    ]
    return rows, checks


def alexnet_vgg_vs_snap():
    prof_a = PAPER_PROFILES["alexnet_conv"]
    rows_a, _, e_a = conv_comparison(
        ALEXNET, prof_a.layer_densities, ALEXNET_DI, "snap", "fig14_alexnet")
    prof_v = PAPER_PROFILES["vgg16_conv"]
    rows_v, _, e_v = conv_comparison(
        VGG16, prof_v.layer_densities, VGG_DI, "snap", "fig14_vgg")
    checks = [
        ("fig14: AlexNet energy vs SNAP ≈1.26×", e_a, (0.95, 1.7),
         0.95 <= e_a <= 1.7),
        ("fig14: VGG energy vs SNAP ≈1.05×", e_v, (0.8, 1.4),
         0.8 <= e_v <= 1.4),
        ("fig14: AlexNet gain > VGG gain (density profile)", e_a - e_v,
         (0.0, None), e_a > e_v),
    ]
    return rows_a + rows_v, checks


def bert_vs_ese(dataset: str, seq: int):
    prof = PAPER_PROFILES[f"bert_{dataset}"]
    rows, tas, ees, weights = [], [], [], []
    for li, dw in enumerate(prof.layer_densities):
        for g in BERT_BASE(seq):
            w = Workload(g.m, g.k, g.n, dw, 1.0)
            s, e = cm.sparse_on_dense(w), cm.ese(w)
            tas.append(s.tops_per_mm2() / e.tops_per_mm2())
            ees.append(s.tops_per_watt / e.tops_per_watt)
            weights.append(w.dense_macs)
        rows.append((f"fig12_{dataset}_L{li}_ta", tas[-1]))
        rows.append((f"fig12_{dataset}_L{li}_e", ees[-1]))
    tot = sum(weights)
    avg_ta = sum(t * w for t, w in zip(tas, weights)) / tot
    avg_e = sum(x * w for x, w in zip(ees, weights)) / tot
    rows.append((f"fig12_{dataset}_avg_ta", avg_ta))
    rows.append((f"fig12_{dataset}_avg_e", avg_e))
    return rows, avg_ta, avg_e


def bert_squad():
    rows, avg_ta, avg_e = bert_vs_ese("squad", 384)
    checks = [
        ("fig12a: BERT-SQuAD avg T/A vs ESE ≈1.4×", avg_ta, (1.0, 2.2),
         1.0 <= avg_ta <= 2.2),
        ("fig12a: BERT-SQuAD avg energy vs ESE ≈3.2× (≥1.5)", avg_e,
         (1.5, 4.5), 1.5 <= avg_e <= 4.5),
    ]
    return rows, checks


def bert_mnli():
    rows, avg_ta, avg_e = bert_vs_ese("mnli", 128)
    checks = [
        ("fig12b: BERT-MNLI avg T/A vs ESE < 1 (density ≤0.2)", avg_ta,
         (None, 1.05), avg_ta < 1.05),
        ("fig12b: BERT-MNLI avg energy vs ESE ≈1.8×", avg_e, (1.2, 2.6),
         1.2 <= avg_e <= 2.6),
    ]
    return rows, checks


ALL = (alexnet_vs_scnn, vgg_vs_scnn, alexnet_vgg_vs_snap, bert_squad,
       bert_mnli)
