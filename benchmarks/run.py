# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV followed by a model-vs-paper validation table (the reproduction gate).
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import kernel_bench, paper_figs, paper_real_models

    rows: list[tuple] = []
    checks: list[tuple] = []
    for fn in paper_figs.ALL + paper_real_models.ALL:
        r, c = fn()
        rows.extend(r)
        checks.extend(c)
    kr, _ = kernel_bench.run()

    print("name,us_per_call,derived")
    for name, val in rows:
        # cost-model rows: derived metric only (analytical, no wall time)
        print(f"{name},,{val:.6g}")
    for name, us, derived in kr:
        print(f"{name},{us:.1f},{derived:.6g}")

    print("\n# paper-claim validation")
    print(f"{'claim':66s} {'model':>18s} {'paper window':>16s}  ok")
    n_fail = 0
    for claim, val, window, ok in checks:
        sval = (f"({val[0]:.2f},{val[1]:.2f})" if isinstance(val, tuple)
                else f"{val:.3f}")
        swin = str(window)
        mark = "PASS" if ok else "FAIL"
        n_fail += 0 if ok else 1
        print(f"{claim:66s} {sval:>18s} {swin:>16s}  {mark}")
    print(f"\n# {len(checks) - n_fail}/{len(checks)} paper claims reproduced")
    if n_fail:
        sys.exit(1)


if __name__ == "__main__":
    main()
