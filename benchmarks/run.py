# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV followed by a model-vs-paper validation table (the reproduction gate).
# Exits non-zero on any failed paper claim OR any kernel-vs-ref mismatch,
# so CI can use it directly; ``--output-json`` writes the same data
# machine-readable.
from __future__ import annotations

import argparse
import json
import pathlib
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--output-json", default=None,
                    help="also write rows/checks/kernel results as JSON")
    args = ap.parse_args(argv)

    from benchmarks import kernel_bench, paper_figs, paper_real_models

    rows: list[tuple] = []
    checks: list[tuple] = []
    for fn in paper_figs.ALL + paper_real_models.ALL:
        r, c = fn()
        rows.extend(r)
        checks.extend(c)
    kr, kernel_mismatches = kernel_bench.run()

    print("name,us_per_call,derived")
    for name, val in rows:
        # cost-model rows: derived metric only (analytical, no wall time)
        print(f"{name},,{val:.6g}")
    for name, us, derived in kr:
        print(f"{name},{us:.1f},{derived:.6g}")

    print("\n# paper-claim validation")
    print(f"{'claim':66s} {'model':>18s} {'paper window':>16s}  ok")
    n_fail = 0
    for claim, val, window, ok in checks:
        sval = (f"({val[0]:.2f},{val[1]:.2f})" if isinstance(val, tuple)
                else f"{val:.3f}")
        swin = str(window)
        mark = "PASS" if ok else "FAIL"
        n_fail += 0 if ok else 1
        print(f"{claim:66s} {sval:>18s} {swin:>16s}  {mark}")
    print(f"\n# {len(checks) - n_fail}/{len(checks)} paper claims reproduced")
    for m in kernel_mismatches:
        print(f"# KERNEL MISMATCH vs ref: {m}", file=sys.stderr)

    if args.output_json:
        payload = {
            "rows": [{"name": n, "derived": v} for n, v in rows]
            + [{"name": n, "us": us, "derived": d} for n, us, d in kr],
            "checks": [
                {"claim": c, "value": v, "window": list(w), "ok": ok}
                for c, v, w, ok in checks
            ],
            "kernel_mismatches": kernel_mismatches,
            "n_claims_failed": n_fail,
        }
        pathlib.Path(args.output_json).write_text(
            json.dumps(payload, indent=1, default=str))
        print(f"# wrote {args.output_json}")

    if n_fail or kernel_mismatches:
        sys.exit(1)


if __name__ == "__main__":
    main()
