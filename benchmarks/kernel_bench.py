"""Pallas kernel micro-benchmarks (interpret mode = functional timing only).

Wall time on CPU interpret mode is NOT TPU performance — the meaningful
derived numbers are the modeled compressed-traffic bytes (what the kernel's
CostEstimate advertises to XLA) and the compression ratios, which feed the
roofline memory term.  Correctness vs the jnp oracle is asserted on the fly.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats, pruning
from repro.kernels import ops, ref


def _time(fn, *args, iters=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    for density in (0.1, 0.3, 0.5):
        w = pruning.random_sparse(key, (512, 512), density)
        x = jax.random.normal(jax.random.fold_in(key, 1), (256, 512))
        p = formats.pack_tiled_csc(w)
        y = ops.sod_matmul(x, p, impl="pallas")
        yr = ref.sod_matmul_ref(x, p)
        assert np.allclose(np.asarray(y), np.asarray(yr), atol=5e-4), density
        us = _time(lambda: ops.sod_matmul(x, p, impl="pallas"))
        rows.append((f"kernel_sod_matmul_d{density:.1f}", us,
                     p.compression_ratio()))
        wb = pruning.block_prune(w, density)
        pb = formats.pack_block_csr(wb)
        yb = ops.sod_matmul(x, pb, impl="pallas")
        assert np.allclose(np.asarray(yb), np.asarray(ref.block_matmul_ref(x, pb)),
                           atol=5e-4)
        us_b = _time(lambda: ops.sod_matmul(x, pb, impl="pallas"))
        skip_frac = 1 - float(jnp.count_nonzero(pb.tile_nnz)) / pb.tile_nnz.size
        rows.append((f"kernel_block_matmul_d{density:.1f}", us_b, skip_frac))
        us_d = _time(lambda: ops.decompress(p))
        rows.append((f"kernel_decompress_d{density:.1f}", us_d,
                     p.nbytes_compressed() / p.nbytes_dense()))
    return rows, []
