"""Kernel sweep driver: default hard-coded configs vs autotuned configs.

For every (format, shape, density) in the sweep this measures

* **default** — the seed's hard-coded kernel configuration (fused Pallas
  kernel, ``bm=128, slot_chunk=8``, fully-resident K-slab), i.e. what ran
  before the registry existed;
* **tuned**   — whatever :mod:`repro.kernels.autotune` picks for the current
  dispatch backend (cost-model-prior-seeded search, measured winner,
  persisted to the tuning cache);

checks both against the jnp oracle, and emits a machine-readable
``BENCH_kernels.json`` for the perf trajectory.  Wall time in interpret mode
is NOT TPU performance — the stable cross-machine signals are the
tuned-vs-default *speedup ratio*, the compression ratios and the modeled
compressed-traffic bytes; those are what ``--check-against`` gates on
(>20% regression fails, as does any kernel-vs-ref mismatch).

Usage:
  PYTHONPATH=src python benchmarks/kernel_bench.py --smoke \\
      --output BENCH_kernels.json --check-against benchmarks/BENCH_baseline.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats, pruning
from repro.kernels import autotune, ref, registry

# (m, k, n, density, fmt)
SWEEP_FULL = [
    (256, 512, 512, 0.1, "tiled_csc"),
    (256, 512, 512, 0.3, "tiled_csc"),
    (256, 512, 512, 0.5, "tiled_csc"),
    (64, 512, 1024, 0.2, "tiled_csc"),
    (8, 512, 512, 0.3, "tiled_csc"),          # decode-like skinny M
    (256, 512, 512, 0.1, "block_csr"),
    (256, 512, 512, 0.3, "block_csr"),
    (64, 512, 1024, 0.2, "block_csr"),
]
SWEEP_SMOKE = [
    (64, 256, 256, 0.1, "tiled_csc"),
    (64, 256, 256, 0.5, "tiled_csc"),
    (64, 512, 512, 0.3, "block_csr"),
]

# (m, k, n, density, fmt, qmode) — quantized-pack gate cases
QUANT_SWEEP_FULL = [
    (64, 256, 256, 0.3, "tiled_csc", "int8"),
    (64, 256, 256, 0.3, "tiled_csc", "fp8"),
    (64, 256, 256, 0.3, "tiled_csc", "codebook"),
    (64, 512, 512, 0.3, "block_csr", "int8"),
    (64, 512, 512, 0.3, "block_csr", "codebook"),
]
QUANT_SWEEP_SMOKE = [
    (64, 256, 256, 0.3, "tiled_csc", "int8"),
    (64, 256, 256, 0.3, "tiled_csc", "codebook"),
    (64, 512, 512, 0.3, "block_csr", "int8"),
]
# Max relative output drift (vs the fp oracle, normalized by max|y_fp|)
# allowed per quantization mode.  int8 keeps 127 levels per tile; fp8 has a
# 3-bit mantissa; a 16-entry codebook is deliberately lossy.
QDRIFT_TOL = {"int8": 0.02, "fp8": 0.08, "codebook": 0.5}

ATOL = 5e-4
# Wall-clock on shared CI runners is noisy; the tuned-vs-default tripwire
# only counts a violation when it clears both a relative tolerance AND this
# absolute deadband, and sweep() re-measures violating cases — a case must
# lose repeatedly before the gate fires.
DEADBAND_US = 200.0
TRIPWIRE_RETRIES = 2


def _median_measure(fn, iters=5) -> float:
    """Median-of-k wall time in µs (compile + warm excluded).

    Medians are robust to the one-sided latency spikes shared runners
    inject; the autotuner keeps min-of-N for *selection* (optimistic is
    fine when every candidate gets the same treatment) but the gate
    compares two numbers across impls, where a single spike on either side
    must not flip the verdict.
    """
    import time

    jax.block_until_ready(fn())          # compile
    jax.block_until_ready(fn())          # warm
    times = []
    for _ in range(max(iters, 3)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def _tripwire_violation(rec, tol=0.2) -> bool:
    return rec["tuned"]["us"] > (1 + tol) * rec["default"]["us"] + DEADBAND_US


def _build(m, k, n, density, fmt, seed=0):
    key = jax.random.PRNGKey(seed)
    w = pruning.random_sparse(key, (k, n), density)
    if fmt == "block_csr":
        w = pruning.block_prune(w, density)
        p = formats.pack_block_csr(w)
    else:
        p = formats.pack_tiled_csc(w)
    x = jax.random.normal(jax.random.fold_in(key, 1), (m, k), jnp.float32)
    return x, w, p


def bench_case(m, k, n, density, fmt, *, iters=3, top_k=4,
               cache=None) -> dict:
    x, w, p = _build(m, k, n, density, fmt)
    backend = registry.current_backend()

    # default: the seed's hard-coded Pallas configuration
    default_impl = registry.get_impl(
        "pallas_fused" if fmt == "tiled_csc" else "pallas_block")
    dkey = registry.problem_key(p, m=m, backend=backend)
    default_params = default_impl.canonical_params(
        dkey, default_impl.default_params(dkey), m)

    # tuned: whatever the autotuner picks for the dispatch backend.  The
    # tuner always measures every impl's default config (the status quo),
    # so the default's time comes from the same measurement session as the
    # winner's — the speedup ratio is same-host, same-session.
    trials: list = []
    entry = autotune.tune(x, p, backend=backend, cache=cache,
                          top_k=top_k, iters=iters, force=True,
                          measure_fn=lambda fn: _median_measure(fn, iters),
                          trials_out=trials)
    tuned_impl = registry.get_impl(entry["impl"])
    tuned_us = entry["us"]
    if default_impl.supports(dkey):
        default_backend = backend
        default_us = next(
            (us for name, params, us in trials
             if name == default_impl.name and params == default_params),
            None)
        if default_us is None:
            raise RuntimeError(
                f"default config {default_impl.name} {default_params} missing "
                f"from tuner trials {[(n, p_) for n, p_, _ in trials]}")
    else:
        # backend where the pallas default can't run natively (e.g. gpu):
        # measure the hard-coded config via the interpreter so the
        # comparison still exists, and keep the record honest about it
        default_backend = "interpret"
        default_us = _median_measure(
            lambda: default_impl.run(x, p, backend=default_backend,
                                     **default_params), iters=iters)

    y_tuned = tuned_impl.run(x, p, backend=backend, **entry["params"])
    y_default = default_impl.run(x, p, backend=default_backend,
                                 **default_params)
    fn_ref = ref.sod_matmul_ref if fmt == "tiled_csc" else ref.block_matmul_ref
    y_ref = np.asarray(fn_ref(x, p))
    max_err = max(
        float(np.max(np.abs(np.asarray(y_tuned) - y_ref))),
        float(np.max(np.abs(np.asarray(y_default) - y_ref))),
    )
    return {
        "name": f"{fmt}_m{m}_k{k}_n{n}_d{density:g}",
        "fmt": fmt, "m": m, "k": k, "n": n, "density": density,
        "default": {"impl": default_impl.name, "params": default_params,
                    "us": round(default_us, 1)},
        "tuned": {"impl": entry["impl"], "params": entry["params"],
                  "us": round(tuned_us, 1)},
        "speedup": round(default_us / max(tuned_us, 1e-9), 3),
        "compression_ratio": round(
            p.nbytes_compressed() / p.nbytes_dense(), 5),
        "max_abs_err": max_err,
        "ref_ok": bool(max_err <= ATOL),
    }


def quant_case(m, k, n, density, fmt, qmode, *, iters=3,
               cache=None) -> dict:
    """Quantized-pack gate: bytes invariant + fused-dequant parity + drift.

    Three checks fold into ``ref_ok``:

    * **bytes** — the quantized pack stores strictly fewer bytes than the
      fp pack at the same density and layout, and the value payload shrinks
      by exactly the mode's bit ratio (int8 halves it, codebook quarters
      it);
    * **kernel parity** — tuned dispatch on the quantized pack matches the
      quantized jnp oracle at kernel ATOL (the Pallas fused dequant must
      agree with reference dequantization, not merely be close);
    * **drift** — the quantized oracle vs the *fp* oracle stays inside the
      per-mode :data:`QDRIFT_TOL` (normalized by max|y_fp|).
    """
    name = f"{fmt}_m{m}_k{k}_n{n}_d{density:g}_q{qmode}"
    if qmode == "fp8" and formats.fp8_dtype() is None:
        return {"name": name, "fmt": fmt, "qmode": qmode,
                "skipped": "no fp8 dtype in this jax build",
                "ref_ok": True}
    x, w, p_fp = _build(m, k, n, density, fmt)
    p_q = formats.quantize_packed(p_fp, qmode)
    backend = registry.current_backend()

    fn_ref = ref.sod_matmul_ref if fmt == "tiled_csc" else ref.block_matmul_ref
    y_fp = np.asarray(fn_ref(x, p_fp))
    y_qref = np.asarray(fn_ref(x, p_q))
    entry = autotune.tune(x, p_q, backend=backend, cache=cache,
                          top_k=2, iters=iters, force=True)
    y_q = np.asarray(registry.get_impl(entry["impl"]).run(
        x, p_q, backend=backend, **entry["params"]))

    kernel_err = float(np.max(np.abs(y_q - y_qref)))
    drift = float(np.max(np.abs(y_qref - y_fp))) / (
        float(np.max(np.abs(y_fp))) or 1.0)
    qb, fb = p_q.nbytes_compressed(), p_fp.nbytes_compressed()
    value_ratio = formats.qvalue_bits(qmode) / 16.0
    # bytes invariant: strictly below the fp pack, and the value payload
    # shrinks by exactly the mode's bit ratio (int8 → 0.5, codebook → 0.25)
    bytes_ok = qb < fb and value_ratio < 1.0
    return {
        "name": name,
        "fmt": fmt, "m": m, "k": k, "n": n, "density": density,
        "qmode": qmode,
        "tuned_impl": entry["impl"],
        "q_bytes": qb, "fp_bytes": fb,
        "value_bytes_ratio": value_ratio,
        "compression_ratio": round(qb / p_q.nbytes_dense(), 5),
        "kernel_err": kernel_err,
        "drift_vs_fp": round(drift, 5),
        "max_abs_err": kernel_err,
        "ref_ok": bool(bytes_ok and kernel_err <= ATOL
                       and drift <= QDRIFT_TOL[qmode]),
    }


def planner_quant_case(cache=None) -> dict:
    """Planner qmode gate: dense fallback judged on *quantized* bytes.

    At density 0.8 a tiled fp pack exceeds the dense byte count (≈1.2×),
    so the planner's fallback stores the layer dense — but the same layer
    under int8 packs to ≈0.8× and must stay packed.  Also checks byte
    parity: the bytes a plan promises (``PackPlan.compressed_bytes``)
    equal what the pack actually stores (``nbytes_compressed``), per mode.
    """
    from repro.core.sod import SoDConfig, sodify_params
    from repro.runtime import planner

    key = jax.random.PRNGKey(11)
    params = {"mlp": {"w_gate": pruning.random_sparse(key, (256, 512), 0.8)}}
    checks, parity_ok = {}, True
    for qmode in ("none", "int8"):
        sodc = SoDConfig(mode="tiled_csc", density=0.8, min_dim=128,
                         qmode=qmode)
        plan = planner.build_plan(params, sodc, cache=cache, m_values=(64,))
        e = plan.entries[".mlp.w_gate"]
        checks[qmode] = e.mode
        if e.mode != "dense":
            packed = sodify_params(params, sodc, plan=plan)
            leaf = packed["mlp"]["w_gate"]
            parity_ok &= leaf.nbytes_compressed() == e.compressed_bytes()
    # fp pack at d=0.8 must fall back to dense; int8 must stay packed and
    # the plan's byte promise must match the real pack exactly
    ok = (checks.get("none") == "dense"
          and checks.get("int8") == "tiled_csc" and parity_ok)
    return {
        "name": "planner_quant_dense_fallback",
        "fmt": "planner", "density": 0.8,
        "mode_by_qmode": checks,
        "plan_pack_byte_parity": bool(parity_ok),
        "ref_ok": bool(ok),
    }


def planner_case(cache=None) -> dict:
    """Planner-produced pack: the bench gate covers the per-layer plan path
    (build → pack-through-plan → dispatch under the active plan), not just
    global-config packing.

    Checks: the planned dispatch matches the jnp oracle on the packed
    operand, and the planner's per-layer choices never exceed the
    global-config pack in compressed bytes (the planner's core invariant —
    it may only swap a layer to a smaller format or leave it dense).
    """
    from repro.core import plan as plan_mod
    from repro.core import sod
    from repro.core.sod import SoDConfig, sodify_params, tree_weight_bytes
    from repro.runtime import planner

    key = jax.random.PRNGKey(7)

    def mk(i, shape):
        return pruning.random_sparse(jax.random.fold_in(key, i), shape, 0.3)

    params = {"blocks": {
        "mlp": {"w_gate": mk(0, (256, 512)), "w_down": mk(1, (512, 256))},
        "attn": {"wo": mk(2, (256, 256))},
    }}
    sodc = SoDConfig(mode="tiled_csc", density=0.3, min_dim=128)
    plan = planner.build_plan(params, sodc, cache=cache, m_values=(64,))
    packed = sodify_params(params, sodc, plan=plan)
    packed_global = sodify_params(params, sodc)
    pb = tree_weight_bytes(packed)
    gb = tree_weight_bytes(packed_global)

    w = packed["blocks"]["mlp"]["w_gate"]
    x = jax.random.normal(jax.random.fold_in(key, 9), (64, 256), jnp.float32)
    with plan_mod.use_plan(plan):
        y = np.asarray(sod.apply(x, w))
    if hasattr(w, "to_dense"):
        y_ref = np.asarray(ref.sod_matmul_ref(x, w))
    else:  # planner left this layer dense
        y_ref = np.asarray(x @ w)
    err = float(np.max(np.abs(y - y_ref)))
    return {
        "name": "planner_tiled_csc_smoke",
        "fmt": "planner", "m": 64, "k": 256, "n": 512, "density": 0.3,
        "plan": {p: e.describe() for p, e in sorted(plan.entries.items())},
        "compression_ratio": round(pb["compressed"] / max(pb["dense"], 1), 5),
        "planner_bytes": pb["compressed"],
        "global_bytes": gb["compressed"],
        "planner_bytes_le_global": bool(pb["compressed"] <= gb["compressed"]),
        "max_abs_err": err,
        "ref_ok": bool(err <= ATOL),
    }


def sweep(smoke=False, iters=None, cache=None) -> dict:
    cases = SWEEP_SMOKE if smoke else SWEEP_FULL
    iters = iters or (3 if smoke else 5)
    records = []
    for c in cases:
        rec = bench_case(*c, iters=iters, top_k=2 if smoke else 4,
                         cache=cache)
        # tuned losing to default is an invariant violation (the tuner
        # measures the default among its candidates), but on a shared
        # runner a single noisy session can fake one — re-measure before
        # letting the record carry a violation to the gate
        retries = 0
        while _tripwire_violation(rec) and retries < TRIPWIRE_RETRIES:
            retries += 1
            print(f"# tuned>default on {rec['name']} "
                  f"({rec['tuned']['us']}us vs {rec['default']['us']}us); "
                  f"re-measuring ({retries}/{TRIPWIRE_RETRIES})",
                  file=sys.stderr)
            rec = bench_case(*c, iters=iters, top_k=2 if smoke else 4,
                             cache=cache)
        rec["tripwire_retries"] = retries
        records.append(rec)
    for c in (QUANT_SWEEP_SMOKE if smoke else QUANT_SWEEP_FULL):
        records.append(quant_case(*c, iters=iters, cache=cache))
    records.append(planner_case(cache=cache))
    records.append(planner_quant_case(cache=cache))
    return {
        "schema": 1,
        "backend": registry.current_backend(),
        "kernel_hash": registry.kernel_hash(),
        "smoke": smoke,
        "records": records,
    }


def check_against(result: dict, baseline_path: str, tol=0.2) -> list[str]:
    """Regression gate vs a checked-in baseline.

    Machine-independent signals only — CI runners and dev boxes differ, so
    absolute wall times (and hence cross-run speedup numbers) are not
    comparable.  Gated, each with ``tol`` (default >20% fails):

    * kernel-vs-ref correctness (hard fail, no tolerance);
    * compression ratio vs the baseline (deterministic packing property);
    * tuned_us ≤ (1+tol)·default_us + DEADBAND_US *within this run*, on
      median-of-k times, and only after sweep() already re-measured the
      case TRIPWIRE_RETRIES times — a repeated violation.  This is an
      invariant tripwire, not a perf gate: tune() measures the default
      config among its candidates and picks the minimum, so the check only
      fires if that guarantee is refactored away (default dropped from the
      trials, winner selection broken).  Absolute perf regressions are
      tracked via the uploaded BENCH_kernels.json artifact trajectory, not
      gated — wall-clock is not comparable across CI hosts.
    """
    base = json.loads(pathlib.Path(baseline_path).read_text())
    problems = []
    if base.get("smoke") != result.get("smoke"):
        # SWEEP_FULL and SWEEP_SMOKE share no case names: comparing across
        # modes would flag every baseline record as uncovered.  Keep the
        # mode-independent checks (ref_ok, tuned≤default tripwire) only.
        print(f"# note: baseline is smoke={base.get('smoke')}, this sweep "
              f"is smoke={result.get('smoke')}; skipping baseline-keyed "
              f"comparisons", file=sys.stderr)
        base_recs = {}
    else:
        base_recs = {r["name"]: r for r in base.get("records", [])}
        covered = {rec["name"] for rec in result["records"]}
        for name in sorted(set(base_recs) - covered):
            problems.append(
                f"{name}: baseline record not covered by this sweep "
                f"(case renamed/removed? regenerate BENCH_baseline.json)")
    for rec in result["records"]:
        if not rec["ref_ok"]:
            problems.append(f"{rec['name']}: kernel-vs-ref mismatch "
                            f"(max_abs_err={rec['max_abs_err']:.2e})")
        if rec.get("planner_bytes_le_global") is False:
            problems.append(
                f"{rec['name']}: planner pack {rec['planner_bytes']}B "
                f"exceeds global-config pack {rec['global_bytes']}B")
        b = base_recs.get(rec["name"])
        if b is not None and "compression_ratio" in b:
            cr, bcr = rec.get("compression_ratio"), b["compression_ratio"]
            if cr is not None and abs(cr - bcr) > tol * bcr:
                problems.append(
                    f"{rec['name']}: compression_ratio {cr} vs baseline {bcr}")
        if "tuned" in rec and _tripwire_violation(rec, tol):
            problems.append(
                f"{rec['name']}: tuned config {rec['tuned']['us']}us lost to "
                f"default {rec['default']['us']}us by >{tol:.0%} "
                f"(+{DEADBAND_US:g}us deadband) even after "
                f"{rec.get('tripwire_retries', 0)} re-measurements")
    return problems


def run():
    """Legacy CSV interface for benchmarks/run.py.

    Returns (rows, mismatches): rows as (name, us, derived) for the tuned
    path, mismatches as human-readable kernel-vs-ref failures (the caller
    exits non-zero on any).  Uses a throwaway tuning cache: reproducing
    paper tables must not mutate the user's live dispatch cache.
    """
    import tempfile

    scratch = autotune.TuningCache(
        pathlib.Path(tempfile.mkdtemp(prefix="repro_bench_"))
        / "tuning_cache.json")
    result = sweep(smoke=True, cache=scratch)
    rows, mismatches = [], []
    for rec in result["records"]:
        if "default" in rec:
            rows.append((f"kernel_{rec['name']}_default",
                         rec["default"]["us"], rec["compression_ratio"]))
            rows.append(
                (f"kernel_{rec['name']}_tuned[{rec['tuned']['impl']}]",
                 rec["tuned"]["us"], rec["speedup"]))
        else:  # planner/quant record: ratio only, no timed pair
            rows.append((f"kernel_{rec['name']}", 0.0,
                         rec.get("compression_ratio", 0.0)))
        if not rec["ref_ok"]:
            mismatches.append(
                f"{rec['name']}: max_abs_err={rec['max_abs_err']:.2e}")
    return rows, mismatches


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, 3 iters — the CI benchmark-smoke job")
    ap.add_argument("--output", default="BENCH_kernels.json")
    ap.add_argument("--check-against", default=None,
                    help="baseline BENCH_kernels.json; fail on >20%% "
                         "regression or any kernel-vs-ref mismatch")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--junit", default=None,
                    help="also write the per-case ref checks and "
                         "regression gates as a junit XML file")
    ap.add_argument("--tuning-cache", default=None,
                    help="tuning-cache path; default is a throwaway temp "
                         "cache — benchmarking must not overwrite the "
                         "user's live dispatch cache")
    args = ap.parse_args(argv)

    if args.tuning_cache:
        cache = autotune.install_cache(args.tuning_cache)
    else:
        import tempfile

        cache = autotune.TuningCache(
            pathlib.Path(tempfile.mkdtemp(prefix="repro_bench_"))
            / "tuning_cache.json")
    result = sweep(smoke=args.smoke, iters=args.iters, cache=cache)

    pathlib.Path(args.output).write_text(json.dumps(result, indent=1))
    print(f"# wrote {args.output} ({len(result['records'])} records, "
          f"backend={result['backend']})")
    hdr = f"{'case':34s} {'default_us':>11s} {'tuned_us':>9s} {'speedup':>8s} {'tuned impl':>14s} ok"
    print(hdr)
    for rec in result["records"]:
        if "default" not in rec:   # planner/quant record: bytes, not time
            status = "PASS" if rec["ref_ok"] else "FAIL"
            if "planner_bytes" in rec:
                detail = (f"planner {rec['planner_bytes']}B vs "
                          f"global {rec['global_bytes']}B")
            elif "qmode" in rec:
                detail = (f"skipped: {rec['skipped']}" if "skipped" in rec
                          else f"q={rec['qmode']} {rec['q_bytes']}B vs fp "
                               f"{rec['fp_bytes']}B drift={rec['drift_vs_fp']}")
            else:
                detail = str(rec.get("mode_by_qmode", ""))
            print(f"{rec['name']:34s} {detail} {status}")
            continue
        print(f"{rec['name']:34s} {rec['default']['us']:11.1f} "
              f"{rec['tuned']['us']:9.1f} {rec['speedup']:8.2f} "
              f"{rec['tuned']['impl']:>14s} "
              f"{'PASS' if rec['ref_ok'] else 'FAIL'}")

    problems = []
    if args.check_against:
        problems = check_against(result, args.check_against)
    else:
        problems = [f"{r['name']}: kernel-vs-ref mismatch"
                    for r in result["records"] if not r["ref_ok"]]
    for p in problems:
        print(f"REGRESSION: {p}", file=sys.stderr)
    if args.junit:
        from _junit import write_junit

        gates = [(f"ref:{r['name']}",
                  None if r["ref_ok"]
                  else f"max_abs_err={r.get('max_abs_err')}")
                 for r in result["records"]]
        # ref mismatches already failed above — don't double-count them
        ref_failed = {r["name"] for r in result["records"]
                      if not r["ref_ok"]}
        gates += [(f"regression:{p.split(':', 1)[0]}", p)
                  for p in problems
                  if p.split(":", 1)[0] not in ref_failed]
        print(f"# wrote {write_junit(args.junit, 'kernel_bench', gates)}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
