"""Serving-engine benchmark: continuous batching, dense vs Sparse-on-Dense.

For one architecture this replays the same seeded Poisson request trace
through the continuous-batching engine three ways — dense weights, SoD
``tiled_csc`` and SoD ``block_csr`` at matched density, the packed
variants under planner-built :class:`~repro.core.plan.PackPlan`s — and
emits ``BENCH_serving.json``.

Two correctness gates run on every case (CI fails on either):

* **engine-vs-ref** — every request's greedy tokens from the engine must
  be identical to per-request static-batch generation
  (:func:`repro.serving.engine.static_generate`) with the same weights;
* **compressed-bytes invariant** — the SoD variants' stored weight bytes
  must be strictly below the dense variant's.

Wall-clock throughput on CPU/interpret is NOT accelerator performance;
the engine reports steady-state tokens/sec with compile/warmup excluded
(the stable part), and the cross-variant signal worth tracking is the
bytes column, not absolute tok/s.

Usage:
  PYTHONPATH=src python benchmarks/serving_bench.py --smoke \\
      --output BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

import jax

from repro import configs
from repro.core.sod import SoDConfig, sodify_params, tree_weight_bytes
from repro.kernels import autotune
from repro.models.model import build_model
from repro.runtime import planner
from repro.serving import Engine, bucket_len, poisson_trace, static_generate

VARIANTS = ("dense", "tiled_csc", "block_csr")


def bench_variant(arch: str, mode: str, *, density: float, requests: int,
                  max_prompt: int, max_new: int, max_slots: int,
                  page_size: int, seed: int, cache=None) -> dict:
    cfg = configs.reduced(configs.get_config(arch))
    if mode != "dense":
        # block_csr needs block-structured pruning: magnitude-scattered
        # survivors touch nearly every sub-block, so block packing would
        # (correctly) dense-fallback everywhere and measure nothing
        method = "block" if mode == "block_csr" else "magnitude"
        cfg = cfg.with_(sod=SoDConfig(mode=mode, density=density,
                                      prune_method=method, min_dim=64))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    plan = None
    if cfg.sod.enabled:
        if cfg.family in ("hybrid", "ssm"):
            m_values = (1, max_slots)
        else:
            m_values = (bucket_len(max_prompt, page_size, cfg.attn_chunk),
                        max_slots)
        plan = planner.load_or_build(
            "auto", params, cfg.sod, cfg=cfg, cache=cache,
            m_values=m_values)
        params = sodify_params(params, cfg.sod, plan=plan)
    wb = tree_weight_bytes(params)

    if cfg.family in ("hybrid", "ssm"):
        max_len = max_prompt + max_new
    else:
        max_len = bucket_len(max_prompt, page_size, cfg.attn_chunk) + max_new
    trace = poisson_trace(requests, 0.5, max_prompt=max_prompt,
                          max_new=max_new, vocab=cfg.vocab, seed=seed)
    eng = Engine(model, params, max_slots=max_slots, page_size=page_size,
                 max_len=max_len, plan=plan)
    res = eng.run(trace)

    mismatches = []
    for req in trace:
        ref = static_generate(model, params, req, plan=plan)
        if res["tokens"][req.rid] != ref:
            mismatches.append({"rid": req.rid, "ref": ref,
                               "engine": res["tokens"][req.rid]})
    rec = {
        "arch": cfg.name, "mode": mode,
        "density": density if mode != "dense" else 1.0,
        "requests": requests, "max_slots": max_slots,
        "page_size": page_size if eng.paged else None,
        "plan_layers": len(plan) if plan is not None else 0,
        "weight_bytes": wb["compressed"],
        "weight_bytes_dense": wb["dense"],
        "compression_ratio": round(wb["ratio"], 4),
        "match_static": not mismatches,
        "mismatches": mismatches,
        **{k: res["stats"][k] for k in
           ("warmup_s", "steady_s", "steady_tok_per_s", "completed",
            "generated_tokens", "p50_latency_s", "p99_latency_s")},
    }
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--density", type=float, default=0.3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace (CI gate sizing)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--output", default="BENCH_serving.json")
    ap.add_argument("--tuning-cache", default=None)
    args = ap.parse_args(argv)

    if args.smoke:
        args.requests, args.prompt_len, args.gen = 6, 10, 5
        args.max_slots, args.page_size = 3, 4
    cache = autotune.install_cache(args.tuning_cache)

    cases = []
    for mode in VARIANTS:
        rec = bench_variant(
            args.arch, mode, density=args.density, requests=args.requests,
            max_prompt=args.prompt_len, max_new=args.gen,
            max_slots=args.max_slots, page_size=args.page_size,
            seed=args.seed, cache=cache)
        cases.append(rec)
        print(f"{rec['mode']:>10}  match={rec['match_static']!s:5}  "
              f"bytes={rec['weight_bytes']:>9}  "
              f"ratio={rec['compression_ratio']:.3f}  "
              f"steady={rec['steady_tok_per_s']:.1f} tok/s  "
              f"p99={rec['p99_latency_s']:.3f}s")

    dense_bytes = next(c["weight_bytes"] for c in cases
                       if c["mode"] == "dense")
    failures = []
    for c in cases:
        if not c["match_static"]:
            failures.append(f"{c['mode']}: engine tokens diverge from "
                            f"static reference ({len(c['mismatches'])} reqs)")
        if c["mode"] != "dense" and c["weight_bytes"] >= dense_bytes:
            failures.append(
                f"{c['mode']}: compressed bytes {c['weight_bytes']} not "
                f"below dense {dense_bytes}")

    out = {
        "kind": "serving_bench",
        "arch": args.arch, "density": args.density, "smoke": args.smoke,
        "cases": cases, "failures": failures, "ok": not failures,
    }
    path = pathlib.Path(args.output)
    path.write_text(json.dumps(out, indent=2))
    print(f"wrote {path}")
    if failures:
        print("FAIL:\n  " + "\n  ".join(failures))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
