"""Serving-engine benchmark: continuous batching, dense vs Sparse-on-Dense.

Two modes, both emitting ``BENCH_serving.json`` (and optionally a junit
XML of every gate for the CI artifact trail):

* **sweep** (default / ``--smoke``): replays the same seeded Poisson
  request trace through the continuous-batching engine three ways — dense
  weights, SoD ``tiled_csc`` and SoD ``block_csr`` at matched density,
  the packed variants under planner-built
  :class:`~repro.core.plan.PackPlan`s.
* **stress** (``--stress``): a high-pressure shared-prefix trace (small
  page pool + long prompts + one common few-shot prefix) through the
  full scheduler — chunked prefill, preemption with page-level swapping,
  and copy-on-write prefix sharing all enabled — and gates that each
  actually fired: at least one preemption/swap-in cycle, prefix pages
  reused (pages allocated for prompts strictly below the sum of prompt
  pages), and multi-chunk prefill, with tokens still bit-identical to
  the static reference.  A third stress record replays two *identical*
  epochs of a repeated system prompt through the persistent multi-tier
  prefix cache (tiny HBM budget + disk spill dir) and gates that the
  second epoch prefills **zero fresh pages**, that the host tier actually
  served promotions, that per-tier byte counters land in the record, and
  that pool + trie + cache tiers drain clean after a flush
  (``docs/caching.md``).
* **spec** (``--spec``): sparsity-tiered speculative decoding — a
  self-draft leg (gates acceptance_rate > 0 and tokens_per_step > 1)
  and a cost-model sparse-draft leg (gates the draft tier's bytes below
  the target tier's), both gating bit-identical tokens vs the
  non-speculative greedy reference and a clean page-pool drain after
  rejected-window rollbacks.
* **stress-spec** (``--stress-spec``): every feature composed at once —
  speculative decoding with a sparse draft tier, chunked prefill,
  preemption with page swapping, and copy-on-write prefix sharing on a
  bursty shared-prefix trace against a starved pool — gated on each
  mechanism firing *while the others are on*: at least one preemption
  landing mid-draft-window (speculative pages trimmed, not swapped), at
  least one page-returning window rollback, prefix-page reuse, and a
  clean pool/trie drain, with tokens bit-identical to the static
  reference.

Correctness gates (CI fails on any):

* **engine-vs-ref** — every request's greedy tokens from the engine must
  be identical to per-request static-batch generation
  (:func:`repro.serving.engine.static_generate`) with the same weights;
* **compressed-bytes invariant** — the SoD variants' stored weight bytes
  must be strictly below the dense variant's;
* **stress counters** (stress mode) — preemptions >= 1, swapped-in pages
  >= 1, shared prompt pages > 0, prompt pages allocated < sum of prompt
  pages, prefill chunks > completed requests;
* **trace identity** (smoke mode) — the same trace with a live
  :class:`repro.obs.Tracer` vs the default no-op tracer must emit
  bit-identical tokens (observability cannot perturb the engine).

Every leg also records the per-request latency breakdown percentiles
(queue wait / ttft / tpot, p50+p99) into ``BENCH_serving.json``, and
``--trace out.trace.json`` writes a Chrome trace-event timeline of the
whole run (summarize with ``scripts/trace_report.py``).

Wall-clock throughput on CPU/interpret is NOT accelerator performance;
the engine reports steady-state tokens/sec with compile/warmup excluded
(the stable part), and the cross-variant signal worth tracking is the
bytes column, not absolute tok/s.

Usage:
  PYTHONPATH=src python benchmarks/serving_bench.py --smoke \\
      --output BENCH_serving.json
  PYTHONPATH=src python benchmarks/serving_bench.py --stress \\
      --output BENCH_serving.json --junit pytest-junit-serving.xml
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import tempfile

import jax

from _junit import write_junit
from repro import configs, obs
from repro.core.sod import SoDConfig, sodify_params, tree_weight_bytes
from repro.kernels import autotune
from repro.models.model import build_model
from repro.runtime import planner
from repro.serving import (
    Engine,
    bucket_len,
    poisson_trace,
    repeated_prompt_trace,
    shared_prefix_trace,
    static_generate,
    stress_spec_trace,
)

VARIANTS = ("dense", "tiled_csc", "block_csr")

# per-request latency breakdown percentiles — emitted by every leg so the
# queue/ttft/tpot tail is visible next to throughput in BENCH_serving.json
LATENCY_KEYS = ("queue_wait_p50_s", "queue_wait_p99_s", "ttft_p50_s",
                "ttft_p99_s", "tpot_p50_s", "tpot_p99_s")

STRESS_COUNTERS = (
    "prefill_chunks", "preemptions", "swapped_out_pages",
    "swapped_in_pages", "cow_forks", "shared_prompt_pages",
    "prompt_pages_total", "prompt_pages_fresh",
)

STRESS_SPEC_COUNTERS = STRESS_COUNTERS + (
    "spec_windows", "draft_proposed", "draft_accepted", "acceptance_rate",
    "spec_rollbacks", "spec_rollback_pages", "spec_window_preemptions",
)

# persistent prefix-cache counters — every name must have a glossary row
# in docs/serving.md (gated by tests/test_prefix_cache.py)
CACHE_COUNTERS = (
    "prefix_hits", "prefix_misses", "prefix_hbm_hits", "prefix_host_hits",
    "prefix_disk_hits", "prefix_restored_pages", "prefix_demotions_host",
    "prefix_demotions_disk", "reprefill_tokens_saved", "prefix_bytes_hbm",
    "prefix_bytes_host", "prefix_bytes_disk",
)


def _build_packed(arch: str, mode: str, *, density: float, seed: int,
                  m_values, cache):
    """(cfg, model, params, plan) with SoD packing for non-dense modes."""
    cfg = configs.reduced(configs.get_config(arch))
    if mode != "dense":
        # block_csr needs block-structured pruning: magnitude-scattered
        # survivors touch nearly every sub-block, so block packing would
        # (correctly) dense-fallback everywhere and measure nothing
        method = "block" if mode == "block_csr" else "magnitude"
        cfg = cfg.with_(sod=SoDConfig(mode=mode, density=density,
                                      prune_method=method, min_dim=64))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    plan = None
    if cfg.sod.enabled:
        plan = planner.load_or_build(
            "auto", params, cfg.sod, cfg=cfg, cache=cache,
            m_values=m_values)
        params = sodify_params(params, cfg.sod, plan=plan)
    return cfg, model, params, plan


def bench_variant(arch: str, mode: str, *, density: float, requests: int,
                  max_prompt: int, max_new: int, max_slots: int,
                  page_size: int, seed: int, cache=None) -> dict:
    cfg0 = configs.reduced(configs.get_config(arch))
    if cfg0.family in ("hybrid", "ssm"):
        m_values = (1, max_slots)
    else:
        m_values = (bucket_len(max_prompt, page_size, cfg0.attn_chunk),
                    max_slots)
    cfg, model, params, plan = _build_packed(
        arch, mode, density=density, seed=seed, m_values=m_values,
        cache=cache)
    wb = tree_weight_bytes(params)

    if cfg.family in ("hybrid", "ssm"):
        max_len = max_prompt + max_new
    else:
        max_len = bucket_len(max_prompt, page_size, cfg.attn_chunk) + max_new
    trace = poisson_trace(requests, 0.5, max_prompt=max_prompt,
                          max_new=max_new, vocab=cfg.vocab, seed=seed)
    eng = Engine(model, params, max_slots=max_slots, page_size=page_size,
                 max_len=max_len, plan=plan)
    res = eng.run(trace)

    mismatches = []
    for req in trace:
        ref = static_generate(model, params, req, plan=plan)
        if res["tokens"][req.rid] != ref:
            mismatches.append({"rid": req.rid, "ref": ref,
                               "engine": res["tokens"][req.rid]})
    rec = {
        "arch": cfg.name, "mode": mode,
        "density": density if mode != "dense" else 1.0,
        "requests": requests, "max_slots": max_slots,
        "page_size": page_size if eng.paged else None,
        "plan_layers": len(plan) if plan is not None else 0,
        "weight_bytes": wb["compressed"],
        "weight_bytes_dense": wb["dense"],
        "compression_ratio": round(wb["ratio"], 4),
        "match_static": not mismatches,
        "mismatches": mismatches,
        **{k: res["stats"][k] for k in
           ("warmup_s", "steady_s", "steady_tok_per_s", "completed",
            "generated_tokens", "p50_latency_s", "p99_latency_s")
           + LATENCY_KEYS},
    }
    return rec


def stress_variant(arch: str, mode: str, *, density: float, requests: int,
                   prefix_len: int, max_prompt: int, max_new: int,
                   max_slots: int, page_size: int, prefill_chunk: int,
                   n_pages: int, arrival_gap: int, seed: int,
                   cache=None) -> dict:
    """High-pressure replay: chunked prefill + preemption + prefix
    sharing on, pool sized to force at least one swap cycle."""
    cfg, model, params, plan = _build_packed(
        arch, mode, density=density, seed=seed,
        m_values=(prefill_chunk, max_slots), cache=cache)
    if cfg.family in ("hybrid", "ssm"):
        raise ValueError("stress mode exercises the paged-KV scheduler; "
                         f"{cfg.family!r} keeps O(1) slot state")
    wb = tree_weight_bytes(params)
    max_len = max_prompt + max_new
    trace = shared_prefix_trace(
        requests, prefix_len=prefix_len, max_prompt=max_prompt,
        max_new=max_new, vocab=cfg.vocab, seed=seed,
        arrival_gap=arrival_gap)
    eng = Engine(model, params, max_slots=max_slots, page_size=page_size,
                 max_len=max_len, n_pages=n_pages, plan=plan,
                 prefill_chunk=prefill_chunk, preemption=True,
                 prefix_sharing=True)
    res = eng.run(trace)

    mismatches = []
    for req in trace:
        ref = static_generate(model, params, req, plan=plan)
        if res["tokens"][req.rid] != ref:
            mismatches.append({"rid": req.rid, "ref": ref,
                               "engine": res["tokens"][req.rid]})
    s = res["stats"]
    rec = {
        "arch": cfg.name, "mode": mode, "stress": True,
        "density": density if mode != "dense" else 1.0,
        "requests": requests, "max_slots": max_slots,
        "page_size": page_size, "n_pages": n_pages,
        "prefill_chunk": prefill_chunk, "prefix_len": prefix_len,
        "plan_layers": len(plan) if plan is not None else 0,
        "weight_bytes": wb["compressed"],
        "weight_bytes_dense": wb["dense"],
        "compression_ratio": round(wb["ratio"], 4),
        "match_static": not mismatches,
        "mismatches": mismatches,
        "preempt_order": list(eng.preempt_log),
        **{k: s[k] for k in STRESS_COUNTERS},
        **{k: s[k] for k in
           ("warmup_s", "steady_s", "steady_tok_per_s", "completed",
            "generated_tokens", "p50_latency_s", "p99_latency_s")
           + LATENCY_KEYS},
    }
    # post-run allocator hygiene: every page back, nothing leaked
    rec["pool_clean"] = (not eng.page_pool.allocated
                         and eng.page_pool.free_count
                         == eng.page_pool.n_pages - 1
                         and (eng.trie is None or len(eng.trie) == 0))
    return rec


def cache_variant(arch: str, *, density: float, seed: int,
                  cache=None) -> dict:
    """Two identical epochs of a repeated system prompt through the
    persistent multi-tier prefix cache.

    Epoch 1 prefills and (on completion) retains each prompt's pages in
    the cache; the HBM budget is squeezed to 3 pages so retention demotes
    most of them to the host tier, with write-through spill to a disk
    dir.  Epoch 2 replays the *same* prompts under fresh request ids —
    every prompt page must come back from a trie hold or a host/disk
    promotion, so the fresh-prefill page counter must not move at all
    (the ``epoch2_fresh_pages == 0`` gate).  Tokens stay bit-identical to
    the static reference in both epochs, and after
    :meth:`~repro.serving.engine.Engine.flush_prefix_cache` the pool,
    trie, and HBM/host tiers must drain clean (``docs/caching.md``).
    """
    requests, prefix_len, suffix_len, max_new = 3, 8, 4, 4
    max_slots, page_size, prefill_chunk, n_pages = 2, 4, 4, 12
    budget_pages = 3
    cfg, model, params, plan = _build_packed(
        arch, "dense", density=density, seed=seed,
        m_values=(prefill_chunk, max_slots), cache=cache)
    if cfg.family in ("hybrid", "ssm"):
        raise ValueError("the prefix cache rides the paged-KV pool; "
                         f"{cfg.family!r} keeps O(1) slot state")
    # probe one tiny pool for the per-page byte size so the budget can be
    # expressed in pages — same formula the engine uses internally
    probe = model.init_paged_pool(2, page_size)
    k = probe["k"]
    page_nbytes = 2 * (k.size // k.shape[2]) * k.dtype.itemsize
    max_len = prefix_len + suffix_len + max_new

    epochs = [repeated_prompt_trace(
        requests, prefix_len=prefix_len, suffix_len=suffix_len,
        max_new=max_new, vocab=cfg.vocab, page_size=page_size, seed=seed,
        arrival_gap=2, rid_base=e * requests) for e in range(2)]
    with tempfile.TemporaryDirectory() as tmp:
        eng = Engine(model, params, max_slots=max_slots,
                     page_size=page_size, max_len=max_len, n_pages=n_pages,
                     plan=plan, prefill_chunk=prefill_chunk,
                     prefix_sharing=True,
                     prefix_cache_budget=budget_pages * page_nbytes,
                     prefix_cache_dir=tmp)
        tokens: dict[int, list[int]] = {}
        fresh = []
        for trace in epochs:
            res = eng.run(trace)
            tokens.update(res["tokens"])
            fresh.append(res["stats"]["prompt_pages_fresh"])
        s = dict(res["stats"])
        eng.flush_prefix_cache()
        pool_clean = (not eng.page_pool.allocated
                      and eng.page_pool.free_count
                      == eng.page_pool.n_pages - 1
                      and len(eng.trie) == 0
                      and eng.prefix_cache.bytes_by_tier()["hbm"] == 0)

    mismatches = []
    for req in epochs[0] + epochs[1]:
        ref = static_generate(model, params, req, plan=plan)
        if tokens[req.rid] != ref:
            mismatches.append({"rid": req.rid, "ref": ref,
                               "engine": tokens[req.rid]})
    rec = {
        "arch": cfg.name, "mode": "prefix_cache", "stress": True,
        "density": 1.0, "requests": 2 * requests, "max_slots": max_slots,
        "page_size": page_size, "n_pages": n_pages,
        "prefill_chunk": prefill_chunk, "prefix_len": prefix_len,
        "cache_budget_pages": budget_pages,
        "cache_budget_bytes": budget_pages * page_nbytes,
        "match_static": not mismatches,
        "mismatches": mismatches,
        "epoch1_fresh_pages": fresh[0],
        "epoch2_fresh_pages": fresh[1] - fresh[0],
        "pool_clean": pool_clean,
        **{k: s[k] for k in CACHE_COUNTERS},
        **{k: s[k] for k in
           ("warmup_s", "steady_s", "steady_tok_per_s", "completed",
            "generated_tokens", "p50_latency_s", "p99_latency_s")
           + LATENCY_KEYS},
    }
    return rec


def spec_variant(arch: str, draft: str, *, density: float, spec_k: int,
                 requests: int, max_prompt: int, max_new: int,
                 max_slots: int, page_size: int, seed: int,
                 cache=None) -> dict:
    """Speculative-decoding replay with a ``self`` or ``sparse`` draft.

    ``self`` drafts with the target tier itself — acceptance is near 1
    (only ragged end-of-sequence windows count unconsumed proposals as
    rejected), which gates the propose/verify/accept/rollback machinery.
    ``sparse`` drafts with the planner's cost-model-chosen aggressive
    tier; on random-init weights its argmax almost never agrees (flat
    logits flip under any pruning), so it gates the rollback-heavy path
    plus the draft tier's compressed-bytes saving.  Both must stay
    bit-identical to the non-speculative static reference.
    """
    cfg = configs.reduced(configs.get_config(arch)).with_(
        sod=SoDConfig(mode="tiled_csc", density=density,
                      prune_method="magnitude", min_dim=64))
    model = build_model(cfg)
    raw = model.init(jax.random.PRNGKey(seed))
    m_values = (bucket_len(max_prompt, page_size, cfg.attn_chunk),
                max_slots)
    plan = planner.load_or_build("auto", raw, cfg.sod, cfg=cfg, cache=cache,
                                 m_values=m_values)
    draft_density = None
    if draft == "sparse":
        # draft packs the raw weights — before the target prune below
        draft_cfg, draft_plan = planner.build_draft_plan(
            raw, cfg.sod, spec_k=spec_k, cfg=cfg, cache=cache,
            m_values=m_values)
        draft_params = sodify_params(raw, draft_cfg, plan=draft_plan)
        draft_density = draft_plan.meta["density_choice"]["chosen"]
    params = sodify_params(raw, cfg.sod, plan=plan)
    if draft == "self":
        draft_params, draft_plan, draft_density = params, plan, density

    max_len = bucket_len(max_prompt, page_size, cfg.attn_chunk) + max_new
    trace = poisson_trace(requests, 0.5, max_prompt=max_prompt,
                          max_new=max_new, vocab=cfg.vocab, seed=seed)
    eng = Engine(model, params, max_slots=max_slots, page_size=page_size,
                 max_len=max_len, plan=plan, spec_k=spec_k,
                 draft_params=draft_params, draft_plan=draft_plan)
    res = eng.run(trace)

    mismatches = []
    for req in trace:
        ref = static_generate(model, params, req, plan=plan)
        if res["tokens"][req.rid] != ref:
            mismatches.append({"rid": req.rid, "ref": ref,
                               "engine": res["tokens"][req.rid]})
    s = res["stats"]
    rec = {
        "arch": cfg.name, "mode": f"spec_{draft}", "spec": True,
        "density": density, "draft_density": draft_density,
        "spec_k": spec_k, "requests": requests, "max_slots": max_slots,
        "page_size": page_size,
        "weight_bytes": plan.compressed_bytes(),
        "draft_weight_bytes": draft_plan.compressed_bytes(),
        "match_static": not mismatches,
        "mismatches": mismatches,
        **{k: s[k] for k in
           ("spec_windows", "draft_proposed", "draft_accepted",
            "acceptance_rate", "tokens_per_step",
            "warmup_s", "steady_s", "steady_tok_per_s", "completed",
            "generated_tokens", "p50_latency_s", "p99_latency_s")
           + LATENCY_KEYS},
    }
    rec["pool_clean"] = (not eng.page_pool.allocated
                         and eng.page_pool.free_count
                         == eng.page_pool.n_pages - 1)
    return rec


def stress_spec_variant(arch: str, *, density: float, seed: int,
                        cache=None) -> dict:
    """Everything at once: sparsity-tiered speculative decoding composed
    with chunked prefill, preemption/page swapping, and copy-on-write
    prefix sharing, on a bursty shared-prefix trace against a starved
    pool.

    The target tier is planner-packed ``tiled_csc``; the draft is the
    cost model's aggressive tier, whose argmax on random-init weights
    almost never agrees with the target's — every window rolls back, so
    rollback runs *concurrently* with preemption and refcounted prefix
    pages.  Sizing is calibrated (3 slots x up-to-6 lifetime pages vs 9
    usable pages, bursts of 2) so that a preemption lands while a draft
    window is in flight (``spec_window_preemptions``), speculative pages
    are trimmed rather than swapped, and prefix pages are still reused —
    with tokens bit-identical to the static reference throughout.
    """
    spec_k, requests, prefix_len = 2, 6, 8
    max_prompt, max_new, max_slots = 14, 8, 3
    page_size, prefill_chunk, n_pages = 4, 4, 10
    cfg = configs.reduced(configs.get_config(arch)).with_(
        sod=SoDConfig(mode="tiled_csc", density=density,
                      prune_method="magnitude", min_dim=64))
    model = build_model(cfg)
    raw = model.init(jax.random.PRNGKey(seed))
    m_values = (prefill_chunk, max_slots)
    plan = planner.load_or_build("auto", raw, cfg.sod, cfg=cfg, cache=cache,
                                 m_values=m_values)
    # draft packs the raw weights — before the target prune below
    draft_cfg, draft_plan = planner.build_draft_plan(
        raw, cfg.sod, spec_k=spec_k, cfg=cfg, cache=cache,
        m_values=m_values)
    draft_params = sodify_params(raw, draft_cfg, plan=draft_plan)
    params = sodify_params(raw, cfg.sod, plan=plan)

    max_len = max_prompt + max_new + spec_k
    trace = stress_spec_trace(
        requests, prefix_len=prefix_len, max_prompt=max_prompt,
        max_new=max_new, vocab=cfg.vocab, seed=seed, burst=2, rate=0.3)
    eng = Engine(model, params, max_slots=max_slots, page_size=page_size,
                 max_len=max_len, n_pages=n_pages, plan=plan,
                 spec_k=spec_k, draft_params=draft_params,
                 draft_plan=draft_plan, prefill_chunk=prefill_chunk,
                 preemption=True, prefix_sharing=True)
    res = eng.run(trace)

    mismatches = []
    for req in trace:
        ref = static_generate(model, params, req, plan=plan)
        if res["tokens"][req.rid] != ref:
            mismatches.append({"rid": req.rid, "ref": ref,
                               "engine": res["tokens"][req.rid]})
    s = res["stats"]
    rec = {
        "arch": cfg.name, "mode": "stress_spec", "stress": True,
        "spec": True, "density": density,
        "draft_density": draft_plan.meta["density_choice"]["chosen"],
        "spec_k": spec_k, "requests": requests, "max_slots": max_slots,
        "page_size": page_size, "n_pages": n_pages,
        "prefill_chunk": prefill_chunk, "prefix_len": prefix_len,
        "weight_bytes": plan.compressed_bytes(),
        "draft_weight_bytes": draft_plan.compressed_bytes(),
        "match_static": not mismatches,
        "mismatches": mismatches,
        "preempt_order": list(eng.preempt_log),
        "trimmed_pages": eng.page_pool.trimmed_pages,
        **{k: s[k] for k in STRESS_SPEC_COUNTERS},
        **{k: s[k] for k in
           ("warmup_s", "steady_s", "steady_tok_per_s", "completed",
            "generated_tokens", "tokens_per_step",
            "p50_latency_s", "p99_latency_s") + LATENCY_KEYS},
    }
    rec["pool_clean"] = (not eng.page_pool.allocated
                         and eng.page_pool.free_count
                         == eng.page_pool.n_pages - 1
                         and len(eng.trie) == 0)
    return rec


def trace_identity_case(arch: str, *, requests: int, max_prompt: int,
                        max_new: int, max_slots: int, page_size: int,
                        seed: int) -> dict:
    """Gate: observability must not perturb the engine.

    Replays the same seeded trace through two engines over the same
    weights — one with the default no-op tracer, one with a live
    :class:`repro.obs.Tracer` — and requires bit-identical tokens.
    """
    cfg = configs.reduced(configs.get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    max_len = bucket_len(max_prompt, page_size, cfg.attn_chunk) + max_new
    outs = []
    for tracer in (obs.NULL_TRACER, obs.Tracer()):
        trace = poisson_trace(requests, 0.5, max_prompt=max_prompt,
                              max_new=max_new, vocab=cfg.vocab, seed=seed)
        eng = Engine(model, params, max_slots=max_slots,
                     page_size=page_size, max_len=max_len, tracer=tracer)
        outs.append(eng.run(trace)["tokens"])
    return {"arch": cfg.name, "mode": "trace_identity",
            "requests": requests, "match": outs[0] == outs[1]}


def _stress_spec_gates(rec: dict) -> list[tuple[str, str | None]]:
    """(gate name, failure message or None) for the composed stress-spec
    record: every mechanism must have fired *while the others were on*."""
    m = rec["mode"]

    def gate(name, ok, msg):
        return (f"{m}:{name}", None if ok else msg)

    return [
        gate("match_static", rec["match_static"],
             f"composed-engine tokens diverge from static reference "
             f"({len(rec['mismatches'])} reqs)"),
        gate("completed", rec["completed"] == rec["requests"],
             f"only {rec['completed']}/{rec['requests']} completed"),
        gate("chunked_prefill", rec["prefill_chunks"] > rec["requests"],
             f"prefill_chunks={rec['prefill_chunks']} — chunking never "
             f"split a prompt (requests={rec['requests']})"),
        gate("windows_ran", rec["spec_windows"] > 0,
             "no speculative windows executed"),
        gate("preemption_cycle",
             rec["preemptions"] >= 1 and rec["swapped_in_pages"] >= 1,
             f"no full preemption/swap-in cycle (preemptions="
             f"{rec['preemptions']}, swapped_in={rec['swapped_in_pages']})"),
        gate("window_preempted", rec["spec_window_preemptions"] >= 1,
             "no preemption landed while a draft window was in flight — "
             "the trim-not-swap path never ran"),
        gate("rollback", rec["spec_rollbacks"] >= 1,
             "no rejected window crossed a page boundary — rollback "
             "never returned a page"),
        gate("prefix_reuse", rec["shared_prompt_pages"] > 0,
             "no prompt pages were shared"),
        gate("draft_bytes",
             rec["draft_weight_bytes"] < rec["weight_bytes"],
             f"draft tier bytes {rec['draft_weight_bytes']} not below "
             f"target tier bytes {rec['weight_bytes']}"),
        gate("pool_clean", rec["pool_clean"],
             "pages or trie entries leaked after the composed drain "
             "(pool, trie, or draft-page rollback)"),
    ]


def _spec_gates(rec: dict) -> list[tuple[str, str | None]]:
    """(gate name, failure message or None) for one spec record."""
    m = rec["mode"]

    def gate(name, ok, msg):
        return (f"{m}:{name}", None if ok else msg)

    gates = [
        gate("match_static", rec["match_static"],
             f"speculative tokens diverge from non-speculative greedy "
             f"reference ({len(rec['mismatches'])} reqs)"),
        gate("completed", rec["completed"] == rec["requests"],
             f"only {rec['completed']}/{rec['requests']} completed"),
        gate("windows_ran", rec["spec_windows"] > 0,
             "no speculative windows executed"),
        gate("pool_clean", rec["pool_clean"],
             "pages leaked after rejected-window rollbacks"),
    ]
    if rec["mode"] == "spec_self":
        gates += [
            gate("acceptance", rec["acceptance_rate"] > 0,
                 f"acceptance_rate={rec['acceptance_rate']} — the "
                 f"self-draft must agree with its own verify pass"),
            gate("speedup", rec["tokens_per_step"] > 1,
                 f"tokens_per_step={rec['tokens_per_step']} — accepted "
                 f"windows must beat one-token-per-step decode"),
        ]
    else:
        gates.append(
            gate("draft_bytes",
                 rec["draft_weight_bytes"] < rec["weight_bytes"],
                 f"draft tier bytes {rec['draft_weight_bytes']} not below "
                 f"target tier bytes {rec['weight_bytes']}"))
    return gates


def _stress_gates(rec: dict) -> list[tuple[str, str | None]]:
    """(gate name, failure message or None) for one stress record."""
    m = rec["mode"]

    def gate(name, ok, msg):
        return (f"{m}:{name}", None if ok else msg)

    return [
        gate("match_static", rec["match_static"],
             f"engine tokens diverge from static reference "
             f"({len(rec['mismatches'])} reqs)"),
        gate("completed", rec["completed"] == rec["requests"],
             f"only {rec['completed']}/{rec['requests']} completed"),
        gate("chunked_prefill", rec["prefill_chunks"] > rec["requests"],
             f"prefill_chunks={rec['prefill_chunks']} — chunking never "
             f"split a prompt (requests={rec['requests']})"),
        gate("preemption_cycle",
             rec["preemptions"] >= 1 and rec["swapped_in_pages"] >= 1,
             f"no full preemption/swap-in cycle (preemptions="
             f"{rec['preemptions']}, swapped_in={rec['swapped_in_pages']})"),
        gate("prefix_reuse", rec["shared_prompt_pages"] > 0,
             "no prompt pages were shared"),
        gate("page_saving",
             rec["prompt_pages_fresh"] < rec["prompt_pages_total"],
             f"pages allocated for prompts ({rec['prompt_pages_fresh']}) "
             f"not below sum of prompt pages "
             f"({rec['prompt_pages_total']})"),
        gate("pool_clean", rec["pool_clean"],
             "pages or trie entries leaked after drain"),
    ]


def _cache_gates(rec: dict) -> list[tuple[str, str | None]]:
    """(gate name, failure message or None) for the prefix-cache record:
    the second epoch must re-prefill nothing, and every tier must have
    actually carried pages."""
    m = rec["mode"]

    def gate(name, ok, msg):
        return (f"{m}:{name}", None if ok else msg)

    return [
        gate("match_static", rec["match_static"],
             f"cached-engine tokens diverge from static reference "
             f"({len(rec['mismatches'])} reqs)"),
        gate("completed", rec["completed"] == rec["requests"],
             f"only {rec['completed']}/{rec['requests']} completed"),
        gate("epoch2_zero_fresh", rec["epoch2_fresh_pages"] == 0,
             f"second epoch prefilled {rec['epoch2_fresh_pages']} fresh "
             "pages — the repeated prompt must resolve entirely from the "
             "cache"),
        gate("cache_hit", rec["prefix_hits"] >= 1,
             "no admission ever hit the cache"),
        gate("host_tier", rec["prefix_host_hits"] >= 1,
             f"host tier never served a promotion (host_hits="
             f"{rec['prefix_host_hits']}) — the HBM budget squeeze "
             "did not demote"),
        gate("disk_tier",
             rec["prefix_demotions_disk"] >= 1
             and rec["prefix_bytes_disk"] > 0,
             f"disk tier never spilled (demotions_disk="
             f"{rec['prefix_demotions_disk']}, bytes_disk="
             f"{rec['prefix_bytes_disk']})"),
        gate("tokens_saved", rec["reprefill_tokens_saved"] > 0,
             "cache served pages but saved no re-prefill tokens"),
        gate("pool_clean", rec["pool_clean"],
             "pages, trie entries, or HBM tier bytes leaked after "
             "flush_prefix_cache() drain"),
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=configs.ARCH_NAMES)
    ap.add_argument("--density", type=float, default=0.3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace (CI gate sizing)")
    ap.add_argument("--stress", action="store_true",
                    help="high-pressure trace: chunked prefill + "
                         "preemption/swap + prefix sharing, gated on each "
                         "mechanism firing")
    ap.add_argument("--spec", action="store_true",
                    help="speculative-decoding legs (self + sparse draft "
                         "tiers), gated on bit-identical tokens vs the "
                         "non-speculative greedy reference and a nonzero "
                         "self-draft acceptance rate")
    ap.add_argument("--stress-spec", action="store_true",
                    help="every feature composed: speculative decoding x "
                         "chunked prefill x preemption x prefix sharing on "
                         "a bursty shared-prefix trace, gated on each "
                         "mechanism firing while the others are on "
                         "(incl. a preemption mid-draft-window)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--output", default="BENCH_serving.json")
    ap.add_argument("--junit", default=None,
                    help="also write every gate as a junit XML testcase")
    ap.add_argument("--tuning-cache", default=None)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON timeline of the "
                         "whole run (all legs) to PATH — open in Perfetto "
                         "or summarize with scripts/trace_report.py")
    args = ap.parse_args(argv)

    if args.stress:
        if args.smoke:
            ap.error("--stress and --smoke are mutually exclusive")
        # the stress trace is calibrated (pool of 8 usable pages vs three
        # 6-page lifetimes) so its preemption/sharing gates fire
        # deterministically — free sizing would silently defeat them
        for flag, default in (("requests", 16), ("prompt_len", 24),
                              ("gen", 12), ("max_slots", 4),
                              ("page_size", 8)):
            if getattr(args, flag) != default:
                ap.error(f"--stress replays a fixed calibrated trace; "
                         f"--{flag.replace('_', '-')} is not configurable "
                         "with it")
    if args.spec and (args.smoke or args.stress):
        ap.error("--spec is its own leg; combine with neither --smoke "
                 "nor --stress")
    if args.stress_spec:
        if args.smoke or args.stress or args.spec:
            ap.error("--stress-spec is its own leg; combine with none of "
                     "--smoke/--stress/--spec")
        # like --stress: the trace is calibrated so every composed gate
        # fires deterministically — free sizing would silently defeat it
        for flag, default in (("requests", 16), ("prompt_len", 24),
                              ("gen", 12), ("max_slots", 4),
                              ("page_size", 8)):
            if getattr(args, flag) != default:
                ap.error(f"--stress-spec replays a fixed calibrated trace; "
                         f"--{flag.replace('_', '-')} is not configurable "
                         "with it")
    if args.smoke:
        args.requests, args.prompt_len, args.gen = 6, 10, 5
        args.max_slots, args.page_size = 3, 4
    if args.spec:
        # calibrated like --smoke: tiny trace, but window-heavy (gen big
        # enough for several k-token windows per sequence)
        args.requests, args.prompt_len, args.gen = 4, 10, 6
        args.max_slots, args.page_size = 2, 4
    cache = autotune.install_cache(args.tuning_cache)
    tracer = None
    if args.trace:
        # installed before any engine is built, so every leg's phase
        # spans, request lifecycle, and kernel dispatch land in one file
        tracer = obs.install_tracer(obs.Tracer())

    cases = []
    gates: list[tuple[str, str | None]] = []
    if args.stress:
        # long prompts vs a pool that cannot hold every admitted
        # sequence's decode growth: 3 slots × up-to-6 lifetime pages
        # against 8 usable pages forces eviction once growth starts,
        # and the shared 8-token prefix (2 pages) packs once
        for mode in ("dense", "tiled_csc"):
            rec = stress_variant(
                args.arch, mode, density=args.density, requests=6,
                prefix_len=8, max_prompt=16, max_new=8, max_slots=3,
                page_size=4, prefill_chunk=4, n_pages=9, arrival_gap=2,
                seed=args.seed, cache=cache)
            cases.append(rec)
            gates += _stress_gates(rec)
            print(f"{rec['mode']:>10}  match={rec['match_static']!s:5}  "
                  f"chunks={rec['prefill_chunks']:>3}  "
                  f"preempt={rec['preemptions']}  "
                  f"swap_in={rec['swapped_in_pages']:>2}  "
                  f"shared={rec['shared_prompt_pages']}  "
                  f"forks={rec['cow_forks']}  "
                  f"pages={rec['prompt_pages_fresh']}/"
                  f"{rec['prompt_pages_total']}")
        # third record: the persistent multi-tier prefix cache replaying
        # two identical epochs — second epoch must prefill zero fresh
        # pages, with the host and disk tiers both demonstrably carrying
        rec = cache_variant(args.arch, density=args.density,
                            seed=args.seed, cache=cache)
        cases.append(rec)
        gates += _cache_gates(rec)
        print(f"{rec['mode']:>12}  match={rec['match_static']!s:5}  "
              f"epoch2_fresh={rec['epoch2_fresh_pages']}  "
              f"hits={rec['prefix_hits']}  "
              f"host={rec['prefix_host_hits']}  "
              f"disk_demote={rec['prefix_demotions_disk']}  "
              f"saved_tok={rec['reprefill_tokens_saved']}")
        failures = [f"{name}: {msg}" for name, msg in gates if msg]
    elif args.stress_spec:
        rec = stress_spec_variant(args.arch, density=args.density,
                                  seed=args.seed, cache=cache)
        cases.append(rec)
        gates += _stress_spec_gates(rec)
        print(f"{rec['mode']:>11}  match={rec['match_static']!s:5}  "
              f"windows={rec['spec_windows']:>3}  "
              f"preempt={rec['preemptions']}  "
              f"mid_window={rec['spec_window_preemptions']}  "
              f"rollbacks={rec['spec_rollbacks']}  "
              f"shared={rec['shared_prompt_pages']}  "
              f"chunks={rec['prefill_chunks']}")
        failures = [f"{name}: {msg}" for name, msg in gates if msg]
    elif args.spec:
        for draft in ("self", "sparse"):
            rec = spec_variant(
                args.arch, draft, density=args.density, spec_k=2,
                requests=args.requests, max_prompt=args.prompt_len,
                max_new=args.gen, max_slots=args.max_slots,
                page_size=args.page_size, seed=args.seed, cache=cache)
            cases.append(rec)
            gates += _spec_gates(rec)
            print(f"{rec['mode']:>11}  match={rec['match_static']!s:5}  "
                  f"accept={rec['acceptance_rate']:.3f}  "
                  f"tok/step={rec['tokens_per_step']:.2f}  "
                  f"windows={rec['spec_windows']:>3}  "
                  f"draft_bytes={rec['draft_weight_bytes']:>9}")
        failures = [f"{name}: {msg}" for name, msg in gates if msg]
    else:
        for mode in VARIANTS:
            rec = bench_variant(
                args.arch, mode, density=args.density,
                requests=args.requests, max_prompt=args.prompt_len,
                max_new=args.gen, max_slots=args.max_slots,
                page_size=args.page_size, seed=args.seed, cache=cache)
            cases.append(rec)
            print(f"{rec['mode']:>10}  match={rec['match_static']!s:5}  "
                  f"bytes={rec['weight_bytes']:>9}  "
                  f"ratio={rec['compression_ratio']:.3f}  "
                  f"steady={rec['steady_tok_per_s']:.1f} tok/s  "
                  f"p99={rec['p99_latency_s']:.3f}s")

        dense_bytes = next(c["weight_bytes"] for c in cases
                           if c["mode"] == "dense")
        failures = []
        for c in cases:
            match_msg = None
            if not c["match_static"]:
                match_msg = (f"engine tokens diverge from static reference "
                             f"({len(c['mismatches'])} reqs)")
            gates.append((f"{c['mode']}:match_static", match_msg))
            if c["mode"] != "dense":
                bytes_msg = None
                if c["weight_bytes"] >= dense_bytes:
                    bytes_msg = (f"compressed bytes {c['weight_bytes']} "
                                 f"not below dense {dense_bytes}")
                gates.append((f"{c['mode']}:compressed_bytes", bytes_msg))
        if args.smoke:
            # observability-perturbation gate: the same trace with a live
            # tracer vs the no-op one must emit bit-identical tokens
            rec = trace_identity_case(
                args.arch, requests=args.requests,
                max_prompt=args.prompt_len, max_new=args.gen,
                max_slots=args.max_slots, page_size=args.page_size,
                seed=args.seed)
            cases.append(rec)
            gates.append(
                ("trace_identity:tokens", None if rec["match"] else
                 "engine tokens differ between trace-enabled and "
                 "trace-disabled runs"))
            print(f"{rec['mode']:>10}  match={rec['match']!s:5}")
        failures = [f"{name}: {msg}" for name, msg in gates if msg]

    kind = "serving_bench"
    if args.stress:
        kind = "serving_bench_stress"
    elif args.spec:
        kind = "serving_bench_spec"
    elif args.stress_spec:
        kind = "serving_bench_stress_spec"
    out = {
        "kind": kind,
        "arch": args.arch, "density": args.density, "smoke": args.smoke,
        "stress": args.stress, "spec": args.spec,
        "stress_spec": args.stress_spec,
        "cases": cases, "failures": failures, "ok": not failures,
    }
    path = pathlib.Path(args.output)
    path.write_text(json.dumps(out, indent=2))
    print(f"wrote {path}")
    if tracer is not None:
        print(f"wrote {tracer.export(args.trace)}")
        obs.install_tracer(None)
    if args.junit:
        suite = kind
        print(f"wrote {write_junit(args.junit, suite, gates)}")
    if failures:
        print("FAIL:\n  " + "\n  ".join(failures))
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
