"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per single-pod (arch × shape) cell, from the compiled dry-run JSON:

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s         [s]
  memory term     = HLO_bytes_per_chip / HBM_bw              [s]
  collective term = collective_bytes_per_chip / link_bw      [s]

(The SPMD-partitioned module is the per-chip program, so cost_analysis and
the parsed collective operand sizes are already per chip.)  Also derives
MODEL_FLOPS (6·N·D dense / 6·N_active·D MoE; 2·N per decoded token) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs, which flags remat/masking
waste.  Caveat recorded in EXPERIMENTS.md: CPU-backend ``bytes accessed``
counts every HLO op's operands without TPU fusion, so the memory term is an
upper bound; the analytic weight/cache stream is reported alongside.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--write]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro import configs
from repro.configs.base import SHAPES
from repro.core.topology import TPU_V5E

RESULTS = pathlib.Path(__file__).resolve().parent / "results" / "dryrun"
OUT = pathlib.Path(__file__).resolve().parent / "results" / "roofline.md"

CHIP = TPU_V5E
N_CHIPS = 256


def model_flops_per_chip(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs per chip per step (fwd+bwd for train)."""
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    d, v = cfg.d_model, cfg.vocab
    embed_table = v * d * (cfg.n_codebooks if cfg.family == "audio" else 1)
    compute_params = cfg.active_param_count() - embed_table
    if cfg.tie_embeddings:
        compute_params += v * d       # tied head still does the matmul

    s, b = shape.seq_len, shape.global_batch
    if shape.kind == "decode":
        tokens = b                     # one new token per sequence
        matmul = 2.0 * compute_params * tokens
        # attention reads the whole cache once per new token
        attn_layers = cfg.n_layers if cfg.family in ("dense", "moe", "vlm",
                                                     "audio") else \
            (cfg.n_layers // cfg.hybrid_attn_every
             if cfg.family == "hybrid" else 0)
        attn = 4.0 * tokens * attn_layers * cfg.n_heads * cfg.head_dim * s
        total = matmul + attn
    else:
        tokens = b * s
        mult = 3.0 if shape.kind == "train" else 1.0   # fwd+bwd ≈ 3× fwd
        matmul = 2.0 * compute_params * tokens * mult
        # causal attention: avg context = S/2 (window caps it on local layers)
        attn = 0.0
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            per_layer_ctx = []
            for j in range(cfg.pattern_period):
                w = cfg.window_for(j)
                ctx = min(w, s) / 2 if w else s / 2
                per_layer_ctx.append(ctx)
            layers_ctx = sum(per_layer_ctx) / len(per_layer_ctx) * cfg.n_layers
            attn = 4.0 * tokens * cfg.n_heads * cfg.head_dim * \
                (layers_ctx / cfg.n_layers) * cfg.n_layers * mult
        elif cfg.family == "hybrid":
            n_attn = cfg.n_layers // cfg.hybrid_attn_every
            attn = 4.0 * tokens * cfg.n_heads * cfg.head_dim * (s / 2) \
                * n_attn * mult
            di = cfg.ssm_expand * d
            attn += 2.0 * tokens * cfg.n_layers * di * \
                (cfg.ssm_chunk + 4 * cfg.ssm_state) * mult
        elif cfg.family == "ssm":
            di = int(d * cfg.xlstm_proj_factor)
            attn = 2.0 * tokens * cfg.n_layers * di * cfg.ssm_chunk * mult
        total = matmul + attn
    return total / N_CHIPS


def load_cells(mesh: str = "16x16", sod: str = "dense",
               results_dir: pathlib.Path | None = None):
    cells = []
    for f in sorted((results_dir or RESULTS).glob(f"*__{mesh}__{sod}.json")):
        r = json.loads(f.read_text())
        cells.append(r)
    return cells


def analyze_cell(rec: dict) -> dict | None:
    if rec["status"] != "ok" or "cost" not in rec:
        return None
    if "error" in rec.get("cost", {}):
        return None
    # probe extrapolation can be noisy on CPU (fusion differences between
    # depths); the scan-HLO counters (while bodies counted once) are a hard
    # floor — clamp to them.
    floor = rec.get("cost_scan_hlo", {})
    flops = max(rec["cost"]["flops"], floor.get("flops", 0.0))
    bytes_ = max(rec["cost"]["bytes_accessed"],
                 floor.get("bytes_accessed", 0.0))
    coll = max(rec.get("collectives", {}).get("total", 0.0),
               rec.get("collectives_scan_hlo", {}).get("total", 0.0))
    t_c = flops / CHIP.peak_bf16_flops
    t_m = bytes_ / CHIP.hbm_bandwidth
    t_x = coll / CHIP.ici_link_bandwidth
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops_per_chip(rec["arch"], rec["shape"])
    bound = max(t_c, t_m, t_x)
    ideal = mf / CHIP.peak_bf16_flops
    return {
        "arch": rec["arch"], "shape": rec["shape"], "sod": rec.get("sod"),
        "t_compute": t_c, "t_memory": t_m, "t_collective": t_x,
        "dominant": dom,
        "model_flops": mf, "hlo_flops": flops,
        "useful_ratio": mf / max(flops, 1.0),
        "roofline_fraction": ideal / max(bound, 1e-30),
        "step_bound_s": bound,
    }


_HINTS = {
    "compute": "cut HLO flops toward MODEL_FLOPS (mask-block skipping, "
               "cheaper remat policy, avoid recompute)",
    "memory": "cut HBM bytes (SoD-compress weight streams, fuse, smaller "
              "remat live set, windowed KV cache)",
    "collective": "cut ICI bytes (SoD-compressed all-gather, reshard to "
                  "avoid activation all-reduces, overlap)",
}


def make_table(sod: str = "dense",
               results_dir: pathlib.Path | None = None) -> str:
    rows = []
    for rec in load_cells(sod=sod, results_dir=results_dir):
        a = analyze_cell(rec)
        if a is None:
            if rec["status"] == "skipped":
                rows.append(
                    f"| {rec['arch']} | {rec['shape']} | — | — | — | skipped"
                    f" | — | — | {rec.get('reason', '')[:40]} |")
            continue
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute']*1e3:.2f} "
            f"| {a['t_memory']*1e3:.2f} | {a['t_collective']*1e3:.2f} "
            f"| {a['dominant']} | {a['useful_ratio']:.2f} "
            f"| {a['roofline_fraction']*100:.1f}% | {_HINTS[a['dominant']][:46]} |")
    header = (
        "| arch | shape | compute ms | memory ms | collective ms | dominant "
        "| useful ratio | roofline frac | to improve |\n"
        "|---|---|---|---|---|---|---|---|---|")
    return header + "\n" + "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sod", default="dense")
    ap.add_argument("--dir", default=None,
                    help="results dir (e.g. results/dryrun_baseline)")
    ap.add_argument("--write", action="store_true")
    args = ap.parse_args()
    rdir = pathlib.Path(args.dir) if args.dir else None
    table = make_table(args.sod, results_dir=rdir)
    print(table)
    if args.write:
        out = OUT if rdir is None else OUT.with_name(
            f"roofline_{rdir.name}.md")
        out.write_text(table + "\n")
        print(f"\nwritten to {out}")


if __name__ == "__main__":
    main()
