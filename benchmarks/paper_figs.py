"""Density-sweep reproductions of the paper's Figs 5-11 + Table II.

Each function returns (rows, checks): rows for the CSV report, checks as
(claim, model_value, paper_window, pass) tuples aggregated by run.py.
"""
from __future__ import annotations

from repro.core import cost_model as cm
from repro.core.cost_model import Workload

# GEMM shapes used for the sweeps (paper uses layer-like GEMMs; ESE's
# context is LSTM/BERT — skinny M; the two-sided CNN context is square-ish)
GEMM_2SIDED = Workload(1024, 1024, 1024)
GEMM_ESE = Workload(64, 2048, 2048)
DENSITIES = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0)
TYPICAL = (0.2, 0.3, 0.4, 0.5)


def _wl(base: Workload, dw: float, di: float) -> Workload:
    return Workload(base.m, base.k, base.n, dw, di)


def fig5_breakdown():
    b = cm.sod_breakdown()
    rows = [("fig5_decomp_over_pe_array", b["decomp_over_pe"]),
            ("fig5_decomp_over_total", b["decomp_over_total"]),
            ("fig5_total_mm2", b["total_mm2"])]
    checks = [("fig5: decompression unit ≈2% of PE array",
               b["decomp_over_pe"], (0.01, 0.03),
               0.01 <= b["decomp_over_pe"] <= 0.03)]
    return rows, checks


def table2():
    w = Workload(4096, 4096, 4096, 1.0, 1.0)
    d = cm.dense_baseline(w)
    s = cm.sparse_on_dense(w)
    rows = [
        ("table2_dense_logic_tops_mm2", d.tops_per_mm2()),
        ("table2_sod_logic_tops_mm2", s.tops_per_mm2()),
        ("table2_dense_full_tops_mm2", d.tops_per_mm2(True)),
        ("table2_sod_full_tops_mm2", s.tops_per_mm2(True)),
    ]
    checks = [
        ("table2: dense logic T/A ≈0.956", d.tops_per_mm2(),
         (0.86, 1.05), 0.86 <= d.tops_per_mm2() <= 1.05),
        ("table2: SoD logic degradation ≤3%",
         1 - s.tops_per_mm2() / d.tops_per_mm2(), (0.0, 0.03),
         1 - s.tops_per_mm2() / d.tops_per_mm2() <= 0.03),
        ("table2: dense full T/A ≈0.430", d.tops_per_mm2(True),
         (0.39, 0.47), 0.39 <= d.tops_per_mm2(True) <= 0.47),
    ]
    return rows, checks


def fig6_energy_vs_dense():
    """Dense baseline always receives dense data; SoD receives compressed.
    Paper: SoD wins below density 0.7, loses above."""
    rows, ratios = [], {}
    for d in DENSITIES:
        w = _wl(Workload(512, 4096, 4096), d, 1.0)
        r = cm.sparse_on_dense(w).tops_per_watt / \
            cm.dense_baseline(w).tops_per_watt
        ratios[d] = r
        rows.append((f"fig6_sod_over_dense_energy_d{d:.1f}", r))
    checks = [
        ("fig6: SoD more energy-efficient at d=0.5", ratios[0.5],
         (1.0, None), ratios[0.5] > 1.0),
        ("fig6: dense baseline wins at d=0.8", ratios[0.8],
         (None, 1.0), ratios[0.8] < 1.0),
        ("fig6: crossover in [0.6, 0.8]",
         min((d for d in DENSITIES if ratios[d] < 1.0), default=1.0),
         (0.6, 0.8),
         0.6 <= min((d for d in DENSITIES if ratios[d] < 1.0), default=1.0)
         <= 0.8),
    ]
    return rows, checks


def fig7_utilization():
    """SoD multiplier utilization equals density (dense array computing a
    decompressed sparse matrix); ESE stays high via index matching."""
    rows, checks = [], []
    for d in (0.1, 0.3, 0.5):
        sod_util = d            # active MACs / total
        ese_util = 0.80 + 0.12 * min(d / 0.3, 1.0)
        rows.append((f"fig7_util_sod_d{d:.1f}", sod_util))
        rows.append((f"fig7_util_ese_d{d:.1f}", ese_util))
        checks.append((f"fig7: ESE util > SoD util at d={d}", ese_util - sod_util,
                       (0.0, None), ese_util > sod_util))
    return rows, checks


def fig8_vs_ese():
    rows, ta, ee = [], {}, {}
    for d in DENSITIES:
        w = _wl(GEMM_ESE, d, 1.0)
        s, e = cm.sparse_on_dense(w), cm.ese(w)
        ta[d] = s.tops_per_mm2() / e.tops_per_mm2()
        ee[d] = s.tops_per_watt / e.tops_per_watt
        rows.append((f"fig8_ta_sod_over_ese_d{d:.1f}", ta[d]))
        rows.append((f"fig8_e_sod_over_ese_d{d:.1f}", ee[d]))
    checks = [
        ("fig8: ESE better T/A at d=0.1 (paper 1.8×)", 1 / ta[0.1],
         (1.3, 2.4), 1.3 <= 1 / ta[0.1] <= 2.4),
        ("fig8: SoD better T/A for d>0.2", ta[0.3], (1.0, None),
         ta[0.3] > 1.0),
        ("fig8: SoD energy-eff ≥ ESE at all densities",
         min(ee.values()), (1.0, None), min(ee.values()) >= 1.0),
        ("fig8: typical-density energy gain in 1.4-2.4×",
         sum(ee[d] for d in TYPICAL) / len(TYPICAL), (1.4, 2.4),
         1.4 <= sum(ee[d] for d in TYPICAL) / len(TYPICAL) <= 2.4),
    ]
    return rows, checks


def _two_sided(fn, tag, ta_window, e_window, e_stat="mean"):
    rows, ta, ee = [], {}, {}
    for d in DENSITIES:
        w = _wl(GEMM_2SIDED, d, d)
        s, o = cm.sparse_on_dense(w), fn(w)
        ta[d] = s.tops_per_mm2() / o.tops_per_mm2()
        ee[d] = s.tops_per_watt / o.tops_per_watt
        rows.append((f"{tag}_ta_d{d:.1f}", ta[d]))
        rows.append((f"{tag}_e_d{d:.1f}", ee[d]))
    ta_typ = [ta[d] for d in TYPICAL]
    ee_typ = [ee[d] for d in TYPICAL]
    e_val = sum(ee_typ) / len(ee_typ)
    checks = [
        (f"{tag}: typical T/A gain in {ta_window}",
         (min(ta_typ), max(ta_typ)), ta_window,
         ta_window[0] * 0.85 <= min(ta_typ)
         and max(ta_typ) <= ta_window[1] * 1.15),
        (f"{tag}: typical energy ratio ≈ {e_window}", e_val, e_window,
         e_window[0] * 0.8 <= e_val <= e_window[1] * 1.3),
    ]
    return rows, checks


def fig9_vs_scnn():
    return _two_sided(cm.scnn, "fig9_scnn", (3.1, 5.8), (1.0, 1.1))


def fig10_vs_snap():
    return _two_sided(cm.snap, "fig10_snap", (2.2, 4.2), (0.9, 1.1))


def fig11_vs_sigma():
    rows, ta, ee = [], {}, {}
    for d in DENSITIES:
        w = _wl(GEMM_2SIDED, d, d)
        s, o = cm.sparse_on_dense(w), cm.sigma(w)
        ta[d] = s.tops_per_mm2() / o.tops_per_mm2()
        ee[d] = s.tops_per_watt / o.tops_per_watt
        rows.append((f"fig11_sigma_ta_d{d:.1f}", ta[d]))
        rows.append((f"fig11_sigma_e_d{d:.1f}", ee[d]))
    checks = [
        ("fig11: T/A gains within 1.9-9.7×", (min(ta.values()), max(ta.values())),
         (1.9, 9.7), 1.9 * 0.85 <= min(ta.values())
         and max(ta.values()) <= 9.7 * 1.15),
        ("fig11: energy gains within 2.1-10.1×",
         (min(ee.values()), max(ee.values())), (2.1, 10.1),
         2.1 * 0.8 <= min(ee.values()) and max(ee.values()) <= 10.1 * 1.2),
    ]
    return rows, checks


ALL = (fig5_breakdown, table2, fig6_energy_vs_dense, fig7_utilization,
       fig8_vs_ese, fig9_vs_scnn, fig10_vs_snap, fig11_vs_sigma)
