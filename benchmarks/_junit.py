"""Minimal junit-XML writer for the benchmark gate scripts.

The CI test-matrix and spmd jobs get junit artifacts from pytest; the
bench-smoke / serving-smoke jobs run plain gate scripts, so they emit the
same format themselves — one ``<testcase>`` per gated invariant, with a
``<failure>`` element carrying the human-readable reason when it trips.
"""
from __future__ import annotations

import pathlib
from xml.sax.saxutils import escape, quoteattr


def write_junit(path: str | pathlib.Path, suite: str,
                cases: list[tuple[str, str | None]]) -> pathlib.Path:
    """Write ``cases`` — (name, failure message or None) pairs — as a
    single-suite junit XML file."""
    n_fail = sum(1 for _, msg in cases if msg)
    lines = [
        '<?xml version="1.0" encoding="utf-8"?>',
        f'<testsuite name={quoteattr(suite)} tests="{len(cases)}" '
        f'failures="{n_fail}" errors="0" skipped="0">',
    ]
    for name, msg in cases:
        if msg:
            lines.append(
                f"  <testcase classname={quoteattr(suite)} "
                f"name={quoteattr(name)}>"
                f"<failure message={quoteattr(msg)}>{escape(msg)}"
                f"</failure></testcase>")
        else:
            lines.append(
                f"  <testcase classname={quoteattr(suite)} "
                f"name={quoteattr(name)} />")
    lines.append("</testsuite>")
    path = pathlib.Path(path)
    path.write_text("\n".join(lines) + "\n")
    return path
